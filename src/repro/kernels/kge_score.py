"""Pallas TPU kernel for blocked DistMult candidate ranking (DESIGN.md §6).

Filtered MRR/Hits@k evaluation scores every test head against up to all N
entity embeddings: ``scores[b, c] = sum_d h_s[b,d] * m_r[b,d] * cand[c,d]``.
This is memory-bound in the candidate stream (arithmetic intensity ≈ d per
candidate row read), so the kernel keeps the query tile ``q = h_s ∘ m_r``
resident in VMEM and streams 128-row candidate tiles from HBM, fusing the
diagonal-relation product and the filtered-setting additive mask into the
matmul (XLA would write q and the unmasked score matrix to HBM between ops).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


Q_BLOCK = 128   # query rows per tile
C_BLOCK = 128   # candidate rows per tile


def _kge_score_kernel(h_s_ref, diag_ref, cand_ref, bias_ref, out_ref):
    """out = (h_s ∘ diag) @ cand^T + bias for one (Q_blk, C_blk) tile."""
    q = (h_s_ref[...] * diag_ref[...]).astype(jnp.float32)
    scores = jax.lax.dot_general(
        q, cand_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] = (scores + bias_ref[...].astype(jnp.float32)).astype(
        out_ref.dtype)


def kge_score(
    h_s: jax.Array,       # (B, d) head embeddings
    rel_diag: jax.Array,  # (B, d) gathered DistMult diagonal per query
    candidates: jax.Array,  # (C, d)
    bias: jax.Array,      # (B, C) additive mask (0 or -inf for filtered)
    *, interpret: bool | None = None,
) -> jax.Array:
    b, d = h_s.shape
    c = candidates.shape[0]
    assert b % Q_BLOCK == 0 and c % C_BLOCK == 0, \
        "ragged B/C must go through ops.kge_score_padded"
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return pl.pallas_call(
        _kge_score_kernel,
        grid=(b // Q_BLOCK, c // C_BLOCK),
        in_specs=[
            pl.BlockSpec((Q_BLOCK, d), lambda i, j: (i, 0)),
            pl.BlockSpec((Q_BLOCK, d), lambda i, j: (i, 0)),
            pl.BlockSpec((C_BLOCK, d), lambda i, j: (j, 0)),
            pl.BlockSpec((Q_BLOCK, C_BLOCK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((Q_BLOCK, C_BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(h_s, rel_diag, candidates, bias)
