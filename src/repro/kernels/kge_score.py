"""Pallas TPU kernel for blocked KGE candidate ranking (DESIGN.md §6).

Filtered MRR/Hits@k evaluation scores every test query against up to all N
entity embeddings.  Every registered decoder reduces to the canonical query
form (``repro.models.decoders``):

    ``scores[b, c] = epilogue(q[b]·C'[c] + q_bias[b] + c_bias[c])
                     + filter_bias[b, c]``

which is memory-bound in the candidate stream (arithmetic intensity ≈ d per
candidate row read), so the kernel keeps the query tile resident in VMEM and
streams 128-row candidate tiles from HBM, fusing the rank-1 pre-epilogue
biases, the epilogue and the filtered-setting additive mask into the matmul
(XLA would write the unmasked score matrix to HBM between ops).

Epilogue families (static, selected at trace time):

* ``bilinear`` — identity; DistMult / ComplEx (their ``q_bias``/``c_bias``
  are zero).
* ``neg_l2``   — ``-sqrt(max(x, 0) + NORM_EPS)``: with the norm-expansion
  query (``q = −2u``, ``q_bias = ‖u‖²``, ``c_bias = ‖c‖²``) this is the
  safe negative L2 distance ``−‖u − c‖`` of TransE / RotatE.  The eps sits
  UNDER the sqrt (zero-distance pairs score ``−sqrt(eps)``, gradients stay
  finite) — never inside the difference vector, which would shift every
  score.

The ``filter_bias`` is added AFTER the epilogue: ``-inf`` pad rows and
``FILTER_BIAS`` filtered candidates stay ``-inf``/large-negative on the
score scale for both families, so rank counting over masked scores is exact.
Both epilogues are elementwise and deterministic per (query row, candidate
row), so candidate-axis sharding (``repro.eval.sharded``) reproduces dense
scores bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


Q_BLOCK = 128   # query rows per tile
C_BLOCK = 128   # candidate rows per tile

NORM_EPS = 1e-9          # safe-norm eps, under the sqrt
EPILOGUES = ("bilinear", "neg_l2")


def apply_epilogue(x: jax.Array, epilogue: str) -> jax.Array:
    """The (elementwise, monotone) epilogue families.  Used
    verbatim inside the kernel body and by every XLA-path scorer, so there
    is exactly one definition of the score non-linearity."""
    if epilogue == "bilinear":
        return x
    if epilogue == "neg_l2":
        return -jnp.sqrt(jnp.maximum(x, 0.0) + NORM_EPS)
    raise ValueError(f"unknown epilogue {epilogue!r}; known: {EPILOGUES}")


def _kge_score_kernel(q_ref, cand_ref, qb_ref, cb_ref, bias_ref, out_ref,
                      *, epilogue: str):
    """One (Q_blk, C_blk) tile of
    ``epilogue(q @ cand^T + q_bias + c_bias) + bias``."""
    q = q_ref[...].astype(jnp.float32)
    scores = jax.lax.dot_general(
        q, cand_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    scores = scores + qb_ref[...].astype(jnp.float32) \
        + cb_ref[...].astype(jnp.float32)
    scores = apply_epilogue(scores, epilogue)
    out_ref[...] = (scores + bias_ref[...].astype(jnp.float32)).astype(
        out_ref.dtype)


def kge_score(
    q: jax.Array,           # (B, d) prepared query rows
    candidates: jax.Array,  # (C, d) prepared candidate rows
    bias: jax.Array,        # (B, C) POST-epilogue mask (0 / -1e9 / -inf)
    q_bias: jax.Array,      # (B, 1) pre-epilogue per-query bias
    c_bias: jax.Array,      # (1, C) pre-epilogue per-candidate bias
    *, epilogue: str = "bilinear", interpret: bool | None = None,
) -> jax.Array:
    b, d = q.shape
    c = candidates.shape[0]
    assert b % Q_BLOCK == 0 and c % C_BLOCK == 0, \
        "ragged B/C must go through ops.kge_score_padded"
    assert q_bias.shape == (b, 1) and c_bias.shape == (1, c), \
        (q_bias.shape, c_bias.shape)
    if epilogue not in EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return pl.pallas_call(
        functools.partial(_kge_score_kernel, epilogue=epilogue),
        grid=(b // Q_BLOCK, c // C_BLOCK),
        in_specs=[
            pl.BlockSpec((Q_BLOCK, d), lambda i, j: (i, 0)),
            pl.BlockSpec((C_BLOCK, d), lambda i, j: (j, 0)),
            pl.BlockSpec((Q_BLOCK, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, C_BLOCK), lambda i, j: (0, j)),
            pl.BlockSpec((Q_BLOCK, C_BLOCK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((Q_BLOCK, C_BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(q, candidates, q_bias, c_bias, bias)
