"""Pallas TPU kernel for per-shard streaming top-k selection.

The serving engine (``repro.serving``) answers ``(head, relation, ?)``
queries with the k best tails.  The dense path materializes the full
``(B, N)`` score matrix on one device and runs ``jax.lax.top_k`` — the
memory wall the candidate-axis-sharded table was built to remove.  The
sharded path instead scores each shard's ``(B, rows/S)`` block with the
``kge_score`` kernel, reduces it to ``(B, k)`` *immediately* with this
kernel, and k-way-merges the per-shard winners — the ``(B, N)`` matrix
never exists on any device (peak score memory per device is one
``(B, rows/S)`` block, and only ``S · B · k`` merge candidates cross
shards).

Selection contract (the serving ``==``-vs-dense gate depends on it):

    k iterations of  (max over still-active columns,
                      LOWEST column index among the maxima wins,
                      winner deactivated)

which is exactly ``jax.lax.top_k``'s documented order — values descending,
ties broken toward the lower index — so per-shard top-k + merge reproduces
the dense ``jax.lax.top_k`` indices EXACTLY (shard row blocks are
contiguous global-id ranges: among equal values, a lower global id is an
earlier shard or a lower local index, both of which the merge preserves).
The oracle is ``kernels.ref.topk_ref`` (same algorithm, pure jnp);
``tests/test_serving.py`` asserts kernel == ref == ``jax.lax.top_k``.

The ``active`` mask — not a ``-inf`` substitution — is what keeps ties
exact: a selected ``-inf`` score (layout-padded rows, filtered
candidates) would be re-selected forever if masking rewrote values, but
deactivation removes the *column*, so repeated ``-inf`` entries drain in
ascending index order exactly like ``lax.top_k``.

One grid step per ``Q_BLOCK`` query rows; the candidate axis stays whole
in VMEM (serving blocks are ``rows/S ≲ 32k`` columns — well inside the
VMEM budget at 128 query rows).  The jit-ready wrapper with B-padding,
k-clamping and the CPU dispatch to the bit-identical ``jax.lax.top_k``
lowering is ``repro.kernels.ops.topk_padded``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


TOPK_Q_BLOCK = 128   # query rows per grid step


def _topk_kernel(scores_ref, vals_ref, idx_ref, *, k: int):
    """Deterministic iterative selection on one (Q_BLOCK, C) score tile."""
    scores = scores_ref[...].astype(jnp.float32)          # (Q, C)
    q, c = scores.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (q, c), 1)
    kcol = jax.lax.broadcasted_iota(jnp.int32, (q, k), 1)

    def body(j, carry):
        active, vals, idx = carry
        cur = jnp.where(active, scores, -jnp.inf)
        m = jnp.max(cur, axis=1)                          # (Q,)
        # the winner: lowest ACTIVE column attaining the max ("& active"
        # matters — when m == -inf every deactivated column also compares
        # equal, and without it the same column would win every round)
        hit = active & (cur == m[:, None])
        pick = jnp.min(jnp.where(hit, col, c), axis=1)    # (Q,)
        vals = jnp.where(kcol == j, m[:, None], vals)
        idx = jnp.where(kcol == j, pick[:, None], idx)
        return active & (col != pick[:, None]), vals, idx

    _, vals, idx = jax.lax.fori_loop(
        0, k, body,
        (jnp.ones((q, c), jnp.bool_),
         jnp.full((q, k), -jnp.inf, jnp.float32),
         jnp.zeros((q, k), jnp.int32)))
    vals_ref[...] = vals
    idx_ref[...] = idx


def topk_scores(
    scores: jax.Array,      # (B, C) float score block
    k: int,
    *, interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Top-k per row of a score block: ``(values (B, k), indices (B, k))``,
    values descending, ties broken toward the LOWEST index — bit-equal to
    ``jax.lax.top_k`` on float32 scores.  B must be a ``TOPK_Q_BLOCK``
    multiple and ``k <= C`` (``ops.topk_padded`` pads/clamps ragged
    callers)."""
    b, c = scores.shape
    assert b % TOPK_Q_BLOCK == 0, \
        "ragged B must go through ops.topk_padded"
    assert 1 <= k <= c, f"k={k} outside [1, C={c}] — ops.topk_padded clamps"
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(b // TOPK_Q_BLOCK,),
        in_specs=[pl.BlockSpec((TOPK_Q_BLOCK, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((TOPK_Q_BLOCK, k), lambda i: (i, 0)),
            pl.BlockSpec((TOPK_Q_BLOCK, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(scores)
