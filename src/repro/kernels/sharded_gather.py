"""Pallas TPU kernels for the sharded-embedding gather hot path.

The PR-2 sharded entity table made per-device memory scale 1/S but left the
gather 3-4x SLOWER than the dense gather it replaced: the shard-local
take → mask → sum/psum chain materializes an (S, V, d) intermediate and
touches ``S × V × d`` elements where the dense gather touches ``V × d``
(``BENCH_embedding.json``, ROADMAP open item 2).  Exactly one shard owns
every id, so the mask+accumulate is pure bookkeeping — it can be folded
into the *index arithmetic*:

    ``flat[v] = Σ_s owned[s, v] ? s · rows + local_ids[s, v] : 0``

turns the per-shard plan back into one flat row index into the stacked
``(S · rows, d)`` table, and the whole chain collapses to a single masked
row gather — the exchange's masked sum never exists as data movement.
This module provides that collapsed op as Pallas kernels:

* ``fused_gather`` — forward: one output row per grid step; the row index
  is a scalar-prefetch argument (``PrefetchScalarGridSpec``), so the block
  index map DMAs exactly the owner's row from the stacked table and the
  ownership mask is applied in-register.  No (S, V, d) intermediate, no
  S-way elementwise mask, no reduction.
* ``fused_dequant_gather`` — the int8 variant: same grid, but the DMA'd
  row is an int8 code row plus its (1, 1) fp32 per-row scale, and the
  dequantize (``codes.astype(f32) · scale``) happens in-register — the
  fp32 table is never materialized (``repro.sharding.embedding``'s
  quantized layout).
* ``scatter_add_onehot`` — backward: the transpose scatter-add as tiled
  one-hot matmuls (the TPU substitute for atomic scatter, same pattern as
  ``rgcn_message.segment_sum_onehot``): for a (row tile, cotangent tile)
  pair build the 0/1 incidence tile and accumulate ``onehot @ g`` on the
  MXU, skipping tiles no cotangent row hits.

Both run under ``interpret=True`` on CPU and compile for TPU unchanged.
Oracles: ``repro.kernels.ref.sharded_gather_ref`` (the original
take→mask→sum chain) and ``ref.sharded_scatter_add_ref``.  The jit-ready
entry point with the custom VJP (and the XLA lowering used on non-TPU
backends, bit-equal by construction) is ``repro.kernels.ops.
fused_sharded_gather``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


ROW_BLOCK = 128   # table-row tile of the scatter-add kernel
COT_BLOCK = 128   # cotangent-row tile of the scatter-add kernel


# ====================================================================== #
# Forward: fused gather + mask (+ the accumulate folded into flat ids)
# ====================================================================== #
def _fused_gather_kernel(flat_ref, mask_ref, table_ref, out_ref):
    """One gathered row per grid step.  ``table_ref`` is the (1, d) row the
    scalar-prefetched flat index selected via the block index map; a row no
    shard owns (dedup-plan padding) is zeroed in-register — the fused
    remnant of the old exchange mask."""
    del flat_ref  # consumed by the index maps (scalar prefetch)
    out_ref[...] = jnp.where(mask_ref[...] != 0, table_ref[...], 0.0)


def fused_gather(
    table_flat: jax.Array,  # (R, d) stacked table, R = S * rows_per_shard
    flat_ids: jax.Array,    # (V,) int32 flat row index (owner-resolved)
    any_owned: jax.Array,   # (V,) bool/int — does ANY shard own this slot
    *, interpret: bool | None = None,
) -> jax.Array:
    """Fused gather+mask: ``out[v] = any_owned[v] ? table_flat[flat_ids[v]]
    : 0`` — the collapsed form of the shard-local take → mask → sum chain
    (``ref.sharded_gather_ref``), one row DMA per output row."""
    v = flat_ids.shape[0]
    d = table_flat.shape[1]
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(v,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, ids: (i, 0)),
            pl.BlockSpec((1, d), lambda i, ids: (ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, ids: (i, 0)),
    )
    return pl.pallas_call(
        _fused_gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((v, d), table_flat.dtype),
        interpret=interpret,
    )(flat_ids.astype(jnp.int32),
      any_owned.astype(jnp.int32).reshape(v, 1), table_flat)


# ====================================================================== #
# Forward (int8): fused dequantize + gather + mask
# ====================================================================== #
def _fused_dequant_gather_kernel(flat_ref, mask_ref, codes_ref, scale_ref,
                                 out_ref):
    """int8 twin of ``_fused_gather_kernel``: the scalar-prefetched flat
    index DMAs the owner's (1, d) int8 code row AND its (1, 1) fp32 scale;
    the row is dequantized in-register (``codes.astype(f32) · scale``) —
    the fp32 row never exists outside this tile."""
    del flat_ref  # consumed by the index maps (scalar prefetch)
    row = codes_ref[...].astype(jnp.float32) * scale_ref[...]
    out_ref[...] = jnp.where(mask_ref[...] != 0, row, 0.0)


def fused_dequant_gather(
    codes_flat: jax.Array,   # (R, d) int8 stacked row codes
    scales_flat: jax.Array,  # (R,) fp32 per-row scales
    flat_ids: jax.Array,     # (V,) int32 flat row index (owner-resolved)
    any_owned: jax.Array,    # (V,) bool/int — does ANY shard own this slot
    *, interpret: bool | None = None,
) -> jax.Array:
    """Fused dequantizing gather: ``out[v] = any_owned[v] ?
    codes_flat[flat_ids[v]].astype(f32) · scales_flat[flat_ids[v]] : 0``.
    Same grid/DMA structure as :func:`fused_gather` with one extra (1, 1)
    scale operand riding the same index map; output is fp32.  Oracle:
    ``ref.dequant_gather_ref``."""
    v = flat_ids.shape[0]
    r, d = codes_flat.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(v,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, ids: (i, 0)),
            pl.BlockSpec((1, d), lambda i, ids: (ids[i], 0)),
            pl.BlockSpec((1, 1), lambda i, ids: (ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, ids: (i, 0)),
    )
    return pl.pallas_call(
        _fused_dequant_gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((v, d), jnp.float32),
        interpret=interpret,
    )(flat_ids.astype(jnp.int32),
      any_owned.astype(jnp.int32).reshape(v, 1), codes_flat,
      scales_flat.astype(jnp.float32).reshape(r, 1))


# ====================================================================== #
# Backward: fused scatter-add of the gather cotangents
# ====================================================================== #
def _scatter_add_kernel(flat_ref, g_ref, mask_ref, out_ref):
    """Grid (i over table-row tiles, j over cotangent tiles); j is the
    minor (fastest) dimension so each row tile accumulates across all
    cotangent tiles before the grid moves on (same accumulation contract
    as ``rgcn_message._segment_sum_kernel``)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    flat = flat_ref[...][:, 0]                     # (COT_BLOCK,)
    mask = mask_ref[...][:, 0]                     # (COT_BLOCK,)
    local = flat - pl.program_id(0) * ROW_BLOCK
    hit = jnp.any((local >= 0) & (local < ROW_BLOCK) & (mask > 0))

    @pl.when(hit)
    def _accum():
        rows = jax.lax.broadcasted_iota(
            jnp.int32, (ROW_BLOCK, local.shape[0]), 0)
        onehot = jnp.where(
            (rows == local[None, :]) & (mask[None, :] > 0), 1.0, 0.0
        ).astype(jnp.float32)                      # (ROW_BLOCK, COT_BLOCK)
        out_ref[...] += jax.lax.dot_general(
            onehot, g_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)


def scatter_add_onehot(
    g: jax.Array,          # (V, d) gather cotangents
    flat_ids: jax.Array,   # (V,) int32 flat destination rows
    any_owned: jax.Array,  # (V,) bool/int — unowned slots contribute 0
    num_rows: int,         # R = S * rows_per_shard (padded table rows)
    *, interpret: bool | None = None,
) -> jax.Array:
    """Scatter-free transpose of :func:`fused_gather`:
    ``out[r] = Σ_v (flat_ids[v] == r ∧ any_owned[v]) · g[v]`` via MXU
    one-hot matmuls.  V and ``num_rows`` must be tile multiples (the ops
    wrapper pads; padded cotangent rows carry ``any_owned=False``)."""
    v, d = g.shape
    assert v % COT_BLOCK == 0 and num_rows % ROW_BLOCK == 0, \
        "pad V/num_rows to tile multiples (ops wrapper)"
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return pl.pallas_call(
        _scatter_add_kernel,
        grid=(num_rows // ROW_BLOCK, v // COT_BLOCK),
        in_specs=[
            pl.BlockSpec((COT_BLOCK, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((COT_BLOCK, d), lambda i, j: (j, 0)),
            pl.BlockSpec((COT_BLOCK, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_rows, d), jnp.float32),
        interpret=interpret,
    )(flat_ids.astype(jnp.int32)[:, None], g,
      any_owned.astype(jnp.int32)[:, None])
