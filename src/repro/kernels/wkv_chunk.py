"""Pallas TPU kernel for chunked RWKV-6 WKV (DESIGN.md §6, §8).

The §Perf pass made chunked WKV the dominant RWKV form (330× memory-term win
over the sequential scan); this kernel is its TPU-native realization: the
recurrent state lives in VMEM scratch across the sequential chunk dimension
of the grid — zero HBM state traffic between chunks — and all intra-chunk
work is MXU matmuls.

Math per chunk (exclusive cumulated log-decay L, bonus u):

    out  = tril_strict((r·e^L)(k·e^{-L-logw})^T) v
           + diag(Σ_d r·u·k) v + (r·e^L) S_in
    S'   = e^{L_tot} ⊙ S_in + (k·e^{L_tot-L-logw})^T v

Grid: (num_bh_tiles, num_chunks) with chunks minor (sequential) so the
scratch state persists across a tile's chunks.  Oracle:
``ref.wkv_chunk_ref`` (== the sequential recurrence, tested both ways).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BH_BLOCK = 8        # batch·head rows per tile (sublane-aligned)


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, out_ref, state_ref):
    """One (BH_BLOCK, chunk, hd) tile; state scratch (BH_BLOCK, hd, hd)."""
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[...].astype(jnp.float32)          # (B, K, hd)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)          # (B, hd)
    kdim = r.shape[1]

    l_exc = jnp.cumsum(lw, axis=1) - lw         # (B, K, hd)
    l_inc = l_exc + lw
    l_tot = l_inc[:, -1:, :]                    # (B, 1, hd)

    r_t = r * jnp.exp(l_exc)
    k_t = k * jnp.exp(-l_inc)
    scores = jax.lax.dot_general(               # (B, K, K)
        r_t, k_t, dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (kdim, kdim), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (kdim, kdim), 1)
    scores = jnp.where((cols < rows)[None], scores, 0.0)
    intra = jax.lax.dot_general(                # (B, K, hd)
        scores, v, dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    bonus = jnp.sum(r * u[:, None, :] * k, axis=-1, keepdims=True)
    state = state_ref[...]
    cross = jax.lax.dot_general(                # (B, K, hd_v)
        r_t, state, dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    out_ref[...] = (intra + bonus * v + cross).astype(out_ref.dtype)

    k_out = k * jnp.exp(l_tot - l_inc)          # (B, K, hd)
    delta = jax.lax.dot_general(                # (B, hd, hd_v)
        k_out, v, dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(l_tot[:, 0])[..., None] * state + delta


def wkv_chunked(r: jax.Array, k: jax.Array, v: jax.Array,
                log_decay: jax.Array, u: jax.Array, *,
                chunk: int = 64, interpret: bool | None = None
                ) -> jax.Array:
    """Chunked WKV over (BH, S, hd) inputs; u (BH, hd).  BH must be a
    multiple of BH_BLOCK and S of ``chunk`` (ops wrapper pads)."""
    bh, s, hd = r.shape
    assert bh % BH_BLOCK == 0 and s % chunk == 0, (bh, s)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    grid = (bh // BH_BLOCK, s // chunk)
    seq_spec = pl.BlockSpec((BH_BLOCK, chunk, hd), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        _wkv_kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((BH_BLOCK, hd), lambda i, j: (i, 0))],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), r.dtype),
        scratch_shapes=[pltpu_scratch((BH_BLOCK, hd, hd))],
        interpret=interpret,
    )(r, k, v, log_decay, u)


def pltpu_scratch(shape):
    """VMEM f32 scratch (portable across pallas versions)."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:   # pragma: no cover - older API
        return pl.VMEM(shape, jnp.float32)
