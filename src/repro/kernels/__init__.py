"""Pallas TPU kernels for the paper's compute hot spots:

* ``rgcn_message`` — fused basis-decomposed relational message passing
  (gather → basis projection+mix → MXU one-hot segment sum).
* ``kge_score`` — blocked candidate ranking in the canonical decoder query
  form ``epilogue(q @ C'^T + q_bias + c_bias) + mask`` — one kernel carries
  every registered decoder (``repro.models.decoders``).
* ``sharded_gather`` — fused flat-index gather / one-hot scatter-add for
  the row-sharded entity table exchange.
* ``topk`` — deterministic per-shard top-k selection (serving: reduce each
  shard's score block to ``(B, k)`` so the dense ``(B, N)`` matrix never
  materializes; ties break toward the lowest index, matching
  ``jax.lax.top_k``).
* ``wkv_chunk`` — chunked RWKV-6 WKV with VMEM-resident recurrent state
  (the §Perf-winning formulation, TPU-native).

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles.
On CPU the kernels run with ``interpret=True``; on TPU they compile.
"""
from repro.kernels import ops, ref
from repro.kernels.kge_score import EPILOGUES, NORM_EPS, apply_epilogue
from repro.kernels.ops import (
    kge_score_padded, merge_topk, rgcn_message_basis, topk_padded,
    wkv_chunked_op,
)

__all__ = ["ops", "ref", "EPILOGUES", "NORM_EPS", "apply_epilogue",
           "kge_score_padded", "merge_topk", "rgcn_message_basis",
           "topk_padded", "wkv_chunked_op"]
