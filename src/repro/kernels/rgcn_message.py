"""Pallas TPU kernels for the RGCN hot spot (DESIGN.md §6).

GPU frameworks implement relational message passing as per-edge
gather→GEMM→atomic-scatter.  TPUs have no atomic scatter and favor large
MXU matmuls from VMEM, so the op is re-thought as two tiled kernels:

1. ``basis_message`` — per-edge basis projection + coefficient mix, edges
   tiled in MXU-aligned blocks of 128.  Fuses the ``B`` basis projections
   with the coefficient mix in VMEM, never materializing the (E, B, d_out)
   intermediate that the XLA einsum path writes to HBM.

2. ``segment_sum_onehot`` — scatter-free segment sum: for an output vertex
   tile and an edge tile, build the 0/1 incidence tile
   ``onehot[v, e] = (src_e == v)`` with iota-compare and accumulate
   ``onehot @ msg`` on the MXU.  This trades FLOPs (V_blk per edge) for the
   systolic array's throughput — the standard TPU substitute for atomic
   scatter.
   Edges pre-sorted by head vertex make the incidence tile block-diagonal so
   most (i, j) grid cells see an all-zero tile; a cheap in-kernel range test
   skips their compute (``pl.when``).

Both kernels run under ``interpret=True`` on CPU (this container) and compile
for TPU unchanged.  Oracles: ``repro.kernels.ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


EDGE_BLOCK = 128     # MXU-aligned edge tile
VERTEX_BLOCK = 128   # output vertex tile


# ====================================================================== #
# Kernel 1: basis message
# ====================================================================== #
def _basis_message_kernel(h_t_ref, coef_ref, mask_ref, bases_ref, out_ref):
    """One edge tile: out = mask * sum_b coef[:, b] * (h_t @ bases[b]).

    Block shapes:
      h_t_ref  (E_blk, d_in)   coef_ref (E_blk, B)   mask_ref (E_blk, 1)
      bases_ref (B, d_in, d_out)  — replicated to every tile (fits VMEM:
      B·d_in·d_out ≤ 2·256·256·4B = 512 KiB at our sizes)
      out_ref  (E_blk, d_out)
    """
    h_t = h_t_ref[...]
    num_bases = bases_ref.shape[0]
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for b in range(num_bases):  # static unroll: B is small (paper uses 2)
        proj = jax.lax.dot_general(
            h_t, bases_ref[b],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc = acc + coef_ref[:, b][:, None].astype(jnp.float32) * proj
    out_ref[...] = (acc * mask_ref[...].astype(jnp.float32)).astype(
        out_ref.dtype)


def basis_message(
    h_t: jax.Array,       # (E, d_in)
    coef: jax.Array,      # (E, B)
    bases: jax.Array,     # (B, d_in, d_out)
    edge_mask: jax.Array,  # (E,)
    *, interpret: bool | None = None,
) -> jax.Array:
    """Tiled fused basis message computation.  E must be a multiple of
    EDGE_BLOCK (the ops wrapper pads)."""
    e, d_in = h_t.shape
    num_bases, _, d_out = bases.shape
    assert e % EDGE_BLOCK == 0, "pad edges to EDGE_BLOCK"
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    grid = (e // EDGE_BLOCK,)
    mask2d = edge_mask.astype(jnp.float32)[:, None]
    return pl.pallas_call(
        _basis_message_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((EDGE_BLOCK, d_in), lambda j: (j, 0)),
            pl.BlockSpec((EDGE_BLOCK, num_bases), lambda j: (j, 0)),
            pl.BlockSpec((EDGE_BLOCK, 1), lambda j: (j, 0)),
            pl.BlockSpec((num_bases, d_in, d_out), lambda j: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((EDGE_BLOCK, d_out), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((e, d_out), h_t.dtype),
        interpret=interpret,
    )(h_t, coef, mask2d, bases)


# ====================================================================== #
# Kernel 2: one-hot segment sum (+ degree counts)
# ====================================================================== #
def _segment_sum_kernel(msg_ref, seg_ref, mask_ref, out_ref, deg_ref,
                        *, num_v_blocks: int):
    """Grid (i over vertex tiles, j over edge tiles); j is the minor
    (fastest) dimension so each output tile accumulates across all edge
    tiles before the grid moves to the next vertex tile."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        deg_ref[...] = jnp.zeros_like(deg_ref)

    seg = seg_ref[...][:, 0]                      # (E_blk,)
    mask = mask_ref[...][:, 0]                    # (E_blk,)
    v_lo = i * VERTEX_BLOCK
    local = seg - v_lo                            # (E_blk,)
    # Skip tiles whose edges can't touch this vertex tile (edges sorted by
    # head make hits block-diagonal; unsorted inputs just skip the skip).
    hit = jnp.any((local >= 0) & (local < VERTEX_BLOCK) & (mask > 0))

    @pl.when(hit)
    def _accum():
        rows = jax.lax.broadcasted_iota(
            jnp.int32, (VERTEX_BLOCK, local.shape[0]), 0)
        onehot = jnp.where(
            (rows == local[None, :]) & (mask[None, :] > 0), 1.0, 0.0
        ).astype(jnp.float32)                      # (V_blk, E_blk)
        out_ref[...] += jax.lax.dot_general(
            onehot, msg_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)
        deg_ref[...] += jnp.sum(
            onehot, axis=1, keepdims=True).astype(deg_ref.dtype)


def segment_sum_onehot(
    msg: jax.Array,       # (E, d)
    seg: jax.Array,       # (E,) int32
    edge_mask: jax.Array,  # (E,)
    num_segments: int,
    *, interpret: bool | None = None,
):
    """Masked segment sum via MXU one-hot matmuls.
    Returns (agg (V, d), deg (V, 1)).  V padded to VERTEX_BLOCK by wrapper."""
    e, d = msg.shape
    assert e % EDGE_BLOCK == 0 and num_segments % VERTEX_BLOCK == 0
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nv = num_segments // VERTEX_BLOCK
    ne = e // EDGE_BLOCK
    seg2d = seg.astype(jnp.int32)[:, None]
    mask2d = edge_mask.astype(jnp.int32)[:, None]
    kernel = functools.partial(_segment_sum_kernel, num_v_blocks=nv)
    return pl.pallas_call(
        kernel,
        grid=(nv, ne),
        in_specs=[
            pl.BlockSpec((EDGE_BLOCK, d), lambda i, j: (j, 0)),
            pl.BlockSpec((EDGE_BLOCK, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((EDGE_BLOCK, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((VERTEX_BLOCK, d), lambda i, j: (i, 0)),
            pl.BlockSpec((VERTEX_BLOCK, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_segments, d), msg.dtype),
            jax.ShapeDtypeStruct((num_segments, 1), msg.dtype),
        ],
        interpret=interpret,
    )(msg, seg2d, mask2d)
