"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference here with an identical
signature; tests sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def basis_message_ref(
    h_t: jax.Array,        # (E, d_in)  gathered tail states
    coef: jax.Array,       # (E, B)     per-edge basis coefficients
    bases: jax.Array,      # (B, d_in, d_out)
    edge_mask: jax.Array,  # (E,) bool
) -> jax.Array:
    """m_e = mask_e * sum_b coef_eb (h_t_e @ V_b)  →  (E, d_out)."""
    proj = jnp.einsum("ed,bdo->ebo", h_t, bases)
    msg = jnp.einsum("ebo,eb->eo", proj, coef)
    return jnp.where(edge_mask[:, None], msg, 0.0)


def segment_mean_ref(
    msg: jax.Array,        # (E, d)
    seg: jax.Array,        # (E,) int32 destination segment (head vertex)
    edge_mask: jax.Array,  # (E,) bool
    num_segments: int,
) -> Tuple[jax.Array, jax.Array]:
    """Masked segment sum + counts → (agg (V, d), deg (V,))."""
    m = jnp.where(edge_mask[:, None], msg, 0.0)
    agg = jax.ops.segment_sum(m, seg, num_segments=num_segments)
    deg = jax.ops.segment_sum(edge_mask.astype(msg.dtype), seg,
                              num_segments=num_segments)
    return agg, deg


def rgcn_message_ref(
    h: jax.Array, src: jax.Array, rel: jax.Array, dst: jax.Array,
    edge_mask: jax.Array, bases: jax.Array, coeffs: jax.Array,
) -> jax.Array:
    """Full fused op oracle: gather → basis message → segment MEAN."""
    msg = basis_message_ref(h[dst], coeffs[rel], bases, edge_mask)
    agg, deg = segment_mean_ref(msg, src, edge_mask, h.shape[0])
    return agg / jnp.maximum(deg, 1.0)[:, None]


def kge_score_ref(
    q: jax.Array,           # (B, d) prepared query rows
    candidates: jax.Array,  # (C, d) prepared candidate rows
    bias: Optional[jax.Array] = None,    # (B, C) POST-epilogue mask
    q_bias: Optional[jax.Array] = None,  # (B,) pre-epilogue query bias
    c_bias: Optional[jax.Array] = None,  # (C,) pre-epilogue candidate bias
    epilogue: str = "bilinear",
) -> jax.Array:
    """Canonical query-form ranking block (``repro.models.decoders``):
    ``epilogue(q @ candidates^T + q_bias + c_bias) + bias``."""
    from repro.kernels.kge_score import apply_epilogue
    x = q @ candidates.T
    if q_bias is not None:
        x = x + q_bias[:, None]
    if c_bias is not None:
        x = x + c_bias[None, :]
    out = apply_epilogue(x, epilogue)
    if bias is not None:
        out = out + bias
    return out


def topk_ref(
    scores: jax.Array,      # (B, C) score block
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Deterministic top-k oracle for ``kernels.topk.topk_scores``: the
    identical iterative selection (max over active columns, lowest index
    wins ties, winner deactivated) in pure jnp.  Selection is
    arithmetic-free, so values AND indices are bit-equal to
    ``jax.lax.top_k`` on float32 scores — the dense serving reference."""
    scores = scores.astype(jnp.float32)
    b, c = scores.shape
    col = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (b, c))

    def step(active, _):
        cur = jnp.where(active, scores, -jnp.inf)
        m = jnp.max(cur, axis=1)
        hit = active & (cur == m[:, None])
        pick = jnp.min(jnp.where(hit, col, c), axis=1)
        return active & (col != pick[:, None]), (m, pick)

    _, (vals, idx) = jax.lax.scan(
        step, jnp.ones((b, c), jnp.bool_), None, length=k)
    return jnp.moveaxis(vals, 0, 1), jnp.moveaxis(idx, 0, 1)


def sharded_gather_ref(
    table: jax.Array,      # (S, rows, d) row-sharded table stack
    local_ids: jax.Array,  # (S, V) per-shard LOCAL row ids
    owned: jax.Array,      # (S, V) ownership masks
) -> jax.Array:
    """The original shard-local take → mask → sum exchange chain — the
    oracle for ``kernels.sharded_gather.fused_gather`` /
    ``ops.fused_sharded_gather``.  Exactly one shard owns each slot, so
    every output element is one real row plus zeros."""
    g = jax.vmap(lambda t, i: t[i])(table, local_ids)        # (S, V, d)
    return jnp.sum(jnp.where(owned[:, :, None], g, 0.0), axis=0)


def sharded_scatter_add_ref(
    g: jax.Array,          # (V, d) gather cotangents
    flat_ids: jax.Array,   # (V,) flat destination rows
    any_owned: jax.Array,  # (V,) mask — unowned slots contribute 0
    num_rows: int,
) -> jax.Array:
    """Transpose of the fused gather: masked scatter-add of the cotangents
    into the stacked table rows.  Oracle for
    ``kernels.sharded_gather.scatter_add_onehot``."""
    upd = jnp.where(any_owned[:, None], g, 0.0).astype(jnp.float32)
    return jnp.zeros((num_rows, g.shape[1]), jnp.float32).at[
        flat_ids].add(upd)


def quantize_rows_ref(table: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Independent oracle for ``repro.sharding.embedding.quantize_rows``.

    The contract: each row's scale is the SMALLEST power of two ``2^k``
    (k in [-149, 127], the full fp32 exponent range incl. subnormals)
    with ``127 · 2^k ≥ amax(row)``, or exactly 0.0 for an all-zero row;
    codes are ``rint(row / scale)`` clipped to ±127.  This oracle runs
    entirely in INTEGER arithmetic — k by explicit search over a
    host-built table of every fp32 power of two (compared via the raw
    bit patterns: for non-negative fp32 the bit ordering is the value
    ordering), and each code by exact shift-and-round-half-even of the
    element's integer mantissa — so it shares nothing with the
    implementation's float construction and is immune to XLA's
    flush-to-zero on subnormal float operands.  ``127 · 2^k`` is exact
    in fp32 (127 needs 7 mantissa bits; subnormal products are exact
    multiples of 2^-149), so the host-built thresholds are exact."""
    import numpy as np
    # thresholds 127·2^k for k = -149..127, exact in host numpy, compared
    # as integer bit patterns (k >= 122 overflows to +inf, which still
    # bit-compares above every finite amax — and the true k never exceeds
    # 122 because 127·2^122 already covers the largest finite fp32)
    with np.errstate(over="ignore"):
        thresh = jnp.asarray(
            (np.float32(127.0) * np.ldexp(np.float32(1.0),
                                          np.arange(-149, 128)))
            .astype(np.float32).view(np.int32))
    pows = jnp.asarray(np.ldexp(np.float32(1.0), np.arange(-149, 128)))
    table = table.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(table, jnp.int32)
    mag = bits & 0x7FFFFFFF
    amax_bits = jnp.max(mag, axis=-1)
    idx = jnp.argmax(thresh >= amax_bits[..., None], axis=-1)
    k = (idx - 149).astype(jnp.int32)
    scale = pows[idx]
    scale = jnp.where(amax_bits > 0, scale, jnp.float32(0.0))
    # integer mantissa/exponent of each element: |x| = M · 2^E
    e_f = mag >> 23
    m_f = mag & 0x7FFFFF
    big_m = jnp.where(e_f == 0, m_f, m_f | (1 << 23))
    big_e = jnp.where(e_f == 0, -149, e_f - 150)
    # code magnitude = rint(M · 2^(E - k)), |result| <= 127 by the scale
    # contract, so left shifts cap at 7 and right shifts at 25 (beyond
    # which the quotient is < 0.5 and rounds to zero)
    shift = big_e - k[..., None]
    left = big_m << jnp.clip(shift, 0, 7)
    t = jnp.clip(-shift, 1, 25)
    floor = big_m >> t
    rem = big_m & ((1 << t) - 1)
    half = 1 << (t - 1)
    round_up = (rem > half) | ((rem == half) & ((floor & 1) == 1))
    right = floor + round_up.astype(jnp.int32)
    code_mag = jnp.where(shift >= 0, left, right)
    codes = jnp.clip(jnp.where(bits < 0, -code_mag, code_mag),
                     -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_rows_ref(codes: jax.Array, scales: jax.Array) -> jax.Array:
    """``codes.astype(f32) · scale`` — exact (int8 ≤ 2^7 mantissa bits,
    scale a power of two)."""
    return codes.astype(jnp.float32) * scales[..., None]


def dequant_gather_ref(
    codes: jax.Array,      # (S, rows, d) int8 row codes
    scales: jax.Array,     # (S, rows) fp32 per-row scales
    local_ids: jax.Array,  # (S, V) per-shard LOCAL row ids
    owned: jax.Array,      # (S, V) ownership masks
) -> jax.Array:
    """Dequantize-THEN-gather: materialize the full fp32 stack and run the
    original exchange chain.  Oracle for
    ``kernels.sharded_gather.fused_dequant_gather`` /
    ``ops.dequant_sharded_gather``, which must match it bitwise on CPU —
    ``code · scale`` is the same f32 product either side of the gather."""
    return sharded_gather_ref(dequantize_rows_ref(codes, scales),
                              local_ids, owned)


def wkv_chunk_ref(
    r: jax.Array,          # (BH, S, hd)
    k: jax.Array,
    v: jax.Array,
    log_decay: jax.Array,  # (BH, S, hd), log w_t in (-inf, 0)
    u: jax.Array,          # (BH, hd) bonus
) -> jax.Array:
    """Sequential WKV recurrence (the RWKV-6 time-mix core):
    out_t = r_t · (S_{t-1} + diag(u) k_t^T v_t); S_t = diag(w_t) S_{t-1}
    + k_t^T v_t.  Oracle for kernels.wkv_chunk."""
    bh, s, hd = r.shape
    w = jnp.exp(log_decay.astype(jnp.float32))

    def step(state, xs):
        r_t, k_t, v_t, w_t = xs                  # (BH, hd)
        kv = k_t[..., :, None] * v_t[..., None, :]
        out = jnp.einsum("bk,bkv->bv", r_t.astype(jnp.float32),
                         state + u.astype(jnp.float32)[..., None] * kv)
        state = w_t[..., None] * state + kv
        return state, out

    init = jnp.zeros((bh, hd, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (r, k, v, w))
    _, outs = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype)
