"""Jit-ready wrappers around the Pallas kernels: padding to block multiples,
gathers that stay in XLA, and de-padding of results.

These are the entry points the model layer uses; on CPU they run the kernels
in interpret mode, on TPU they compile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.kge_score import C_BLOCK, Q_BLOCK, kge_score
from repro.kernels.rgcn_message import (
    EDGE_BLOCK, VERTEX_BLOCK, basis_message, segment_sum_onehot,
)
from repro.kernels.sharded_gather import (
    COT_BLOCK, ROW_BLOCK, fused_gather, scatter_add_onehot,
)
from repro.kernels.topk import TOPK_Q_BLOCK, topk_scores


def _pad_to(x: jax.Array, n: int, axis: int = 0, fill=0) -> jax.Array:
    cur = x.shape[axis]
    if cur == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n - cur)
    return jnp.pad(x, pad, constant_values=fill)


def _round_up(x: int, m: int) -> int:
    return max(m, ((x + m - 1) // m) * m)


@jax.custom_vjp
def rgcn_message_basis(
    h: jax.Array,          # (V, d_in) vertex states
    src: jax.Array,        # (E,) heads (segment ids)
    rel: jax.Array,        # (E,) relations
    dst: jax.Array,        # (E,) tails (gather ids)
    edge_mask: jax.Array,  # (E,) bool
    bases: jax.Array,      # (B, d_in, d_out)
    coeffs: jax.Array,     # (R, B)
) -> jax.Array:
    """Fused RGCN message layer: gather → basis message kernel →
    one-hot segment-sum kernel → mean normalize.  Matches
    ``ref.rgcn_message_ref`` / ``models.rgcn.message_passing_ref``.

    Differentiable: forward runs the Pallas kernels; backward runs the VJP of
    the mathematically identical reference formulation (the usual pairing —
    the backward's gather/scatter pattern differs from the forward's and is
    left to XLA until profiled as a bottleneck)."""
    return _rgcn_message_basis_fwd_impl(
        h, src, rel, dst, edge_mask, bases, coeffs)


def _rgcn_message_basis_fwd_impl(
    h, src, rel, dst, edge_mask, bases, coeffs,
    interpret: Optional[bool] = None,
) -> jax.Array:
    v, d_in = h.shape
    e = src.shape[0]
    d_out = bases.shape[-1]

    e_pad = _round_up(e, EDGE_BLOCK)
    v_pad = _round_up(v, VERTEX_BLOCK)

    dst_p = _pad_to(dst.astype(jnp.int32), e_pad)
    rel_p = _pad_to(rel.astype(jnp.int32), e_pad)
    src_p = _pad_to(src.astype(jnp.int32), e_pad)
    mask_p = _pad_to(edge_mask.astype(jnp.bool_), e_pad, fill=False)

    h_t = h[dst_p]                     # (E_pad, d_in) XLA gather
    coef = coeffs[rel_p]               # (E_pad, B)
    msg = basis_message(h_t, coef, bases, mask_p, interpret=interpret)
    agg, deg = segment_sum_onehot(
        msg, src_p, mask_p, v_pad, interpret=interpret)
    out = agg[:v] / jnp.maximum(deg[:v], 1.0)
    return out.astype(h.dtype)


def _rgcn_fwd(h, src, rel, dst, edge_mask, bases, coeffs):
    out = _rgcn_message_basis_fwd_impl(
        h, src, rel, dst, edge_mask, bases, coeffs)
    return out, (h, src, rel, dst, edge_mask, bases, coeffs)


def _rgcn_bwd(res, g):
    from repro.kernels import ref
    h, src, rel, dst, edge_mask, bases, coeffs = res
    _, vjp = jax.vjp(
        lambda h_, bases_, coeffs_: ref.rgcn_message_ref(
            h_, src, rel, dst, edge_mask, bases_, coeffs_),
        h, bases, coeffs)
    dh, dbases, dcoeffs = vjp(g)
    return dh, None, None, None, None, dbases, dcoeffs


rgcn_message_basis.defvjp(_rgcn_fwd, _rgcn_bwd)


@functools.partial(jax.jit, static_argnames=("epilogue", "interpret"))
def kge_score_padded(
    q: jax.Array,           # (B, d) prepared query rows
    candidates: jax.Array,  # (C, d) prepared candidate rows
    bias: Optional[jax.Array] = None,   # (B, C) POST-epilogue mask
    q_bias: Optional[jax.Array] = None,  # (B,) pre-epilogue query bias
    c_bias: Optional[jax.Array] = None,  # (C,) pre-epilogue candidate bias
    *, epilogue: str = "bilinear",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Block-padding wrapper around the Pallas ``kge_score`` kernel.

    Takes the canonical decoder query form (``repro.models.decoders``):
    ``epilogue(q @ candidates^T + q_bias + c_bias) + bias``.  ``kge_score``
    asserts B and C are multiples of its 128-row tiles; this wrapper pads
    ragged shapes (the last test batch, a shard's row block) up to the tiles
    and slices the result back to ``(B, C)``.  Pad *candidate* rows get
    post-epilogue bias ``-inf``, so any padded score is ``-inf`` and can
    never outrank (or tie) a real candidate — rank counting over a padded
    score matrix stays exact.  Matches ``kernels.ref.kge_score_ref`` on the
    real rows.
    """
    b, d = q.shape
    c = candidates.shape[0]
    b_pad = _round_up(b, Q_BLOCK)
    c_pad = _round_up(c, C_BLOCK)

    q_p = _pad_to(q, b_pad)
    cand_p = _pad_to(candidates, c_pad)
    if bias is None:
        bias = jnp.zeros((b, c), q.dtype)
    bias_p = _pad_to(_pad_to(bias, b_pad, axis=0), c_pad, axis=1,
                     fill=-jnp.inf)
    qb = jnp.zeros((b,), jnp.float32) if q_bias is None else q_bias
    cb = jnp.zeros((c,), jnp.float32) if c_bias is None else c_bias
    qb_p = _pad_to(qb.astype(jnp.float32), b_pad).reshape(b_pad, 1)
    cb_p = _pad_to(cb.astype(jnp.float32), c_pad).reshape(1, c_pad)
    out = kge_score(q_p, cand_p, bias_p, qb_p, cb_p, epilogue=epilogue,
                    interpret=interpret)
    return out[:b, :c]


# ---------------------------------------------------------------------- #
# Per-shard top-k + merge (repro.serving hot path)
# ---------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("k", "interpret", "use_kernel"))
def topk_padded(
    scores: jax.Array,      # (B, C) score block
    k: int,
    *, interpret: Optional[bool] = None,
    use_kernel: Optional[bool] = None,
):
    """Padding/dispatch wrapper around the Pallas ``topk_scores`` kernel:
    ``(values (B, k), indices (B, k))``, values descending, ties broken
    toward the LOWEST index.  ``k`` must already be clamped to ``[1, C]``
    (the serving layer owns the vocabulary clamp so the request-level
    semantics live in one place); ragged B is padded to the kernel's
    128-row tile and sliced back.

    On TPU the Pallas kernel runs; elsewhere the production path is
    ``jax.lax.top_k`` — the same documented selection order (descending,
    lower index wins ties), with no arithmetic that could drift, so the
    two dispatches are bit-identical (``tests/test_serving.py`` asserts
    kernel == ref == ``lax.top_k``)."""
    b, c = scores.shape
    if not 1 <= k <= c:
        raise ValueError(f"k={k} outside [1, C={c}] — clamp before topk")
    scores = scores.astype(jnp.float32)
    if use_kernel is None:
        # mirror fused_sharded_gather: the kernel's iterative selection is
        # VPU-friendly on TPU; on CPU the interpreter per-grid overhead
        # loses to XLA's native sort-based TopK, which implements the
        # identical order
        use_kernel = jax.default_backend() == "tpu"
    if not use_kernel:
        return jax.lax.top_k(scores, k)
    b_pad = _round_up(b, TOPK_Q_BLOCK)
    vals, idx = topk_scores(_pad_to(scores, b_pad), k, interpret=interpret)
    return vals[:b], idx[:b]


def merge_topk(
    vals: jax.Array,       # (B, S * k) per-shard top-k values, concat
    ids: jax.Array,        # (B, S * k) matching GLOBAL candidate ids
    k: int,
    *, interpret: Optional[bool] = None,
):
    """Global k-way merge of per-shard top-k winners: top-k over the
    concatenated ``(B, S·k)`` value rows, then the winning positions are
    mapped back to their global candidate ids.

    Exactness: each shard's list is (value desc, local index asc) and the
    shard row blocks cover contiguous ascending global-id ranges, so among
    equal values a lower concat POSITION is always a lower global id —
    the lowest-index tie-break of ``topk_padded`` therefore selects and
    orders exactly the candidates dense ``jax.lax.top_k`` would over the
    full axis."""
    v, pos = topk_padded(vals, k, interpret=interpret)
    return v, jnp.take_along_axis(ids, pos, axis=1)


# ---------------------------------------------------------------------- #
# Fused sharded-table gather (repro.sharding.embedding hot path)
# ---------------------------------------------------------------------- #
def flat_gather_plan(local_ids: jax.Array, owned: jax.Array,
                     rows_per_shard: int):
    """Collapse a per-shard gather plan into flat row indices.

    ``(local_ids, owned)`` are the ``(S, V)`` plan of
    ``repro.sharding.embedding.plan_local_gather``; exactly one shard owns
    each valid slot, so the exchange's mask+accumulate reduces to integer
    bookkeeping: ``flat[v] = Σ_s owned[s,v] ? s·rows + local[s,v] : 0`` —
    which is the slot's GLOBAL row id in the stacked ``(S·rows, d)`` table
    — plus ``any_owned[v]`` marking slots no shard owns (dedup-plan
    padding), which must gather exact zeros."""
    s = local_ids.shape[0]
    offsets = (jnp.arange(s, dtype=jnp.int32) * rows_per_shard
               ).reshape((s,) + (1,) * (local_ids.ndim - 1))
    flat = jnp.sum(jnp.where(owned, local_ids.astype(jnp.int32) + offsets,
                             0), axis=0)
    return flat, jnp.any(owned, axis=0)


def _fused_sharded_gather_impl(table, local_ids, owned,
                               interpret: Optional[bool] = None,
                               use_kernel: Optional[bool] = None):
    s, rows, d = table.shape
    flat, any_owned = flat_gather_plan(local_ids, owned, rows)
    table_flat = table.reshape(s * rows, d)
    if use_kernel is None:
        # the per-row-DMA kernel wins on TPU; on CPU the interpreter's
        # per-grid-step overhead would swamp the gather, so the production
        # path is the IDENTICAL XLA lowering (one masked row gather —
        # tests/test_kernels.py asserts kernel == XLA bit-for-bit)
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        return fused_gather(table_flat, flat, any_owned,
                            interpret=interpret)
    return jnp.where(any_owned[:, None], table_flat[flat], 0.0)


@jax.custom_vjp
def fused_sharded_gather(
    table: jax.Array,      # (S, rows, d) row-sharded table stack
    local_ids: jax.Array,  # (S, V) per-shard LOCAL row ids
    owned: jax.Array,      # (S, V) ownership masks
) -> jax.Array:
    """Fused replacement for the shard-local take → mask → sum chain
    (``ref.sharded_gather_ref``): the ownership masks fold into flat row
    indices (``flat_gather_plan``) and the whole exchange becomes ONE
    masked row gather — V·d elements touched instead of S·V·d, no
    (S, V, d) intermediate.  Bitwise equal to the chain (each output
    element is the owner's row value; the chain adds S−1 zeros to it).

    Differentiable with a fused backward: the custom VJP scatter-adds the
    cotangents straight into the stacked table rows — the SAME single
    scatter-add a dense ``table[ids]`` gather's VJP performs (so sharded
    gradients stay bitwise equal to dense ones) instead of
    differentiating through the S-way mask/sum chain.  On TPU the
    forward runs the ``sharded_gather.fused_gather`` Pallas kernel and
    the backward the ``scatter_add_onehot`` MXU one-hot kernel."""
    return _fused_sharded_gather_impl(table, local_ids, owned)


def _fsg_fwd(table, local_ids, owned):
    out = _fused_sharded_gather_impl(table, local_ids, owned)
    # bwd needs only table's STATIC shape/dtype; the array residual is a
    # free edge to the parameter under jit (no extra buffer)
    return out, (local_ids, owned, table)


def _fsg_bwd(res, g):
    from repro.kernels import ref
    local_ids, owned, table = res
    s, rows, d = table.shape
    dtype = table.dtype
    flat, any_owned = flat_gather_plan(local_ids, owned, rows)
    if jax.default_backend() == "tpu":
        v = flat.shape[0]
        v_pad = _round_up(v, COT_BLOCK)
        r_pad = _round_up(s * rows, ROW_BLOCK)
        dt = scatter_add_onehot(
            _pad_to(g, v_pad), _pad_to(flat, v_pad),
            _pad_to(any_owned, v_pad, fill=False), r_pad)[:s * rows]
    else:
        dt = ref.sharded_scatter_add_ref(g, flat, any_owned, s * rows)
    return dt.reshape(s, rows, d).astype(dtype), None, None


fused_sharded_gather.defvjp(_fsg_fwd, _fsg_bwd)

# Public alias: the quantized gathers reuse the SAME straight-through
# backward (scatter-add of the output cotangents into the fp32 master
# table), so master-weight gradients stay bitwise equal to the fp32
# path's gradients on identical dequantized inputs.
fsg_bwd = _fsg_bwd


# ---------------------------------------------------------------------- #
# Quantized (int8) sharded gather — fused dequant variants
# ---------------------------------------------------------------------- #
def dequant_sharded_gather(
    codes: jax.Array,       # (S, rows, d) int8 row codes
    scales: jax.Array,      # (S, rows) fp32 per-row scales
    local_ids: jax.Array,   # (S, V) per-shard LOCAL row ids
    owned: jax.Array,       # (S, V) ownership masks
    interpret: Optional[bool] = None,
    use_kernel: Optional[bool] = None,
) -> jax.Array:
    """Fused dequantizing gather over int8 row codes:
    ``out[v] = any_owned[v] ? codes_flat[flat[v]].astype(f32) ·
    scales_flat[flat[v]] : 0`` — the int8 twin of
    :func:`fused_sharded_gather`'s forward.  Only the V gathered rows are
    ever dequantized; no fp32 ``(S·rows, d)`` table exists at any point
    (the replication audit asserts this on the compiled HLO).  On TPU the
    ``sharded_gather.fused_dequant_gather`` Pallas kernel runs; elsewhere
    the identical XLA lowering.  Oracle: ``ref.dequant_gather_ref``
    (dequantize-then-gather), bitwise equal because ``code · scale`` is
    computed in f32 either side of the gather."""
    s, rows, d = codes.shape
    flat, any_owned = flat_gather_plan(local_ids, owned, rows)
    codes_flat = codes.reshape(s * rows, d)
    scales_flat = scales.reshape(s * rows)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels.sharded_gather import fused_dequant_gather
        return fused_dequant_gather(codes_flat, scales_flat, flat,
                                    any_owned, interpret=interpret)
    rows_f32 = (codes_flat[flat].astype(jnp.float32)
                * scales_flat[flat][:, None])
    return jnp.where(any_owned[:, None], rows_f32, 0.0)


def _qsg_impl(table, local_ids, owned):
    # function-level import: sharding.embedding imports this module
    from repro.sharding.embedding import quantize_rows
    codes, scales = quantize_rows(table)
    return dequant_sharded_gather(codes, scales, local_ids, owned)


@jax.custom_vjp
def quantized_sharded_gather(
    table: jax.Array,      # (S, rows, d) fp32 MASTER table stack
    local_ids: jax.Array,  # (S, V) per-shard LOCAL row ids
    owned: jax.Array,      # (S, V) ownership masks
) -> jax.Array:
    """int8 training gather: quantize the fp32 master table row-wise
    in-program, then run the fused dequantizing gather — the optimizer
    only ever sees the fp32 master.  Straight-through custom VJP: the
    backward is :func:`fused_sharded_gather`'s scatter-add (``fsg_bwd``),
    accumulating fp32 cotangents into the master rows, NOT the
    zero-almost-everywhere derivative of round().  Consequence tested in
    tests/test_sharded_embedding.py: master gradients are bitwise equal
    to fp32-path gradients when the fp32 path runs on the dequantized
    master."""
    return _qsg_impl(table, local_ids, owned)


def _qsg_fwd(table, local_ids, owned):
    return _qsg_impl(table, local_ids, owned), (local_ids, owned, table)


quantized_sharded_gather.defvjp(_qsg_fwd, _fsg_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def wkv_chunked_op(
    r: jax.Array, k: jax.Array, v: jax.Array, log_decay: jax.Array,
    u: jax.Array, chunk: int = 64,
) -> jax.Array:
    """Padded wrapper for the chunked-WKV Pallas kernel: (BH, S, hd) →
    (BH, S, hd); pads BH to BH_BLOCK and S to the chunk size.
    Differentiable: forward = Pallas kernel, backward = VJP of the
    mathematically identical sequential reference (same pairing as
    ``rgcn_message_basis``)."""
    return _wkv_fwd_impl(r, k, v, log_decay, u, chunk)


def _wkv_fwd_impl(r, k, v, log_decay, u, chunk,
                  interpret: Optional[bool] = None) -> jax.Array:
    from repro.kernels.wkv_chunk import BH_BLOCK, wkv_chunked
    bh, s, hd = r.shape
    bh_p = _round_up(bh, BH_BLOCK)
    s_p = _round_up(s, chunk)

    def pad(x, fill=0.0):
        return _pad_to(_pad_to(x, bh_p, axis=0, fill=fill), s_p, axis=1,
                       fill=fill)

    out = wkv_chunked(
        pad(r), pad(k), pad(v), pad(log_decay),
        _pad_to(u, bh_p, axis=0), chunk=chunk, interpret=interpret)
    return out[:bh, :s]


def _wkv_fwd(r, k, v, log_decay, u, chunk):
    # custom_vjp fwd receives args in primal order; nondiff args go FIRST
    # only in the bwd rule
    return (_wkv_fwd_impl(r, k, v, log_decay, u, chunk),
            (r, k, v, log_decay, u))


def _wkv_bwd(chunk, res, g):
    from repro.kernels import ref
    r, k, v, log_decay, u = res
    _, vjp = jax.vjp(
        lambda *a: ref.wkv_chunk_ref(*a), r, k, v, log_decay, u)
    return vjp(g)


wkv_chunked_op.defvjp(_wkv_fwd, _wkv_bwd)
