"""Link-prediction evaluation (filtered MRR / Hits@k): vectorized CSR
filter index, dense blocked ranking, and the candidate-axis-sharded path
over the row-sharded entity table (``repro.eval.sharded``)."""
from repro.eval.ranking import (
    CSRFilterIndex, FILTER_BIAS, build_filter_index, evaluate_both_directions,
    mean_rank, metrics_from_ranks, ranking_metrics,
)
from repro.eval.sharded import (
    make_sharded_rank_step, shard_filter_bias_block,
    sharded_candidate_rank_counts, sharded_rank_counts,
    sharded_ranking_metrics,
)
__all__ = ["CSRFilterIndex", "FILTER_BIAS", "build_filter_index",
           "ranking_metrics", "evaluate_both_directions", "mean_rank",
           "metrics_from_ranks", "make_sharded_rank_step",
           "shard_filter_bias_block", "sharded_candidate_rank_counts",
           "sharded_rank_counts", "sharded_ranking_metrics"]
