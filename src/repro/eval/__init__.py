"""Link-prediction evaluation (filtered MRR / Hits@k)."""
from repro.eval.ranking import (
    build_filter_index, ranking_metrics, evaluate_both_directions,
)
__all__ = ["build_filter_index", "ranking_metrics",
           "evaluate_both_directions"]
