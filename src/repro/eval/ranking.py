"""Filtered link-prediction evaluation — paper §4.2 (Eq. 5, 6).

For each test triplet the candidate set is every entity (FB15k-237 protocol)
or a provided candidate list (ogbl-citation2 ships 1000 negatives per edge);
candidates that form a KNOWN positive (train/valid/test) are filtered out.
Both corruption directions are evaluated — tail corruption on (s, r, t) and,
through the inverse relation, head corruption.

Scoring runs through the Pallas ranking kernel
(``repro.kernels.kge_score`` via its block-padding wrapper) in candidate
blocks; with ``num_shards > 1`` ranking is candidate-axis-sharded over the
row-sharded entity table (``repro.eval.sharded``).

Filter index
------------
The filter is stored as a ``CSRFilterIndex``: known (s, r) pairs as a sorted
int64 key array plus a CSR ``indptr`` into one flat ``tails`` array.  Both
the build (one lexsort over all split triplets) and the per-batch bias
construction (searchsorted + one fancy-index scatter) are vectorized numpy —
no per-triplet Python loop.  ``bias`` also has a COLUMN-RANGE form
(``col_start``/``num_cols``) that builds one block of the bias straight
from CSR, which is how the sharded ranking path gets per-shard bias blocks
without ever materializing the dense ``(B, N)`` matrix.
``build_filter_index`` keeps the dict-of-sets reference implementation the
CSR index is property-tested against (it is NOT a production path).

Rank convention
---------------
Ties are scored with the standard mean ("realistic") rank:
``rank = 1 + #{score > true} + 0.5 * #{score == true, candidate != true}``.
A strict ``scores > true`` count alone would give candidates tying the true
score rank 1 — optimistically biased for embeddings with exact ties
(duplicate entities, saturated scores).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import KnowledgeGraph
from repro.kernels.kge_score import apply_epilogue
from repro.models.decoders import Decoder, get_decoder

# Additive score mask for filtered-out candidates.  Large-negative rather
# than -inf so a filtered candidate still loses cleanly without generating
# inf-inf NaNs anywhere downstream; pad rows (never real candidates) do use
# -inf (see kernels.ops.kge_score_padded / eval.sharded).
FILTER_BIAS = -1e9


def build_filter_index(graphs: Iterable[KnowledgeGraph]) -> Dict:
    """(s, r) -> set of known-true tails, over all splits.

    Reference implementation (per-triplet Python loop).  Production code
    uses ``CSRFilterIndex.build`` — bit-identical filtered metrics, built
    and applied with vectorized numpy; this dict form remains the oracle
    the CSR index is property-tested against and the benchmark baseline.
    """
    idx: Dict = {}
    for g in graphs:
        for s, r, t in g.triplets():
            idx.setdefault((int(s), int(r)), set()).add(int(t))
    return idx


@dataclasses.dataclass(frozen=True)
class CSRFilterIndex:
    """Vectorized ``(s, r) → known tails`` filter index in CSR form.

    ``keys`` holds every known (s, r) pair encoded as ``s * num_relations
    + r`` (int64, sorted, unique); ``tails[indptr[k]:indptr[k+1]]`` are the
    known-true tails of ``keys[k]`` (deduplicated).  Lookup for a whole test
    batch is one ``searchsorted`` over ``keys``; the (B, N) filter bias is
    one fancy-index scatter — no per-triplet Python loop (contrast
    ``build_filter_index``).
    """

    keys: np.ndarray        # (K,) int64, sorted unique s * num_relations + r
    indptr: np.ndarray      # (K + 1,) int64
    tails: np.ndarray       # (nnz,) int32, grouped by key
    num_relations: int      # key encoding stride (covers inverse relations)

    @classmethod
    def build(cls, graphs: Iterable[KnowledgeGraph],
              num_relations: Optional[int] = None) -> "CSRFilterIndex":
        """Build from all splits' triplets with one lexsort (duplicates —
        across splits or within one — are dropped)."""
        graphs = list(graphs)
        if num_relations is None:
            num_relations = max(
                [int(g.num_relations) for g in graphs], default=1)
        if graphs:
            cat = np.concatenate([g.triplets() for g in graphs], axis=0)
        else:
            cat = np.zeros((0, 3), np.int32)
        key = cat[:, 0].astype(np.int64) * num_relations + cat[:, 1]
        tail = cat[:, 2].astype(np.int32)
        order = np.lexsort((tail, key))
        key, tail = key[order], tail[order]
        if key.size:
            keep = np.ones(key.size, bool)
            keep[1:] = (key[1:] != key[:-1]) | (tail[1:] != tail[:-1])
            key, tail = key[keep], tail[keep]
        ukeys, starts = np.unique(key, return_index=True)
        indptr = np.concatenate(
            [starts, [key.size]]).astype(np.int64)
        return cls(keys=ukeys, indptr=indptr, tails=tail,
                   num_relations=int(num_relations))

    @property
    def num_pairs(self) -> int:
        return int(self.keys.shape[0])

    def _check_rel(self, r) -> None:
        # the key encoding s * num_relations + r is only injective for
        # r < num_relations: an out-of-range query (e.g. inverse relation
        # ids against an index built WITHOUT inverse graphs) would silently
        # hit a different (s, r) pair's tails where the dict reference
        # would just find nothing
        r = np.asarray(r)
        if np.any(r >= self.num_relations) or np.any(r < 0):
            raise ValueError(
                f"query relation id outside [0, {self.num_relations}) — "
                f"build the index over the same (inverse-augmented) "
                f"relation vocabulary it is queried with")

    def _stride(self) -> int:
        """Exclusive upper bound on stored tail ids (cached O(nnz) scan):
        a column range reaching it covers every tail, so full-range
        ``bias`` calls can skip the range index entirely."""
        cached = getattr(self, "_stride_cache", None)
        if cached is None:
            cached = int(self.tails.max()) + 1 if self.tails.size else 1
            object.__setattr__(self, "_stride_cache", cached)
        return cached

    def _range_index(self) -> np.ndarray:
        """``aug[i] = segment(i) * stride + tails[i]`` for column-range
        lookups: globally non-decreasing (the build lexsorts by
        (key, tail), and every tail < stride), so the in-range tail span
        of each query's key segment is two vectorized ``searchsorted``s —
        per-batch work and memory then scale with the tails INSIDE the
        range, not the whole batch's tails.  Built lazily on the first
        SUB-range query and cached (one int64 per stored tail; full-range
        queries never build it)."""
        cached = getattr(self, "_range_cache", None)
        if cached is not None:
            return cached
        seg = np.repeat(np.arange(self.num_pairs, dtype=np.int64),
                        np.diff(self.indptr))
        aug = seg * self._stride() + self.tails
        object.__setattr__(self, "_range_cache", aug)
        return aug

    def resolve_queries(
            self, triplets: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Per-row key positions for a test batch: ``(pos, found)`` with
        ``keys[pos[i]]`` the row's (s, r) key where ``found[i]`` (clamped
        otherwise).  Shard-independent — the sharded eval path resolves a
        batch ONCE and reuses it across every column-range ``bias`` block
        instead of re-searching the key array per shard."""
        trip = np.asarray(triplets)
        b = trip.shape[0]
        if b == 0 or self.num_pairs == 0:
            return (np.zeros(b, np.int64), np.zeros(b, bool))
        self._check_rel(trip[:, 1])
        q = trip[:, 0].astype(np.int64) * self.num_relations + trip[:, 1]
        pos = np.searchsorted(self.keys, q)
        pos_c = np.minimum(pos, self.num_pairs - 1)
        found = (pos < self.num_pairs) & (self.keys[pos_c] == q)
        return pos_c, found

    def tails_of(self, s: int, r: int) -> np.ndarray:
        """Known tails of one (s, r) pair (empty if absent) — test surface."""
        self._check_rel(r)
        q = np.int64(s) * self.num_relations + r
        k = int(np.searchsorted(self.keys, q))
        if k >= self.num_pairs or self.keys[k] != q:
            return np.zeros(0, np.int32)
        return self.tails[self.indptr[k]: self.indptr[k + 1]]

    def bias(self, triplets: np.ndarray, num_cols: int,
             col_start: int = 0,
             resolved: Optional[Tuple[np.ndarray, np.ndarray]] = None
             ) -> np.ndarray:
        """(B, num_cols) float32 filter bias for a test batch: ``FILTER_BIAS``
        on every known tail of each row's (s, r), 0 elsewhere — and always 0
        on the row's own true tail (never self-filtered).  One searchsorted
        + one scatter; equals the reference dict-of-sets double loop
        bit-for-bit.

        The COLUMN-RANGE form (``col_start > 0`` or ``num_cols`` smaller
        than the vocabulary) covers global candidate columns
        ``[col_start, col_start + num_cols)`` and, for ranges WITHIN the
        vocabulary (``col_start + num_cols <= N``), equals
        ``bias(triplets, N)[:, col_start:col_start + num_cols]`` without
        ever materializing the dense ``(B, N)`` matrix — this is what the
        candidate-axis-sharded ranking path builds per model shard, so peak
        host bias memory is ∝ 1/num_shards (a multi-host mesh builds only
        its own shards' blocks).  Columns at or beyond the vocabulary stay
        0.0 — the index stores tails, not the entity count, so it cannot
        mark nonexistent-entity columns; a caller whose score matrix has
        padded rows there must mask them itself (the sharded path's
        ``shard_filter_bias_block`` fills layout padding with ``-inf``).
        Host cost stays one searchsorted plus one scatter, and only tails
        inside the range are scattered; full-range calls (the dense
        ranking path) read spans directly off ``indptr`` and never build
        the range index.  ``resolved`` short-circuits the key lookup with
        a cached ``resolve_queries`` result — callers building many column
        blocks of one batch (the sharded eval path) resolve once.
        """
        trip = np.asarray(triplets)
        b = trip.shape[0]
        out = np.zeros((b, num_cols), np.float32)
        if b == 0 or num_cols == 0 or self.num_pairs == 0:
            return out
        pos_c, found = (self.resolve_queries(trip) if resolved is None
                        else resolved)
        if col_start <= 0 and col_start + num_cols >= self._stride():
            # full range: every stored tail is inside — spans come
            # straight off indptr, no range index needed
            starts = np.where(found, self.indptr[pos_c], 0)
            counts = np.where(found, self.indptr[pos_c + 1] - starts, 0)
        else:
            # each query's IN-RANGE tail span, via the augmented range
            # index — the scatter temporaries below scale with the tails
            # inside [col_start, col_start + num_cols), so a 1/S column
            # block costs ~1/S of the dense bias in host memory, not just
            # output size
            stride, aug = self._stride(), self._range_index()
            lo_q = min(max(col_start, 0), stride)
            hi_q = min(max(col_start + num_cols, 0), stride)
            starts = np.searchsorted(aug, pos_c * stride + lo_q)
            ends = np.searchsorted(aug, pos_c * stride + hi_q)
            counts = np.where(found, ends - starts, 0)
            starts = np.where(found, starts, 0)
        total = int(counts.sum())
        if total:
            rows = np.repeat(np.arange(b), counts)
            # flat tails positions: starts[i] + (0 .. counts[i]-1) per row
            csum = np.concatenate([[0], np.cumsum(counts)[:-1]])
            flat = np.repeat(starts - csum, counts) + np.arange(total)
            out[rows, self.tails[flat] - col_start] = FILTER_BIAS
        t = trip[:, 2]
        in_range = (t >= col_start) & (t < col_start + num_cols)
        out[np.nonzero(in_range)[0], t[in_range] - col_start] = 0.0
        return out


FilterIndex = Union[Dict, CSRFilterIndex]


def _filter_bias(filter_index: FilterIndex, batch: np.ndarray,
                 num_cols: int, col_start: int = 0,
                 resolved=None) -> np.ndarray:
    """(B, num_cols) bias covering global candidate columns
    ``[col_start, col_start + num_cols)`` from either index form (the dict
    path is the loop reference the CSR column-range path is tested
    against); ``resolved`` is a cached CSR ``resolve_queries`` result."""
    if isinstance(filter_index, CSRFilterIndex):
        return filter_index.bias(batch, num_cols, col_start, resolved)
    bias = np.zeros((batch.shape[0], num_cols), np.float32)
    for i, (s, r, t) in enumerate(batch):
        known = filter_index.get((int(s), int(r)), ())
        for k in known:
            if k != int(t) and col_start <= k < col_start + num_cols:
                bias[i, k - col_start] = FILTER_BIAS
    return bias


def mean_rank(greater, equal_incl_true):
    """Tie-aware rank from candidate counts: ``equal_incl_true`` counts
    score-ties INCLUDING the true candidate itself (which always ties)."""
    return 1.0 + np.asarray(greater, np.float64) \
        + 0.5 * (np.asarray(equal_incl_true, np.float64) - 1.0)


def metrics_from_ranks(ranks: np.ndarray,
                       hits_ks: Sequence[int]) -> Dict[str, float]:
    ranks = np.asarray(ranks, np.float64)
    out = {"mrr": float(np.mean(1.0 / ranks))}
    for k in hits_ks:
        out[f"hits@{k}"] = float(np.mean(ranks <= k))
    return out


def ranking_metrics(
    entity_emb: np.ndarray,          # (N, d) encoded entity embeddings
    decoder_params: Dict[str, Any],  # decoder parameter tree
    test_triplets: np.ndarray,       # (T, 3) global ids
    filter_index: FilterIndex,
    hits_ks: Sequence[int] = (1, 3, 10),
    candidates: Optional[np.ndarray] = None,   # (T, C) per-test candidates
    batch_size: int = 256,
    decoder: Union[str, Decoder] = "distmult",
    num_shards: int = 1,
    table_dtype: str = "fp32",
) -> Dict[str, float]:
    """Filtered MRR / Hits@k, tail-corruption direction.

    ``decoder`` is a registered :class:`repro.models.decoders.Decoder` (or
    its name — the paper's approach is "agnostic to the used knowledge graph
    embedding model" §6).  EVERY decoder goes through the Pallas ranking
    kernel in its canonical query form; ``decoder_params`` is the decoder's
    own parameter tree (``params["decoder"]`` from the trained model).

    ``num_shards > 1`` routes to the candidate-axis-sharded path
    (``repro.eval.sharded``) for every decoder and BOTH candidate
    protocols: in the all-entities protocol each shard scores only its own
    table rows (per-shard filter-bias column blocks built straight from the
    CSR index — the dense (B, N) bias is never materialized); in the ogbl
    candidate-list protocol the per-row candidate ids are scattered by
    owning row block and each shard scores only the candidates it stores.
    Both emit partial greater/equal counts whose exchange reconstructs
    exactly the same metrics as this dense reference (enforced by
    ``tests/test_decoders.py`` / ``tests/test_eval_ranking.py``).

    Run twice (once on the graph, once on the inverse-relation graph) and
    average to get the standard both-directions protocol —
    ``evaluate_both_directions`` does that.
    """
    dec = get_decoder(decoder)
    if num_shards > 1 or table_dtype != "fp32":
        # int8 always takes the sharded path (even single-shard): its
        # block-at-a-time dequantization is what keeps the fp32 table off
        # the device, and the sharded metrics are EXACTLY the dense
        # metrics over the dequantized table
        from repro.eval.sharded import sharded_ranking_metrics
        return sharded_ranking_metrics(
            entity_emb, decoder_params, test_triplets, filter_index,
            max(num_shards, 1), hits_ks=hits_ks, batch_size=batch_size,
            decoder=dec, candidates=candidates, table_dtype=table_dtype)

    n = entity_emb.shape[0]
    emb = jnp.asarray(entity_emb)
    dparams = jax.tree_util.tree_map(jnp.asarray, decoder_params)
    # candidate-side preparation is row-local and query-independent:
    # prepare the full entity matrix once, reuse across batches (the ogbl
    # per-row-candidates path prepares its own gathered rows instead)
    prepared = (dec.prepare_candidates(dparams, emb)
                if candidates is None else None)
    ranks: list = []

    for lo in range(0, test_triplets.shape[0], batch_size):
        batch = test_triplets[lo: lo + batch_size]
        b = batch.shape[0]
        h_s = emb[jnp.asarray(batch[:, 0])]
        rel = jnp.asarray(batch[:, 1])

        if candidates is None:
            # score against ALL entities, filtered setting
            bias = _filter_bias(filter_index, batch, n)
            scores = dec.rank_scores(dparams, h_s, rel, emb,
                                     jnp.asarray(bias), prepared=prepared)
            true_scores = scores[jnp.arange(b), jnp.asarray(batch[:, 2])]
            greater = jnp.sum(scores > true_scores[:, None], axis=1)
            # the true candidate's own column always ties (bias 0 there) —
            # mean_rank discounts it
            equal = jnp.sum(scores == true_scores[:, None], axis=1)
            rank = mean_rank(np.asarray(greater), np.asarray(equal))
        else:
            # ogbl-style: true tail + provided negative candidates (per-row
            # candidate sets — the query form with a batched candidate axis)
            cand = candidates[lo: lo + batch_size]           # (b, C)
            cand_emb = emb[jnp.asarray(cand.reshape(-1))].reshape(
                b, cand.shape[1], -1)
            q, q_bias = dec.prepare_query(dparams, h_s, rel)
            c_neg, cb_neg = dec.prepare_candidates(dparams, cand_emb)
            neg_scores = apply_epilogue(
                jnp.einsum("bd,bcd->bc", q, c_neg)
                + q_bias[:, None] + cb_neg, dec.epilogue)
            c_true, cb_true = dec.prepare_candidates(
                dparams, emb[jnp.asarray(batch[:, 2])])
            true_scores = apply_epilogue(
                jnp.sum(q * c_true, axis=1) + q_bias + cb_true,
                dec.epilogue)
            greater = jnp.sum(neg_scores > true_scores[:, None], axis=1)
            equal = jnp.sum(neg_scores == true_scores[:, None], axis=1)
            # candidates exclude the true tail, so no self-tie to discount
            rank = mean_rank(np.asarray(greater), np.asarray(equal) + 1)
        ranks.append(np.asarray(rank))

    return metrics_from_ranks(np.concatenate(ranks), hits_ks)


def evaluate_both_directions(
    entity_emb: np.ndarray,
    decoder_params: Dict[str, Any],
    test_kg: KnowledgeGraph,
    filter_graphs: Sequence[KnowledgeGraph],
    num_relations_base: int,
    hits_ks: Sequence[int] = (1, 3, 10),
    decoder: Union[str, Decoder] = "distmult",
    num_shards: int = 1,
    table_dtype: str = "fp32",
) -> Dict[str, float]:
    """Average of tail-corruption on (s,r,t) and on the inverse triplets
    (t, r+R, s) — i.e. head corruption.  ``decoder_params`` (the decoder's
    relation tables) must cover the doubled relation vocabulary (we train
    with inverse relations).  The CSR filter index over all splits (inverse
    relations included) is built once and shared by both directions."""
    fidx = CSRFilterIndex.build(
        [g.with_inverse_relations() for g in filter_graphs])
    fwd = test_kg.triplets()
    inv = np.stack([test_kg.dst, test_kg.rel + num_relations_base,
                    test_kg.src], axis=1)
    m_fwd = ranking_metrics(entity_emb, decoder_params, fwd, fidx, hits_ks,
                            decoder=decoder, num_shards=num_shards,
                            table_dtype=table_dtype)
    m_inv = ranking_metrics(entity_emb, decoder_params, inv, fidx, hits_ks,
                            decoder=decoder, num_shards=num_shards,
                            table_dtype=table_dtype)
    return {k: 0.5 * (m_fwd[k] + m_inv[k]) for k in m_fwd}
