"""Filtered link-prediction evaluation — paper §4.2 (Eq. 5, 6).

For each test triplet the candidate set is every entity (FB15k-237 protocol)
or a provided candidate list (ogbl-citation2 ships 1000 negatives per edge);
candidates that form a KNOWN positive (train/valid/test) are filtered out.
Both corruption directions are evaluated — tail corruption on (s, r, t) and,
through the inverse relation, head corruption.

Scoring runs through the Pallas ranking kernel
(``repro.kernels.distmult_rank_scores``) in candidate blocks.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import KnowledgeGraph
from repro.kernels.ops import distmult_rank_scores
from repro.models.decoders import score_against_candidates


def build_filter_index(graphs: Iterable[KnowledgeGraph]) -> Dict:
    """(s, r) -> set of known-true tails, over all splits."""
    idx: Dict = {}
    for g in graphs:
        for s, r, t in g.triplets():
            idx.setdefault((int(s), int(r)), set()).add(int(t))
    return idx


def ranking_metrics(
    entity_emb: np.ndarray,          # (N, d) encoded entity embeddings
    rel_diag_table: np.ndarray,      # (R, d) decoder relation table
    test_triplets: np.ndarray,       # (T, 3) global ids
    filter_index: Dict,
    hits_ks: Sequence[int] = (1, 3, 10),
    candidates: Optional[np.ndarray] = None,   # (T, C) per-test candidates
    batch_size: int = 256,
    decoder: str = "distmult",
) -> Dict[str, float]:
    """Filtered MRR / Hits@k, tail-corruption direction.

    ``decoder`` selects the scoring function (the paper's approach is
    "agnostic to the used knowledge graph embedding model" §6): DistMult
    goes through the Pallas ranking kernel; TransE/ComplEx go through
    ``score_against_candidates``.

    Run twice (once on the graph, once on the inverse-relation graph) and
    average to get the standard both-directions protocol —
    ``evaluate_both_directions`` does that.
    """
    n = entity_emb.shape[0]
    emb = jnp.asarray(entity_emb)
    table = jnp.asarray(rel_diag_table)
    ranks: list = []

    for lo in range(0, test_triplets.shape[0], batch_size):
        batch = test_triplets[lo: lo + batch_size]
        b = batch.shape[0]
        h_s = emb[jnp.asarray(batch[:, 0])]
        rel = jnp.asarray(batch[:, 1])

        if candidates is None:
            # score against ALL entities, filtered setting
            bias = np.zeros((b, n), np.float32)
            for i, (s, r, t) in enumerate(batch):
                known = filter_index.get((int(s), int(r)), ())
                for k in known:
                    if k != int(t):
                        bias[i, k] = -1e9
            if decoder == "distmult":
                scores = distmult_rank_scores(
                    h_s, rel, table, emb, jnp.asarray(bias))
            else:
                key = {"transe": "rel_vec",
                       "complex": "rel_complex"}[decoder]
                scores = score_against_candidates(
                    {key: table}, decoder, h_s, rel, emb)
                scores = scores + jnp.asarray(bias)
            true_scores = scores[jnp.arange(b), jnp.asarray(batch[:, 2])]
            rank = 1 + jnp.sum(scores > true_scores[:, None], axis=1)
        else:
            # ogbl-style: true tail + provided negative candidates
            cand = candidates[lo: lo + batch_size]           # (b, C)
            cand_emb = emb[jnp.asarray(cand.reshape(-1))].reshape(
                b, cand.shape[1], -1)
            q = h_s * table[rel]
            neg_scores = jnp.einsum("bd,bcd->bc", q, cand_emb)
            true_scores = jnp.sum(q * emb[jnp.asarray(batch[:, 2])], axis=1)
            rank = 1 + jnp.sum(neg_scores > true_scores[:, None], axis=1)
        ranks.append(np.asarray(rank))

    ranks_np = np.concatenate(ranks).astype(np.float64)
    out = {"mrr": float(np.mean(1.0 / ranks_np))}
    for k in hits_ks:
        out[f"hits@{k}"] = float(np.mean(ranks_np <= k))
    return out


def evaluate_both_directions(
    entity_emb: np.ndarray,
    rel_diag_table: np.ndarray,
    test_kg: KnowledgeGraph,
    filter_graphs: Sequence[KnowledgeGraph],
    num_relations_base: int,
    hits_ks: Sequence[int] = (1, 3, 10),
    decoder: str = "distmult",
) -> Dict[str, float]:
    """Average of tail-corruption on (s,r,t) and on the inverse triplets
    (t, r+R, s) — i.e. head corruption.  ``rel_diag_table`` must cover the
    doubled relation vocabulary (we train with inverse relations)."""
    fidx = build_filter_index(
        [g.with_inverse_relations() for g in filter_graphs])
    fwd = test_kg.triplets()
    inv = np.stack([test_kg.dst, test_kg.rel + num_relations_base,
                    test_kg.src], axis=1)
    m_fwd = ranking_metrics(entity_emb, rel_diag_table, fwd, fidx, hits_ks,
                            decoder=decoder)
    m_inv = ranking_metrics(entity_emb, rel_diag_table, inv, fidx, hits_ks,
                            decoder=decoder)
    return {k: 0.5 * (m_fwd[k] + m_inv[k]) for k in m_fwd}
