"""Candidate-axis-sharded filtered ranking over the row-sharded entity table.

Dense ``ranking_metrics`` scores every test query against the full ``(N, d)``
entity matrix on one device — the last single-device assumption in the
system once training stores the entity table row-sharded over the ``model``
mesh axis (``repro.sharding.embedding``).  This module shards the *candidate*
axis of evaluation along the same row blocks, for EVERY registered decoder
(``repro.models.decoders``) via the canonical query form:

    per model shard s (owning table rows [s·rows, (s+1)·rows)):
        q, q_bias = decoder.prepare_query(...)        (replicated, computed
                                                       once per batch)
        C'_s, c_bias_s = decoder.prepare_candidates(table_s)   (row-local)
                  ──►  Pallas kge_score kernel against ONLY the shard's
                       rows (+ per-shard filter-bias block, -inf on pads)
                  ──►  partial counts   greater_s = #{score > true}
                                        equal_s   = #{score == true}
                       (true score: the owning shard's kernel row, masked)
    global rank = 1 + psum(greater_s) + 0.5 · (psum(equal_s) − 1)

The exchange is integer (candidate counts) plus one one-hot float (the true
score, owned by exactly one shard), so the sharded rank is EXACTLY the dense
rank — not approximately: candidate preparation is row-local, each
per-candidate score is the same ``d``-length MXU dot + elementwise epilogue
the dense kernel computes, only tiled per shard, and the count psum is
order-free.  ``tests/test_decoders.py`` enforces identical MRR/Hits@k
(``==``, not allclose) at 1/2/4 shards for every registered decoder,
including ties and padded rows.

Host data path: the per-shard filter-bias blocks are built DIRECTLY from
the CSR index's column-range form (``CSRFilterIndex.bias(triplets, rows,
col_start)`` via :func:`shard_filter_bias_block`) — the dense ``(B, N)``
bias matrix is never materialized, so peak host bias memory is
∝ 1/num_shards and a multi-host mesh builds only its own shards' column
blocks (``tests/test_eval_ranking.py`` asserts the peak-allocation bound).

The ogbl candidate-list protocol rides the same sharded path: per-row
candidate ids are scattered by owning row block (``plan_local_gather`` on
the ``(B, C)`` id matrix), each shard reads only its own table rows and
COUNTS only the candidates it stores (all lanes are scored, non-owned
ones masked — table memory shrinks ∝ 1/S, scoring FLOPs do not; see
:func:`sharded_candidate_rank_counts`), and masked greater/equal partial
counts are summed — again EXACTLY the dense candidate-path metrics.

Two execution paths, mirroring ``sharded_gather``:

* ``axis_name=None`` — masked single-device simulation: the full
  ``(S, rows, d)`` stack is looped shard-by-shard and partials summed.
* ``axis_name="model"`` — inside ``shard_map``: each device holds its
  ``(1, rows, d)`` row block and ``(1, B, rows)`` bias block (or
  ``(1, B, C)`` candidate-plan block); partials are ``jax.lax.psum``'d over
  the model axis (``make_sharded_rank_step``).  A step built by
  ``make_sharded_rank_step`` carries its mesh, so table, bias blocks and
  candidate plans are ``jax.device_put`` per model-axis device
  (``jax.make_array_from_callback`` — each host realizes only its own
  devices' blocks).

Head/query embeddings are fetched through the PR-2 ``sharded_gather``
exchange — ranking never materializes the dense entity matrix.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.eval.ranking import (
    CSRFilterIndex, _filter_bias, mean_rank, metrics_from_ranks,
)
from repro.kernels.kge_score import apply_epilogue
from repro.kernels.ops import kge_score_padded
from repro.models.decoders import Decoder, get_decoder
from repro.sharding.embedding import (
    TABLE_DTYPES, ShardedTableLayout, dequantize_rows, plan_local_gather,
    plan_local_gather_block, quantize_rows, shard_table, shard_table_block,
    sharded_dequant_gather, sharded_gather,
)


def _num_table_blocks(table) -> int:
    """Shard count of a table argument — a ``(S, rows, d)`` fp32 stack or
    an int8 ``(codes, scales)`` pair (``quantize_rows`` layout)."""
    return (table[0] if isinstance(table, tuple) else table).shape[0]


def _table_block(table, s) -> jax.Array:
    """Shard ``s``'s fp32 ``(rows, d)`` row block.  For a quantized table
    the block is dequantized HERE, transiently — only one shard's rows
    ever exist in fp32, never the ``(S, rows, d)`` stack (the invariant
    the replication audit checks on the serving program)."""
    if isinstance(table, tuple):
        codes, scales = table
        return dequantize_rows(codes[s], scales[s])
    return table[s]


def shard_filter_bias_block(filter_index, batch: np.ndarray,
                            layout: ShardedTableLayout,
                            shard: int, resolved=None) -> np.ndarray:
    """One shard's ``(B, rows_per_shard)`` filter-bias column block, built
    straight from the index's column-range form.

    Covers global candidate columns ``[shard·rows, (shard+1)·rows)``;
    layout-padded tail columns (``>= num_rows`` — no real entity) get
    ``-inf`` so a padded row's score can neither outrank nor tie any real
    candidate.  Equals ``shard_bias_blocks(dense_bias, layout)[shard]``
    bit-for-bit WITHOUT the dense ``(B, N)`` bias ever existing: peak host
    bias memory per call is one column block, ∝ 1/num_shards.
    ``resolved`` is a cached ``CSRFilterIndex.resolve_queries(batch)``
    result so many blocks of one batch share a single key lookup.
    """
    rows = layout.rows_per_shard
    lo, hi = layout.shard_row_span(shard)
    width = hi - lo
    if width == rows:                  # interior shard: no layout padding
        return _filter_bias(filter_index, batch, rows, col_start=lo,
                            resolved=resolved)
    block = np.full((np.asarray(batch).shape[0], rows), -np.inf, np.float32)
    if width:
        block[:, :width] = _filter_bias(filter_index, batch, width,
                                        col_start=lo, resolved=resolved)
    return block


def _model_axis_put(shape, fn, mesh, model_axis: str):
    """Assemble a shard-leading ``(S, ...)`` global array sharded over the
    mesh's model axis from a per-shard block factory ``fn(s) -> block``,
    via ``jax.make_array_from_callback``: the callback runs once per
    addressable device slice, so each HOST realizes only its own devices'
    blocks — never the full stack (a plain ``device_put`` of the full
    array would both materialize it everywhere and fail multi-host on
    non-addressable devices)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    sharding = NamedSharding(mesh, P(model_axis))
    cache = {}   # make_array_from_callback invokes the callback once per
    #              addressable DEVICE (no index dedup for partially
    #              replicated shardings), so every data-axis replica of a
    #              model block would rebuild fn(s) without this memo

    def block(s):
        if s not in cache:
            cache[s] = fn(s)
        return cache[s]

    def callback(index):
        lo, hi, _ = index[0].indices(shape[0])
        return np.stack([block(s) for s in range(lo, hi)])

    return jax.make_array_from_callback(shape, sharding, callback)


def _stack_bias_blocks(filter_index, batch: np.ndarray,
                       layout: ShardedTableLayout, mesh=None,
                       model_axis: str = "model") -> jax.Array:
    """The batch's ``(S, B, rows)`` per-shard bias stack with no dense
    ``(B, N)`` intermediate.  Without a mesh each block is transferred as
    soon as it is built (host holds one block at a time); with a mesh the
    stack is assembled per model-axis device via
    ``jax.make_array_from_callback``."""
    b = np.asarray(batch).shape[0]
    # the key lookup is shard-independent: resolve the batch ONCE and let
    # every column block reuse it (the dict reference index has no
    # precomputable form — it is not a production path)
    resolved = (filter_index.resolve_queries(batch)
                if isinstance(filter_index, CSRFilterIndex) else None)
    if mesh is not None:
        return _model_axis_put(
            (layout.num_shards, b, layout.rows_per_shard),
            lambda s: shard_filter_bias_block(filter_index, batch, layout,
                                              s, resolved),
            mesh, model_axis)
    # jnp.copy (not asarray): the CPU backend zero-copy-aliases numpy
    # buffers, which would keep every block's host memory alive inside the
    # device stack — a synchronized copy releases each block before the
    # next is built (async dispatch would otherwise queue all S copies
    # with their host sources pinned)
    def put(block):
        return jnp.copy(block).block_until_ready()

    return jnp.stack([
        put(shard_filter_bias_block(filter_index, batch, layout, s,
                                    resolved))
        for s in range(layout.num_shards)])


def shard_scores(decoder: Decoder, dec_params, table_block, q, q_bias,
                 bias_block, interpret=None, *, prepared=None):
    """One shard's ``(B, rows)`` kernel scores: row-local candidate
    preparation of the shard's own table block + the shared query rows.

    Because preparation is row-local, each column is bitwise the matching
    column of the dense kernel's ``(B, N)`` output — the invariant both the
    sharded ranking metrics and the serving top-k (``repro.serving.kge``,
    which passes its per-shard ``prepared`` cache to skip re-preparing the
    static candidate side every request) are built on."""
    cand, c_bias = (prepared if prepared is not None else
                    decoder.prepare_candidates(dec_params, table_block))
    return kge_score_padded(q, cand, bias_block, q_bias, c_bias,
                            epilogue=decoder.epilogue, interpret=interpret)


def sharded_rank_counts(
    decoder: Union[str, Decoder],
    dec_params: Dict[str, Any],  # decoder params (replicated)
    table: jax.Array,        # (S, rows, d) sim / (1, rows, d) per device
    q: jax.Array,            # (B, d) prepared query rows (replicated)
    q_bias: jax.Array,       # (B,) pre-epilogue query bias (replicated)
    bias: jax.Array,         # (S, B, rows) sim / (1, B, rows) per device
    true_local: jax.Array,   # (S, B) true-tail local row per shard
    true_owned: jax.Array,   # (S, B) which shard owns each true tail
    *,
    axis_name: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-query global rank counts from shard-local kernel scores.

    Returns ``(greater, equal, true_score)``: ``greater``/``equal`` are the
    global candidate counts vs the true score (``equal`` INCLUDES the true
    candidate's own self-tie; callers discount it via ``mean_rank``), and
    ``true_score`` the reconstructed true-tail score.  The true score is
    extracted from the owning shard's kernel output row — not recomputed
    with a separate dot — so it is bit-identical to the dense kernel's
    ``scores[b, t]`` and the ``>``/``==`` comparisons agree with the dense
    path even at exact ties.  ``bias`` must be ``-inf`` on layout-padded
    rows (``shard_filter_bias_block``), which zeroes their count
    contribution for both epilogue families.
    """
    decoder = get_decoder(decoder)
    b = q.shape[0]
    rows_idx = jnp.arange(b)

    num_blocks = _num_table_blocks(table)
    if axis_name is None:
        # masked single-device simulation over the full shard stack
        scores = [shard_scores(decoder, dec_params, _table_block(table, s),
                               q, q_bias, bias[s], interpret)
                  for s in range(num_blocks)]
        true_score = sum(
            jnp.where(true_owned[s], scores[s][rows_idx, true_local[s]], 0.0)
            for s in range(num_blocks))
        greater = sum(
            jnp.sum((sc > true_score[:, None]).astype(jnp.int32), axis=1)
            for sc in scores)
        equal = sum(
            jnp.sum((sc == true_score[:, None]).astype(jnp.int32), axis=1)
            for sc in scores)
        return greater, equal, true_score

    if num_blocks != 1:
        # same trap as sharded_gather: a replicated (S, rows, d) stack
        # inside shard_map would score shard 0's rows everywhere and psum
        # S wrong partial counts — fail at trace time instead
        raise ValueError(
            f"sharded_rank_counts under shard_map expects this device's "
            f"(1, rows, d) row block, got {num_blocks} blocks — shard the "
            f"table and bias over {axis_name!r}")
    s = jax.lax.axis_index(axis_name)
    scores = shard_scores(decoder, dec_params, _table_block(table, 0), q,
                          q_bias, bias[0], interpret)
    true_score = jax.lax.psum(
        jnp.where(true_owned[s], scores[rows_idx, true_local[s]], 0.0),
        axis_name)
    greater = jax.lax.psum(
        jnp.sum((scores > true_score[:, None]).astype(jnp.int32), axis=1),
        axis_name)
    equal = jax.lax.psum(
        jnp.sum((scores == true_score[:, None]).astype(jnp.int32), axis=1),
        axis_name)
    return greater, equal, true_score


def sharded_candidate_rank_counts(
    decoder: Union[str, Decoder],
    dec_params: Dict[str, Any],  # decoder params (replicated)
    table: jax.Array,        # (S, rows, d) sim / (1, rows, d) per device
    q: jax.Array,            # (B, d) prepared query rows (replicated)
    q_bias: jax.Array,       # (B,) pre-epilogue query bias (replicated)
    cand_local: jax.Array,   # (S, B, C) / (1, B, C): local candidate rows
    cand_owned: jax.Array,   # (S, B, C) / (1, B, C): ownership masks
    true_score: jax.Array,   # (B,) true-tail scores (replicated)
    *,
    axis_name: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """ogbl candidate-list protocol over the row-sharded table: per-query
    global ``(greater, equal)`` counts vs the provided candidate sets.

    Candidate ids arrive pre-scattered by owning row block
    (``plan_local_gather`` on the ``(B, C)`` id matrix): each shard reads
    ONLY its own table rows — non-owned lanes gather a clipped junk row,
    every ``(B, C)`` lane is scored with the same einsum + rank-1 biases +
    elementwise epilogue the dense candidate path computes, and non-owned
    lanes are masked out of the counts — so each owned per-element score
    is bitwise the dense score and the integer-count exchange reconstructs
    exactly the dense rank.  The tradeoff is explicit: sharding here buys
    TABLE-MEMORY distribution (rows/S per device, no replicated table),
    not scoring FLOPs — each shard still runs the full ``(B, C, d)``
    einsum (total work S× dense; C is the small ogbl candidate count, so
    scoring is cheap next to the table bytes).  Compacting each shard to
    its ~C/S owned candidates would make per-shard shapes data-dependent —
    incompatible with the fixed-shape ``shard_map`` step.  ``equal``
    EXCLUDES the true tail (ogbl candidate lists do not contain it);
    callers add the self-tie back via ``mean_rank(greater, equal + 1)``,
    matching the dense path.
    """
    decoder = get_decoder(decoder)

    def one(table_block, local, owned):
        gathered = table_block[local]                     # (B, C, d)
        cand, c_bias = decoder.prepare_candidates(dec_params, gathered)
        scores = apply_epilogue(
            jnp.einsum("bd,bcd->bc", q, cand) + q_bias[:, None] + c_bias,
            decoder.epilogue)
        greater = jnp.sum(
            (owned & (scores > true_score[:, None])).astype(jnp.int32),
            axis=1)
        equal = jnp.sum(
            (owned & (scores == true_score[:, None])).astype(jnp.int32),
            axis=1)
        return greater, equal

    num_blocks = _num_table_blocks(table)
    if axis_name is None:
        parts = [one(_table_block(table, s), cand_local[s], cand_owned[s])
                 for s in range(num_blocks)]
        return sum(p[0] for p in parts), sum(p[1] for p in parts)

    if num_blocks != 1:
        raise ValueError(
            f"sharded_candidate_rank_counts under shard_map expects this "
            f"device's (1, rows, d) row block, got {num_blocks} blocks — "
            f"shard the table and candidate plans over {axis_name!r}")
    greater, equal = one(_table_block(table, 0), cand_local[0],
                         cand_owned[0])
    return (jax.lax.psum(greater, axis_name),
            jax.lax.psum(equal, axis_name))


def make_sharded_rank_step(mesh, *, decoder: Union[str, Decoder] = "distmult",
                           model_axis: str = "model",
                           protocol: str = "all-entities",
                           interpret: Optional[bool] = None):
    """Build the jitted ``shard_map`` rank-count step for a real mesh.

    The entity-table row blocks — and, per ``protocol``, either the
    per-shard bias blocks (``"all-entities"``) or the scattered candidate
    plans (``"candidates"``, the ogbl list protocol) — are sharded over
    ``model_axis`` (one block per device — the layouts ``kge_param_specs``
    prescribes); queries, query bias and the decoder's own params are
    replicated.  ``decoder`` is jit-static (a registry name or frozen
    Decoder singleton).  Returns ``step(dec_params, table, q, q_bias, bias,
    true_local, true_owned) -> (greater, equal, true_score)`` for the
    all-entities protocol, or ``step(dec_params, table, q, q_bias,
    cand_local, cand_owned, true_score) -> (greater, equal)`` for the
    candidate protocol, with globally psum'd outputs exactly equal to the
    ``axis_name=None`` simulation.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dec = get_decoder(decoder)

    if protocol == "all-entities":
        def body(dec_params, table, q, q_bias, bias, true_local, true_owned):
            return sharded_rank_counts(
                dec, dec_params, table, q, q_bias, bias, true_local,
                true_owned, axis_name=model_axis, interpret=interpret)

        in_specs = (P(), P(model_axis), P(), P(), P(model_axis), P(), P())
        out_specs = (P(), P(), P())
    elif protocol == "candidates":
        def body(dec_params, table, q, q_bias, cand_local, cand_owned,
                 true_score):
            return sharded_candidate_rank_counts(
                dec, dec_params, table, q, q_bias, cand_local, cand_owned,
                true_score, axis_name=model_axis)

        in_specs = (P(), P(model_axis), P(), P(), P(model_axis),
                    P(model_axis), P())
        out_specs = (P(), P())
    else:
        raise ValueError(
            f"unknown protocol {protocol!r}; choose 'all-entities' "
            f"(score every table row) or 'candidates' (ogbl per-row "
            f"candidate lists)")

    sharded = shard_map(
        body, mesh=mesh,
        in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )
    step = jax.jit(sharded)
    # tag so sharded_ranking_metrics can fail fast on a step built with a
    # DIFFERENT decoder or protocol than the queries were prepared with
    # (the scores would be silently wrong, not shape-mismatched), and so it
    # can device_put the per-shard blocks onto the step's own mesh axis
    step.decoder = dec
    step.protocol = protocol
    step.mesh = mesh
    step.model_axis = model_axis
    return step


def sharded_ranking_metrics(
    entity_emb: np.ndarray,          # (N, d) encoded entity embeddings
    decoder_params: Dict[str, Any],  # decoder parameter tree
    test_triplets: np.ndarray,       # (T, 3) global ids
    filter_index,                    # CSRFilterIndex or dict reference
    num_shards: int,
    hits_ks: Sequence[int] = (1, 3, 10),
    batch_size: int = 256,
    decoder: Union[str, Decoder] = "distmult",
    rank_step=None,
    interpret: Optional[bool] = None,
    candidates: Optional[np.ndarray] = None,   # (T, C) per-test candidates
    table_dtype: str = "fp32",
) -> Dict[str, float]:
    """Filtered MRR / Hits@k with candidate-axis-sharded ranking — the
    ``num_shards > 1`` twin of the dense ``ranking_metrics`` (any registered
    decoder, both candidate protocols), returning exactly the same metrics.

    The entity table is row-sharded once (``shard_table``).  All-entities
    protocol (``candidates=None``): per test batch the host builds each
    shard's ``(B, rows)`` filter-bias column block straight from the CSR
    index's column-range form (the dense ``(B, N)`` bias is never
    materialized — peak host bias memory ∝ 1/num_shards), plans the head
    gather and true-tail ownership with the PR-2 ``plan_local_gather``, and
    the device computes per-shard partial counts from the decoder's query
    form.  ogbl candidate protocol (``candidates`` given): the per-row
    candidate ids are scattered by owning row block and each shard scores
    only the candidates it stores (``sharded_candidate_rank_counts``).

    ``rank_step`` switches the compute path: ``None`` runs the
    single-device shard-loop simulation; a ``make_sharded_rank_step``
    product (built with the SAME decoder, and ``protocol="candidates"``
    when ``candidates`` is given) runs the real ``shard_map`` + psum
    exchange, with table/bias/plan blocks ``device_put`` per model-axis
    device of the step's mesh.

    ``table_dtype="int8"`` stores the table as row-wise symmetric codes +
    fp32 per-row scales (``quantize_rows``); each shard's rows are
    dequantized transiently at score time and heads/true tails are fetched
    through the fused dequantizing gather, so no fp32 ``(S·rows, d)``
    buffer ever exists — metrics are EXACTLY the dense metrics over the
    dequantized table (the quantization error itself is the documented
    ≤ scale/2 per element; the MRR drift it induces is gated in
    ``benchmarks/run.py``).  Simulation path only (``rank_step=None``).
    """
    dec = get_decoder(decoder)
    step_dec = getattr(rank_step, "decoder", None)
    if step_dec is not None and step_dec != dec:
        raise ValueError(
            f"rank_step was built for decoder {step_dec.name!r} but ranking "
            f"runs {dec.name!r} — rebuild with make_sharded_rank_step"
            f"(mesh, decoder={dec.name!r}) (a mismatched step would score "
            f"silently wrong, not shape-mismatch)")
    protocol = "all-entities" if candidates is None else "candidates"
    step_proto = getattr(rank_step, "protocol", None)
    if step_proto is not None and step_proto != protocol:
        raise ValueError(
            f"rank_step was built for the {step_proto!r} protocol but this "
            f"call runs {protocol!r} — rebuild with make_sharded_rank_step"
            f"(mesh, protocol={protocol!r})")
    mesh = getattr(rank_step, "mesh", None)
    model_axis = getattr(rank_step, "model_axis", "model")

    if table_dtype not in TABLE_DTYPES:
        raise ValueError(
            f"table_dtype={table_dtype!r} not in {TABLE_DTYPES}")
    n, d = entity_emb.shape
    layout = ShardedTableLayout(n, num_shards)
    emb_f32 = np.ascontiguousarray(np.asarray(entity_emb, np.float32))
    if table_dtype == "int8":
        if mesh is not None:
            raise ValueError(
                "table_dtype='int8' runs on the simulation path only — "
                "pass rank_step=None (the shard_map rank step stays fp32)")
        codes, scales = quantize_rows(shard_table(emb_f32, layout))
        table: Any = (jnp.asarray(codes), jnp.asarray(scales))
    elif mesh is None:
        table = jnp.asarray(shard_table(emb_f32, layout))
    else:
        table = _model_axis_put(
            (layout.num_shards, layout.rows_per_shard, d),
            lambda s: shard_table_block(emb_f32, layout, s),
            mesh, model_axis)

    def gather_rows(li, ow):
        # embeddings through the PR-2 shard-local gather + exchange —
        # bitwise equal to the dense gather over the (dequantized) table
        if table_dtype == "int8":
            return sharded_dequant_gather(table[0], table[1],
                                          jnp.asarray(li), jnp.asarray(ow))
        return sharded_gather(table, jnp.asarray(li), jnp.asarray(ow))

    dparams = jax.tree_util.tree_map(jnp.asarray, decoder_params)
    ranks = []

    for lo in range(0, test_triplets.shape[0], batch_size):
        batch = np.asarray(test_triplets[lo: lo + batch_size])
        h_li, h_ow = plan_local_gather(layout, batch[:, 0])
        h_s = gather_rows(h_li, h_ow)
        rel = jnp.asarray(batch[:, 1].astype(np.int32))
        q, q_bias = dec.prepare_query(dparams, h_s, rel)
        t_li, t_ow = plan_local_gather(layout, batch[:, 2])

        if candidates is None:
            bias_blocks = _stack_bias_blocks(filter_index, batch, layout,
                                             mesh, model_axis)
            t_li, t_ow = jnp.asarray(t_li), jnp.asarray(t_ow)
            if rank_step is None:
                greater, equal, _ = sharded_rank_counts(
                    dec, dparams, table, q, q_bias, bias_blocks, t_li, t_ow,
                    interpret=interpret)
            else:
                greater, equal, _ = rank_step(
                    dparams, table, q, q_bias, bias_blocks, t_li, t_ow)
            ranks.append(mean_rank(np.asarray(greater), np.asarray(equal)))
        else:
            # ogbl list protocol: true-tail rows through the same sharded
            # gather (bitwise the dense emb[t] rows), candidate ids
            # scattered by owning row block
            t_emb = gather_rows(t_li, t_ow)
            c_true, cb_true = dec.prepare_candidates(dparams, t_emb)
            true_score = apply_epilogue(
                jnp.sum(q * c_true, axis=1) + q_bias + cb_true,
                dec.epilogue)
            cand = np.asarray(candidates[lo: lo + batch_size])
            if mesh is None:
                c_li, c_ow = plan_local_gather(layout, cand)   # (S, B, C)
                c_li, c_ow = jnp.asarray(c_li), jnp.asarray(c_ow)
            else:
                shape = (num_shards,) + cand.shape
                plans = {}      # memo: both callbacks share one plan build

                def plan(s):
                    if s not in plans:
                        plans[s] = plan_local_gather_block(layout, cand, s)
                    return plans[s]

                c_li = _model_axis_put(shape, lambda s: plan(s)[0],
                                       mesh, model_axis)
                c_ow = _model_axis_put(shape, lambda s: plan(s)[1],
                                       mesh, model_axis)
            if rank_step is None:
                greater, equal = sharded_candidate_rank_counts(
                    dec, dparams, table, q, q_bias, c_li, c_ow, true_score)
            else:
                greater, equal = rank_step(
                    dparams, table, q, q_bias, c_li, c_ow, true_score)
            # candidates exclude the true tail, so no self-tie to discount
            ranks.append(mean_rank(np.asarray(greater),
                                   np.asarray(equal) + 1))

    return metrics_from_ranks(np.concatenate(ranks), hits_ks)
