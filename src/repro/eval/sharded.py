"""Candidate-axis-sharded filtered ranking over the row-sharded entity table.

Dense ``ranking_metrics`` scores every test query against the full ``(N, d)``
entity matrix on one device — the last single-device assumption in the
system once training stores the entity table row-sharded over the ``model``
mesh axis (``repro.sharding.embedding``).  This module shards the *candidate*
axis of evaluation along the same row blocks, for EVERY registered decoder
(``repro.models.decoders``) via the canonical query form:

    per model shard s (owning table rows [s·rows, (s+1)·rows)):
        q, q_bias = decoder.prepare_query(...)        (replicated, computed
                                                       once per batch)
        C'_s, c_bias_s = decoder.prepare_candidates(table_s)   (row-local)
                  ──►  Pallas kge_score kernel against ONLY the shard's
                       rows (+ per-shard filter-bias block, -inf on pads)
                  ──►  partial counts   greater_s = #{score > true}
                                        equal_s   = #{score == true}
                       (true score: the owning shard's kernel row, masked)
    global rank = 1 + psum(greater_s) + 0.5 · (psum(equal_s) − 1)

The exchange is integer (candidate counts) plus one one-hot float (the true
score, owned by exactly one shard), so the sharded rank is EXACTLY the dense
rank — not approximately: candidate preparation is row-local, each
per-candidate score is the same ``d``-length MXU dot + elementwise epilogue
the dense kernel computes, only tiled per shard, and the count psum is
order-free.  ``tests/test_decoders.py`` enforces identical MRR/Hits@k
(``==``, not allclose) at 1/2/4 shards for every registered decoder,
including ties and padded rows.

Two execution paths, mirroring ``sharded_gather``:

* ``axis_name=None`` — masked single-device simulation: the full
  ``(S, rows, d)`` stack is looped shard-by-shard and partials summed.
* ``axis_name="model"`` — inside ``shard_map``: each device holds its
  ``(1, rows, d)`` row block and ``(1, B, rows)`` bias block; partials are
  ``jax.lax.psum``'d over the model axis (``make_sharded_rank_step``).

Head/query embeddings are fetched through the PR-2 ``sharded_gather``
exchange — ranking never materializes the dense entity matrix.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import kge_score_padded
from repro.models.decoders import Decoder, get_decoder
from repro.sharding.embedding import (
    ShardedTableLayout, plan_local_gather, shard_bias_blocks, shard_table,
    sharded_gather,
)


def _shard_scores(decoder: Decoder, dec_params, table_block, q, q_bias,
                  bias_block, interpret):
    """One shard's (B, rows) kernel scores: row-local candidate preparation
    of the shard's own table block + the shared query rows."""
    cand, c_bias = decoder.prepare_candidates(dec_params, table_block)
    return kge_score_padded(q, cand, bias_block, q_bias, c_bias,
                            epilogue=decoder.epilogue, interpret=interpret)


def sharded_rank_counts(
    decoder: Union[str, Decoder],
    dec_params: Dict[str, Any],  # decoder params (replicated)
    table: jax.Array,        # (S, rows, d) sim / (1, rows, d) per device
    q: jax.Array,            # (B, d) prepared query rows (replicated)
    q_bias: jax.Array,       # (B,) pre-epilogue query bias (replicated)
    bias: jax.Array,         # (S, B, rows) sim / (1, B, rows) per device
    true_local: jax.Array,   # (S, B) true-tail local row per shard
    true_owned: jax.Array,   # (S, B) which shard owns each true tail
    *,
    axis_name: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-query global rank counts from shard-local kernel scores.

    Returns ``(greater, equal, true_score)``: ``greater``/``equal`` are the
    global candidate counts vs the true score (``equal`` INCLUDES the true
    candidate's own self-tie; callers discount it via ``mean_rank``), and
    ``true_score`` the reconstructed true-tail score.  The true score is
    extracted from the owning shard's kernel output row — not recomputed
    with a separate dot — so it is bit-identical to the dense kernel's
    ``scores[b, t]`` and the ``>``/``==`` comparisons agree with the dense
    path even at exact ties.  ``bias`` must be ``-inf`` on layout-padded
    rows (``shard_bias_blocks``), which zeroes their count contribution for
    both epilogue families.
    """
    decoder = get_decoder(decoder)
    b = q.shape[0]
    rows_idx = jnp.arange(b)

    if axis_name is None:
        # masked single-device simulation over the full shard stack
        scores = [_shard_scores(decoder, dec_params, table[s], q, q_bias,
                                bias[s], interpret)
                  for s in range(table.shape[0])]
        true_score = sum(
            jnp.where(true_owned[s], scores[s][rows_idx, true_local[s]], 0.0)
            for s in range(table.shape[0]))
        greater = sum(
            jnp.sum((sc > true_score[:, None]).astype(jnp.int32), axis=1)
            for sc in scores)
        equal = sum(
            jnp.sum((sc == true_score[:, None]).astype(jnp.int32), axis=1)
            for sc in scores)
        return greater, equal, true_score

    if table.shape[0] != 1:
        # same trap as sharded_gather: a replicated (S, rows, d) stack
        # inside shard_map would score shard 0's rows everywhere and psum
        # S wrong partial counts — fail at trace time instead
        raise ValueError(
            f"sharded_rank_counts under shard_map expects this device's "
            f"(1, rows, d) row block, got {table.shape} — shard the table "
            f"and bias over {axis_name!r}")
    s = jax.lax.axis_index(axis_name)
    scores = _shard_scores(decoder, dec_params, table[0], q, q_bias,
                           bias[0], interpret)
    true_score = jax.lax.psum(
        jnp.where(true_owned[s], scores[rows_idx, true_local[s]], 0.0),
        axis_name)
    greater = jax.lax.psum(
        jnp.sum((scores > true_score[:, None]).astype(jnp.int32), axis=1),
        axis_name)
    equal = jax.lax.psum(
        jnp.sum((scores == true_score[:, None]).astype(jnp.int32), axis=1),
        axis_name)
    return greater, equal, true_score


def make_sharded_rank_step(mesh, *, decoder: Union[str, Decoder] = "distmult",
                           model_axis: str = "model",
                           interpret: Optional[bool] = None):
    """Build the jitted ``shard_map`` rank-count step for a real mesh.

    The entity-table row blocks and per-shard bias blocks are sharded over
    ``model_axis`` (one block per device — the layouts ``kge_param_specs``
    prescribes); queries, query bias, gather plans and the decoder's own
    params are replicated.  ``decoder`` is jit-static (a registry name or
    frozen Decoder singleton).  Returns ``step(dec_params, table, q, q_bias,
    bias, true_local, true_owned) -> (greater, equal, true_score)`` with
    globally psum'd outputs, exactly equal to the ``axis_name=None``
    simulation.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dec = get_decoder(decoder)

    def body(dec_params, table, q, q_bias, bias, true_local, true_owned):
        return sharded_rank_counts(
            dec, dec_params, table, q, q_bias, bias, true_local, true_owned,
            axis_name=model_axis, interpret=interpret)

    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(model_axis), P(), P(), P(model_axis), P(), P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    step = jax.jit(sharded)
    # tag so sharded_ranking_metrics can fail fast on a step built with a
    # DIFFERENT decoder than the queries were prepared with (the scores
    # would be silently wrong, not shape-mismatched)
    step.decoder = dec
    return step


def sharded_ranking_metrics(
    entity_emb: np.ndarray,          # (N, d) encoded entity embeddings
    decoder_params: Dict[str, Any],  # decoder parameter tree
    test_triplets: np.ndarray,       # (T, 3) global ids
    filter_index,                    # CSRFilterIndex or dict reference
    num_shards: int,
    hits_ks: Sequence[int] = (1, 3, 10),
    batch_size: int = 256,
    decoder: Union[str, Decoder] = "distmult",
    rank_step=None,
    interpret: Optional[bool] = None,
) -> Dict[str, float]:
    """Filtered MRR / Hits@k with candidate-axis-sharded ranking — the
    ``num_shards > 1`` twin of the dense ``ranking_metrics`` (any registered
    decoder, all-entities protocol), returning exactly the same metrics.

    The entity table is row-sharded once (``shard_table``); per test batch
    the host builds the (B, N) filter bias (CSR scatter), splits it into
    per-shard blocks, plans the head gather and true-tail ownership with the
    PR-2 ``plan_local_gather``, and the device computes per-shard partial
    counts from the decoder's query form.  ``rank_step`` switches the
    compute path: ``None`` runs the single-device shard-loop simulation; a
    ``make_sharded_rank_step`` product (built with the SAME decoder) runs
    the real ``shard_map`` + psum exchange.
    """
    from repro.eval.ranking import _filter_bias, mean_rank, \
        metrics_from_ranks

    dec = get_decoder(decoder)
    step_dec = getattr(rank_step, "decoder", None)
    if step_dec is not None and step_dec != dec:
        raise ValueError(
            f"rank_step was built for decoder {step_dec.name!r} but ranking "
            f"runs {dec.name!r} — rebuild with make_sharded_rank_step"
            f"(mesh, decoder={dec.name!r}) (a mismatched step would score "
            f"silently wrong, not shape-mismatch)")
    n, d = entity_emb.shape
    layout = ShardedTableLayout(n, num_shards)
    table = jnp.asarray(shard_table(
        np.ascontiguousarray(np.asarray(entity_emb, np.float32)), layout))
    dparams = jax.tree_util.tree_map(jnp.asarray, decoder_params)
    ranks = []

    for lo in range(0, test_triplets.shape[0], batch_size):
        batch = np.asarray(test_triplets[lo: lo + batch_size])
        # head embeddings through the PR-2 shard-local gather + exchange —
        # bitwise equal to the dense emb[batch[:, 0]] gather
        h_li, h_ow = plan_local_gather(layout, batch[:, 0])
        h_s = sharded_gather(table, jnp.asarray(h_li), jnp.asarray(h_ow))
        rel = jnp.asarray(batch[:, 1].astype(np.int32))
        q, q_bias = dec.prepare_query(dparams, h_s, rel)

        bias = _filter_bias(filter_index, batch, n)
        bias_blocks = jnp.asarray(shard_bias_blocks(bias, layout))
        t_li, t_ow = plan_local_gather(layout, batch[:, 2])
        t_li, t_ow = jnp.asarray(t_li), jnp.asarray(t_ow)

        if rank_step is None:
            greater, equal, _ = sharded_rank_counts(
                dec, dparams, table, q, q_bias, bias_blocks, t_li, t_ow,
                interpret=interpret)
        else:
            greater, equal, _ = rank_step(
                dparams, table, q, q_bias, bias_blocks, t_li, t_ow)
        ranks.append(mean_rank(np.asarray(greater), np.asarray(equal)))

    return metrics_from_ranks(np.concatenate(ranks), hits_ks)
