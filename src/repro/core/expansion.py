"""Neighborhood expansion (paper §3.2.2): make partitions self-sufficient.

An ``n``-layer GNN needs, for every vertex it must embed, the full ``n``-hop
in-neighborhood.  After vertex-cut partitioning some of that neighborhood
lives in other partitions ("boundary edges").  Expansion copies the missing
*support vertices* and *support edges* into the partition so that training
NEVER communicates neighbor state across partitions — the paper's central
self-sufficiency invariant.

Message-passing convention (matches ``repro.models.rgcn``): an edge
``(s, r, t)`` carries ``h_t`` into the update of ``h_s``; i.e. the in-edges of
a vertex ``v`` are the edges with ``src == v``.  Inverse relations are added
upstream (``KnowledgeGraph.with_inverse_relations``) so information flows both
ways, exactly as RGCN does.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.graph import KnowledgeGraph
from repro.core.partition import EdgePartition, core_vertices


@dataclasses.dataclass
class SelfSufficientPartition:
    """A localized, self-sufficient partition.

    All arrays use LOCAL vertex ids ``0..num_local_vertices-1``;
    ``local_to_global`` maps back.  Core entities come first in the local id
    space (``local id < num_core_vertices`` ⇔ core vertex) which makes the
    constraint-based negative sampler a plain ``randint``.
    """

    # Local message-passing graph (core + support edges).
    src: np.ndarray          # (E_loc,) int32 local ids
    rel: np.ndarray          # (E_loc,) int32
    dst: np.ndarray          # (E_loc,) int32 local ids
    # Which local edges are core (positive training edges).
    core_edge_mask: np.ndarray  # (E_loc,) bool
    # Id maps.
    local_to_global: np.ndarray  # (V_loc,) int64
    num_core_vertices: int
    num_core_edges: int
    # Provenance.
    partition_id: int = 0
    num_hops: int = 2

    @property
    def num_local_vertices(self) -> int:
        return int(self.local_to_global.shape[0])

    @property
    def num_local_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_support_edges(self) -> int:
        return self.num_local_edges - self.num_core_edges

    def core_edges_local(self) -> np.ndarray:
        """(E_core, 3) local-id (s, r, t) of positive training triplets."""
        m = self.core_edge_mask
        return np.stack([self.src[m], self.rel[m], self.dst[m]], axis=1)


def expand_partition(
    kg: KnowledgeGraph,
    part: EdgePartition,
    num_hops: int,
    partition_id: int = 0,
) -> SelfSufficientPartition:
    """Expand one partition to include the ``num_hops``-hop in-neighborhood
    of every core vertex (paper §3.2.2, Fig. 4)."""
    core_v = core_vertices(kg, part)

    needed = np.zeros(kg.num_edges, dtype=bool)
    needed[part.core_edge_ids] = True

    frontier = core_v
    for _ in range(num_hops):
        in_eids = kg.in_edges(frontier)          # edges with src in frontier
        new = in_eids[~needed[in_eids]]
        if new.size == 0:
            break
        needed[new] = True
        frontier = np.unique(kg.dst[new])

    all_eids = np.nonzero(needed)[0]
    src_g = kg.src[all_eids]
    rel_g = kg.rel[all_eids]
    dst_g = kg.dst[all_eids]
    core_mask = np.zeros(kg.num_edges, dtype=bool)
    core_mask[part.core_edge_ids] = True
    core_edge_mask = core_mask[all_eids]

    # Local id space: core vertices first (stable order), then supports.
    support_v = np.setdiff1d(
        np.unique(np.concatenate([src_g, dst_g])), core_v, assume_unique=False)
    local_to_global = np.concatenate([core_v, support_v]).astype(np.int64)
    g2l = np.full(kg.num_entities, -1, dtype=np.int64)
    g2l[local_to_global] = np.arange(local_to_global.shape[0])

    return SelfSufficientPartition(
        src=g2l[src_g].astype(np.int32),
        rel=rel_g.astype(np.int32),
        dst=g2l[dst_g].astype(np.int32),
        core_edge_mask=core_edge_mask,
        local_to_global=local_to_global,
        num_core_vertices=int(core_v.shape[0]),
        num_core_edges=int(part.core_edge_ids.shape[0]),
        partition_id=partition_id,
        num_hops=num_hops,
    )


def expand_all(
    kg: KnowledgeGraph,
    parts: Sequence[EdgePartition],
    num_hops: int,
) -> List[SelfSufficientPartition]:
    return [
        expand_partition(kg, p, num_hops, partition_id=i)
        for i, p in enumerate(parts)
    ]


# ====================================================================== #
# Fixed-shape padding for SPMD execution
# ====================================================================== #
@dataclasses.dataclass
class PaddedPartitionBatch:
    """All partitions padded to common (V_max, E_max) and stacked on a
    leading trainer axis — the array the ``data`` mesh axis shards.

    Padded vertices map to a sink row (embedding row V_max-1 is real but
    masked); padded edges have ``edge_mask == False`` and src=dst=0, rel=0 so
    gathers stay in range.
    """

    src: np.ndarray              # (P, E_max) int32
    rel: np.ndarray              # (P, E_max) int32
    dst: np.ndarray              # (P, E_max) int32
    edge_mask: np.ndarray        # (P, E_max) bool   — real message edges
    core_edge_mask: np.ndarray   # (P, E_max) bool   — real AND core
    local_to_global: np.ndarray  # (P, V_max) int64  — padded with 0
    vertex_mask: np.ndarray      # (P, V_max) bool
    num_core_vertices: np.ndarray  # (P,) int32
    num_core_edges: np.ndarray     # (P,) int32

    @property
    def num_partitions(self) -> int:
        return int(self.src.shape[0])

    @property
    def padded_vertices(self) -> int:
        return int(self.local_to_global.shape[1])

    @property
    def padded_edges(self) -> int:
        return int(self.src.shape[1])

    def padding_waste(self) -> float:
        """Fraction of padded edge slots that are padding — the SPMD analogue
        of GPU straggler time (see DESIGN.md §2)."""
        return 1.0 - float(self.edge_mask.mean())


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_partitions(
    parts: Sequence[SelfSufficientPartition],
    edge_align: int = 128,
    vertex_align: int = 8,
    max_vertices: Optional[int] = None,
    max_edges: Optional[int] = None,
) -> PaddedPartitionBatch:
    """Pad every partition to shared maxima (128-aligned edges: the Pallas
    kernels tile edges in blocks of 128 for the MXU)."""
    v_max = max(p.num_local_vertices for p in parts)
    e_max = max(p.num_local_edges for p in parts)
    v_max = _round_up(max(v_max, max_vertices or 0), vertex_align)
    e_max = _round_up(max(e_max, max_edges or 0), edge_align)
    n = len(parts)

    out = PaddedPartitionBatch(
        src=np.zeros((n, e_max), np.int32),
        rel=np.zeros((n, e_max), np.int32),
        dst=np.zeros((n, e_max), np.int32),
        edge_mask=np.zeros((n, e_max), bool),
        core_edge_mask=np.zeros((n, e_max), bool),
        local_to_global=np.zeros((n, v_max), np.int64),
        vertex_mask=np.zeros((n, v_max), bool),
        num_core_vertices=np.zeros(n, np.int32),
        num_core_edges=np.zeros(n, np.int32),
    )
    for i, p in enumerate(parts):
        e, v = p.num_local_edges, p.num_local_vertices
        out.src[i, :e] = p.src
        out.rel[i, :e] = p.rel
        out.dst[i, :e] = p.dst
        out.edge_mask[i, :e] = True
        out.core_edge_mask[i, :e] = p.core_edge_mask
        out.local_to_global[i, :v] = p.local_to_global
        out.vertex_mask[i, :v] = True
        out.num_core_vertices[i] = p.num_core_vertices
        out.num_core_edges[i] = p.num_core_edges
    return out


def verify_self_sufficiency(
    kg: KnowledgeGraph, part: SelfSufficientPartition,
) -> bool:
    """Invariant check (used by property tests): every vertex reachable in
    ``num_hops`` message-passing steps from a core vertex has ALL its
    in-edges of the remaining depth present locally.

    Concretely: for hop d = 0..n-1, every global in-edge of every vertex at
    BFS depth d from the core set must be a local edge."""
    local_edges = set(
        zip(part.local_to_global[part.src].tolist(),
            part.rel.tolist(),
            part.local_to_global[part.dst].tolist())
    )
    frontier = set(part.local_to_global[:part.num_core_vertices].tolist())
    for _ in range(part.num_hops):
        next_frontier = set()
        fr = np.fromiter(frontier, dtype=np.int64) if frontier else \
            np.zeros(0, np.int64)
        eids = kg.in_edges(fr)
        for eid in eids:
            trip = (int(kg.src[eid]), int(kg.rel[eid]), int(kg.dst[eid]))
            if trip not in local_edges:
                return False
            next_frontier.add(trip[2])
        frontier = next_frontier
    return True
