"""Edge mini-batch training support (paper §3.3.2, Fig. 5, Algorithm 1).

Per epoch (Algorithm 1):
  1. ``negativeSampler(gPartition)`` — sample ``s`` negatives per core edge
     from the partition's core vertices (host numpy: cheap integer work).
  2. Batch over positive+negative edges.
  3. ``getComputeGraph(batch, gPartition)`` — the n-hop computational graph of
     the batch endpoints, so every embedding needed to score the batch can be
     computed locally.

TPU adaptation (DESIGN.md §2): DGL materializes a fresh dynamic sub-graph per
batch; XLA needs static shapes.  ``getComputeGraph`` therefore runs on host
and emits FIXED-SHAPE padded index arrays (budgets = measured maxima, 128-
aligned).  The device step is one SPMD program; the host builder is cheap and
overlappable — the paper's Fig. 6 shows this component dominating on their
stack, our split moves it off the device critical path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.core.expansion import SelfSufficientPartition


# ====================================================================== #
# Host-side negative sampling (Algorithm 1 line 3)
# ====================================================================== #
def sample_epoch_negatives(
    rng: np.random.Generator,
    part: SelfSufficientPartition,
    num_negatives: int,
    sampler: str = "constraint",
) -> np.ndarray:
    """Negatives for one epoch: corrupt head or tail of each core edge with a
    uniform draw from the partition's CORE vertices (``constraint``, local ids
    [0, num_core_vertices)) or from ALL local vertices (``global`` — the
    closed-world ablation restricted to the partition's address space, the
    same restriction ``fullgraph_loss`` applies).  Returns (E_core * s, 3)
    int32."""
    if sampler not in ("constraint", "global"):
        raise ValueError(f"unknown negative sampler {sampler!r}")
    pos = part.core_edges_local()
    e = pos.shape[0]
    s = num_negatives
    if e == 0 or s == 0:
        return np.zeros((0, 3), np.int32)
    hi = part.num_core_vertices if sampler == "constraint" \
        else part.num_local_vertices
    pos_rep = np.repeat(pos, s, axis=0)
    corrupt_head = rng.random(e * s) < 0.5
    repl = rng.integers(0, max(hi, 1), size=e * s).astype(np.int32)
    neg = pos_rep.copy()
    neg[corrupt_head, 0] = repl[corrupt_head]
    neg[~corrupt_head, 2] = repl[~corrupt_head]
    return neg


# ====================================================================== #
# Computational graph construction (getComputeGraph)
# ====================================================================== #
class _PartitionCSR:
    """In-edge CSR over partition-local ids: for vertex v, the local edge ids
    with ``src == v`` (the edges feeding v's update)."""

    def __init__(self, part: SelfSufficientPartition):
        n = part.num_local_vertices
        order = np.argsort(part.src, kind="stable")
        self.sorted_eids = order.astype(np.int64)
        self.indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(np.bincount(part.src, minlength=n), out=self.indptr[1:])
        self.dst = part.dst

    def in_edges_of(self, vertices: np.ndarray) -> np.ndarray:
        """Concatenated in-edge spans of ``vertices`` (span order follows the
        input order).  Vectorized: one ``np.repeat``-based gather instead of a
        Python loop over per-vertex slices."""
        v = np.asarray(vertices, dtype=np.int64)
        if v.size == 0:
            return np.zeros(0, np.int64)
        starts = self.indptr[v]
        counts = self.indptr[v + 1] - starts
        total = int(counts.sum())
        if total == 0:
            return np.zeros(0, np.int64)
        # index i of the output belongs to the span whose cumulative start
        # offset was repeated into slot i; shift by the span's CSR start.
        out_offsets = np.cumsum(counts) - counts
        idx = (np.arange(total, dtype=np.int64)
               - np.repeat(out_offsets, counts)
               + np.repeat(starts, counts))
        return self.sorted_eids[idx]

    def in_edges_of_loop(self, vertices: np.ndarray) -> np.ndarray:
        """Reference implementation (per-vertex span loop) kept for the
        vectorization-equivalence tests."""
        if vertices.size == 0:
            return np.zeros(0, np.int64)
        spans = [
            self.sorted_eids[self.indptr[v]: self.indptr[v + 1]]
            for v in vertices
        ]
        return np.concatenate(spans) if spans else np.zeros(0, np.int64)


def build_comp_graph(
    part: SelfSufficientPartition,
    seed_vertices: np.ndarray,
    num_hops: int,
    csr: Optional[_PartitionCSR] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """n-hop computational graph of ``seed_vertices`` inside the partition.

    Returns (vertex_ids, edge_ids) — partition-local ids of every vertex and
    edge needed to embed the seeds with an ``num_hops``-layer GNN.
    """
    csr = csr or _PartitionCSR(part)
    need_edge = np.zeros(part.num_local_edges, dtype=bool)
    seen_v = np.zeros(part.num_local_vertices, dtype=bool)
    seeds = np.unique(np.asarray(seed_vertices, dtype=np.int64))
    seen_v[seeds] = True
    frontier = seeds
    for _ in range(num_hops):
        eids = csr.in_edges_of(frontier)
        eids = eids[~need_edge[eids]]
        if eids.size == 0:
            break
        need_edge[eids] = True
        nxt = np.unique(part.dst[eids].astype(np.int64))
        frontier = nxt[~seen_v[nxt]]
        seen_v[nxt] = True
    return np.nonzero(seen_v)[0], np.nonzero(need_edge)[0]


# ====================================================================== #
# Fixed-shape mini-batch
# ====================================================================== #
@dataclasses.dataclass
class EdgeMiniBatch:
    """One padded edge mini-batch.  All ids are BATCH-LOCAL; ``gather_ids``
    maps batch-local vertex ids to partition-local ids (for the embedding /
    feature gather)."""

    gather_ids: np.ndarray    # (V_b,) int32 partition-local vertex ids
    gather_global: np.ndarray  # (V_b,) int32 GLOBAL entity ids (for the
                               # shared embedding/feature table gather)
    vertex_mask: np.ndarray   # (V_b,) bool
    comp_src: np.ndarray      # (E_b,) int32 batch-local
    comp_rel: np.ndarray      # (E_b,) int32
    comp_dst: np.ndarray      # (E_b,) int32 batch-local
    comp_mask: np.ndarray     # (E_b,) bool
    triplets: np.ndarray      # (T_b, 3) int32 batch-local (s, r, t)
    labels: np.ndarray        # (T_b,) float32 1=positive 0=negative
    triplet_mask: np.ndarray  # (T_b,) bool


def _pad1(x: np.ndarray, n: int, fill=0) -> np.ndarray:
    out = np.full((n,) + x.shape[1:], fill, dtype=x.dtype)
    out[: x.shape[0]] = x[:n]
    return out


def build_edge_minibatch(
    part: SelfSufficientPartition,
    triplets: np.ndarray,       # (T, 3) partition-local
    labels: np.ndarray,         # (T,)
    num_hops: int,
    max_vertices: int,
    max_edges: int,
    max_triplets: int,
    csr: Optional[_PartitionCSR] = None,
) -> EdgeMiniBatch:
    """Build one padded mini-batch: comp graph over the triplet endpoints,
    relabeled to batch-local ids."""
    seeds = np.unique(triplets[:, [0, 2]].reshape(-1))
    verts, eids = build_comp_graph(part, seeds, num_hops, csr)
    if verts.shape[0] > max_vertices or eids.shape[0] > max_edges:
        raise ValueError(
            f"comp graph ({verts.shape[0]} v, {eids.shape[0]} e) exceeds "
            f"budget ({max_vertices} v, {max_edges} e); raise the budget "
            f"(measured maxima are auto-derived by plan_budgets)")
    # batch-local relabel
    p2b = np.full(part.num_local_vertices, -1, dtype=np.int64)
    p2b[verts] = np.arange(verts.shape[0])
    t = triplets.shape[0]
    bt = np.stack(
        [p2b[triplets[:, 0]], triplets[:, 1].astype(np.int64),
         p2b[triplets[:, 2]]], axis=1)
    assert (bt[:, [0, 2]] >= 0).all(), "triplet endpoint missing in comp graph"

    return EdgeMiniBatch(
        gather_ids=_pad1(verts.astype(np.int32), max_vertices),
        gather_global=_pad1(
            part.local_to_global[verts].astype(np.int32), max_vertices),
        vertex_mask=_pad1(np.ones(verts.shape[0], bool), max_vertices,
                          fill=False),
        comp_src=_pad1(p2b[part.src[eids]].astype(np.int32), max_edges),
        comp_rel=_pad1(part.rel[eids], max_edges),
        comp_dst=_pad1(p2b[part.dst[eids]].astype(np.int32), max_edges),
        comp_mask=_pad1(np.ones(eids.shape[0], bool), max_edges, fill=False),
        triplets=_pad1(bt.astype(np.int32), max_triplets),
        labels=_pad1(labels.astype(np.float32)[:max_triplets], max_triplets),
        triplet_mask=_pad1(np.ones(t, bool), max_triplets, fill=False),
    )


def _round_up(x: int, m: int) -> int:
    return max(m, ((x + m - 1) // m) * m)


@dataclasses.dataclass
class BatchBudget:
    max_vertices: int
    max_edges: int
    max_triplets: int


def negatives_of_positives(
    neg: np.ndarray, take: np.ndarray, num_negatives: int,
) -> np.ndarray:
    """Rows of the epoch negative table belonging to positive edges ``take``
    — the pairing ``iterate_edge_minibatches`` uses (``s`` consecutive rows
    per positive)."""
    if neg.shape[0] == 0:
        return np.zeros((0, 3), np.int32)
    rows = (take[:, None] * num_negatives +
            np.arange(num_negatives)[None, :]).reshape(-1)
    return neg[rows]


def plan_budgets(
    parts: Sequence[SelfSufficientPartition],
    batch_size: int,
    num_negatives: int,
    num_hops: int,
    seed: int = 0,
    probe_batches: int = 4,
    slack: float = 1.25,
    sampler: str = "constraint",
) -> BatchBudget:
    """Probe a few random batches per partition to size the fixed budgets
    (then add slack and 128-align).  This replaces DGL's dynamic allocation:
    budgets are a compile-time contract.

    Probes pair each sampled positive with ITS OWN epoch negatives (the
    ``s``-consecutive-rows pairing ``iterate_edge_minibatches`` uses), not
    with the first ``batch*s`` rows of the epoch table — the latter probes a
    different seed set than training ever builds and can under-measure the
    comp-graph budget."""
    rng = np.random.default_rng(seed)
    v_hi, e_hi = 1, 1
    t_hi = batch_size * (1 + num_negatives)
    for part in parts:
        csr = _PartitionCSR(part)
        pos = part.core_edges_local()
        neg = sample_epoch_negatives(rng, part, num_negatives, sampler)
        for _ in range(probe_batches):
            take = rng.choice(pos.shape[0],
                              size=min(batch_size, pos.shape[0]),
                              replace=False)
            batch_pos = pos[take]
            batch_neg = negatives_of_positives(neg, take, num_negatives)
            seeds = np.unique(
                np.concatenate([batch_pos[:, [0, 2]].reshape(-1),
                                batch_neg[:, [0, 2]].reshape(-1)]))
            verts, eids = build_comp_graph(part, seeds, num_hops, csr)
            v_hi = max(v_hi, verts.shape[0])
            e_hi = max(e_hi, eids.shape[0])
    return BatchBudget(
        max_vertices=_round_up(int(v_hi * slack), 8),
        max_edges=_round_up(int(e_hi * slack), 128),
        max_triplets=_round_up(t_hi, 128),
    )


def iterate_edge_minibatches(
    rng: np.random.Generator,
    part: SelfSufficientPartition,
    batch_size: int,
    num_negatives: int,
    num_hops: int,
    budget: BatchBudget,
    csr: Optional[_PartitionCSR] = None,
    sampler: str = "constraint",
) -> Iterator[EdgeMiniBatch]:
    """One epoch of Algorithm 1 on one partition: epoch negatives, shuffled
    positive batches, each with its ``s`` negatives and comp graph."""
    csr = csr or _PartitionCSR(part)
    pos = part.core_edges_local()
    e = pos.shape[0]
    neg = sample_epoch_negatives(rng, part, num_negatives, sampler)
    perm = rng.permutation(e)
    for lo in range(0, e, batch_size):
        take = perm[lo: lo + batch_size]
        batch_pos = pos[take]
        # negatives of these positives (s per positive, epoch-sampled)
        batch_neg = negatives_of_positives(neg, take, num_negatives)
        trip = np.concatenate([batch_pos, batch_neg], axis=0)
        labels = np.concatenate(
            [np.ones(batch_pos.shape[0], np.float32),
             np.zeros(batch_neg.shape[0], np.float32)])
        yield build_edge_minibatch(
            part, trip, labels, num_hops,
            budget.max_vertices, budget.max_edges, budget.max_triplets, csr)


def stack_minibatches(batches: Sequence[EdgeMiniBatch]) -> EdgeMiniBatch:
    """Stack one mini-batch per partition on a leading trainer axis — the
    array sharded over the ``data`` mesh axis in the SPMD step."""
    def s(name):
        return np.stack([getattr(b, name) for b in batches], axis=0)
    return EdgeMiniBatch(**{
        f.name: s(f.name) for f in dataclasses.fields(EdgeMiniBatch)})
