"""Knowledge-graph container used by the partitioning / training pipeline.

The graph lives on host as numpy arrays (the paper's preprocessing is an
offline CPU step); the device-side training step only ever sees fixed-shape
padded index arrays derived from it.

A knowledge graph is a set of triplets (s, r, t): head entity, relation type,
tail entity.  Entities and relations are dense int32 ids.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class KnowledgeGraph:
    """Immutable triplet store with adjacency indexes.

    Attributes:
      src:  (E,) int32 head entity per edge.
      rel:  (E,) int32 relation type per edge.
      dst:  (E,) int32 tail entity per edge.
      num_entities: N.
      num_relations: R (before adding inverse relations).
      features: optional (N, F) float32 input features; None => learned
        entity embeddings (transductive, like FB15k-237 in the paper).
    """

    src: np.ndarray
    rel: np.ndarray
    dst: np.ndarray
    num_entities: int
    num_relations: int
    features: Optional[np.ndarray] = None

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int32)
        self.rel = np.asarray(self.rel, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        if not (self.src.shape == self.rel.shape == self.dst.shape):
            raise ValueError("src/rel/dst must have identical shapes")
        self._csr: Optional[Tuple[np.ndarray, np.ndarray]] = None

    # ------------------------------------------------------------------ #
    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    def triplets(self) -> np.ndarray:
        """(E, 3) int32 array of (s, r, t)."""
        return np.stack([self.src, self.rel, self.dst], axis=1)

    # ------------------------------------------------------------------ #
    def with_inverse_relations(self) -> "KnowledgeGraph":
        """Add (t, r + R, s) for every (s, r, t) — standard RGCN practice so
        message passing flows both directions."""
        src = np.concatenate([self.src, self.dst])
        dst = np.concatenate([self.dst, self.src])
        rel = np.concatenate([self.rel, self.rel + self.num_relations])
        return KnowledgeGraph(
            src=src,
            rel=rel,
            dst=dst,
            num_entities=self.num_entities,
            num_relations=2 * self.num_relations,
            features=self.features,
        )

    # ------------------------------------------------------------------ #
    def degrees(self) -> np.ndarray:
        """(N,) total (in+out) degree."""
        deg = np.zeros(self.num_entities, dtype=np.int64)
        np.add.at(deg, self.src, 1)
        np.add.at(deg, self.dst, 1)
        return deg

    def _build_csr(self) -> Tuple[np.ndarray, np.ndarray]:
        """Undirected incidence CSR: for each vertex, the edge ids touching
        it.  Used by BFS-style neighborhood expansion."""
        if self._csr is not None:
            return self._csr
        e = self.num_edges
        endpoints = np.concatenate([self.src, self.dst])
        edge_ids = np.concatenate(
            [np.arange(e, dtype=np.int64), np.arange(e, dtype=np.int64)]
        )
        order = np.argsort(endpoints, kind="stable")
        sorted_v = endpoints[order]
        sorted_e = edge_ids[order]
        indptr = np.zeros(self.num_entities + 1, dtype=np.int64)
        counts = np.bincount(sorted_v, minlength=self.num_entities)
        np.cumsum(counts, out=indptr[1:])
        self._csr = (indptr, sorted_e)
        return self._csr

    def incident_edges(self, vertices: np.ndarray) -> np.ndarray:
        """Edge ids incident (as src OR dst) to any vertex in `vertices`."""
        indptr, sorted_e = self._build_csr()
        vertices = np.asarray(vertices, dtype=np.int64)
        if vertices.size == 0:
            return np.zeros(0, dtype=np.int64)
        spans = [sorted_e[indptr[v]: indptr[v + 1]] for v in vertices]
        if not spans:
            return np.zeros(0, dtype=np.int64)
        return np.unique(np.concatenate(spans))

    def in_edges(self, vertices: np.ndarray) -> np.ndarray:
        """Edge ids whose dst is in `vertices` (messages flow dst->src update
        in our convention: edge (s,r,t) carries h_t into h_s, i.e. an edge is
        an *in*-edge of its head s).  For expansion we need, for every vertex
        we must embed, the edges that feed it: edges with src == v."""
        vset = np.zeros(self.num_entities, dtype=bool)
        vset[np.asarray(vertices, dtype=np.int64)] = True
        return np.nonzero(vset[self.src])[0].astype(np.int64)

    # ------------------------------------------------------------------ #
    def subgraph(self, edge_ids: np.ndarray) -> "KnowledgeGraph":
        """Sub-KG on a subset of edges, KEEPING global entity ids."""
        edge_ids = np.asarray(edge_ids, dtype=np.int64)
        return KnowledgeGraph(
            src=self.src[edge_ids],
            rel=self.rel[edge_ids],
            dst=self.dst[edge_ids],
            num_entities=self.num_entities,
            num_relations=self.num_relations,
            features=self.features,
        )


def triplet_set(kg: KnowledgeGraph) -> set:
    """Set of (s, r, t) tuples — used by filtered evaluation."""
    return set(map(tuple, kg.triplets().tolist()))


def make_synthetic_kg(
    num_entities: int,
    num_relations: int,
    num_edges: int,
    seed: int = 0,
    feature_dim: Optional[int] = None,
    power: float = 1.2,
) -> KnowledgeGraph:
    """Synthetic KG with a skewed (Zipf-like) degree distribution — the paper
    highlights that enterprise KGs are skewed, which stresses partition
    balance; uniform random graphs would hide the effect."""
    rng = np.random.default_rng(seed)
    # Zipf-ish popularity over entities.
    w = 1.0 / np.arange(1, num_entities + 1, dtype=np.float64) ** power
    w /= w.sum()
    src = rng.choice(num_entities, size=num_edges, p=w).astype(np.int32)
    dst = rng.choice(num_entities, size=num_edges, p=w).astype(np.int32)
    # avoid self loops (re-draw once; leftovers shifted)
    loops = src == dst
    dst[loops] = (dst[loops] + 1 + rng.integers(0, num_entities - 1,
                                                loops.sum())) % num_entities
    rel = rng.integers(0, num_relations, size=num_edges).astype(np.int32)
    # dedupe triplets
    trip = np.unique(np.stack([src, rel, dst], axis=1), axis=0)
    features = None
    if feature_dim is not None:
        features = rng.normal(0, 1, (num_entities, feature_dim)).astype(
            np.float32)
    return KnowledgeGraph(
        src=trip[:, 0], rel=trip[:, 1], dst=trip[:, 2],
        num_entities=num_entities, num_relations=num_relations,
        features=features,
    )


def split_train_valid_test(
    kg: KnowledgeGraph, valid_frac: float = 0.05, test_frac: float = 0.05,
    seed: int = 0,
) -> Dict[str, KnowledgeGraph]:
    """Random triplet split in the FB15k-237 style."""
    rng = np.random.default_rng(seed)
    e = kg.num_edges
    perm = rng.permutation(e)
    n_valid = int(e * valid_frac)
    n_test = int(e * test_frac)
    valid_ids = perm[:n_valid]
    test_ids = perm[n_valid:n_valid + n_test]
    train_ids = perm[n_valid + n_test:]
    return {
        "train": kg.subgraph(train_ids),
        "valid": kg.subgraph(valid_ids),
        "test": kg.subgraph(test_ids),
    }
