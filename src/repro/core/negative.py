"""Negative sampling (paper §3.3.1).

Two samplers:

* ``constraint_based`` — the paper's: corrupt head or tail with entities drawn
  ONLY from the partition's *core vertices* (locally-closed-world).  Because
  the self-sufficient partition puts core vertices first in the local id
  space, the sampler is a shard-local ``randint(0, num_core_vertices)`` — no
  cross-partition traffic, no stale embeddings, smaller candidate space
  (harder negatives).
* ``global_closed_world`` — the classic baseline: corrupt with any entity in
  the full graph.  In a distributed setting this would require fetching
  remote embeddings; we implement it for the ablation (it is what DGL-KE/PBG
  style systems do) and to quantify the paper's claim.

Both are pure-JAX (device-side, jit/shard_map friendly).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def corrupt_triplets(
    key: jax.Array,
    triplets: jax.Array,          # (B, 3) int32 local (s, r, t)
    num_negatives: int,           # s in the paper
    candidate_limit: jax.Array,   # scalar int32: draw ids from [0, limit)
) -> Tuple[jax.Array, jax.Array]:
    """Generate ``num_negatives`` corruptions per positive.

    Returns (neg_triplets (B*s, 3), neg_is_head_corrupt (B*s,) bool).
    Each negative corrupts head OR tail (Bernoulli 0.5), replacing it with a
    uniform draw from ``[0, candidate_limit)``.
    """
    b = triplets.shape[0]
    s = num_negatives
    k_side, k_ent = jax.random.split(key)
    corrupt_head = jax.random.bernoulli(k_side, 0.5, (b, s))
    repl = jax.random.randint(
        k_ent, (b, s), 0, jnp.maximum(candidate_limit, 1), dtype=jnp.int32)

    pos = jnp.broadcast_to(triplets[:, None, :], (b, s, 3))
    neg_src = jnp.where(corrupt_head, repl, pos[..., 0])
    neg_dst = jnp.where(corrupt_head, pos[..., 2], repl)
    neg = jnp.stack([neg_src, pos[..., 1], neg_dst], axis=-1)
    return neg.reshape(b * s, 3), corrupt_head.reshape(b * s)


def constraint_based_negatives(
    key: jax.Array,
    triplets: jax.Array,
    num_negatives: int,
    num_core_vertices: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Paper's sampler: candidates = this partition's core vertices, which are
    local ids [0, num_core_vertices)."""
    return corrupt_triplets(key, triplets, num_negatives, num_core_vertices)


def global_closed_world_negatives(
    key: jax.Array,
    triplets: jax.Array,
    num_negatives: int,
    num_entities: int,
) -> Tuple[jax.Array, jax.Array]:
    """Baseline sampler over the full entity set (requires the full embedding
    table to be addressable — i.e. remote fetches in a partitioned system)."""
    return corrupt_triplets(
        key, triplets, num_negatives, jnp.int32(num_entities))


def mix_pos_neg(
    pos: jax.Array,                # (B, 3)
    neg: jax.Array,                # (B*s, 3)
) -> Tuple[jax.Array, jax.Array]:
    """Concatenate positives and negatives with 1/0 labels (paper Eq. 3:
    |T| = p * (s + 1) training examples)."""
    trip = jnp.concatenate([pos, neg], axis=0)
    labels = jnp.concatenate(
        [jnp.ones(pos.shape[0], jnp.float32),
         jnp.zeros(neg.shape[0], jnp.float32)], axis=0)
    return trip, labels
