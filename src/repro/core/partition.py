"""Graph partitioning strategies (paper §3.2.1, Table 5).

Three strategies, matching the paper's comparison:

* ``vertex_cut``  — the paper's choice (KaHIP-style edge partitioning).  We
  implement streaming HDRF [Petroni et al.] with a degree-aware tie-break
  (DBH): edges are assigned to partitions so that endpoint vertices are
  replicated as little as possible while edge counts stay balanced.  Produces
  DISJOINT edge sets ("core edges"); vertices on the cut are replicated.
* ``edge_cut``    — METIS-style baseline: vertices are clustered (greedy BFS
  region growing + label-propagation refinement), a partition's core edges
  are all edges incident to its vertices ⇒ cut edges are REPLICATED into
  multiple partitions (the paper's Fig. 4b pathology).
* ``random``      — random edge assignment (Table 5's worst case).

All partitioners run on host numpy; they are offline preprocessing exactly as
in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.graph import KnowledgeGraph


@dataclasses.dataclass
class EdgePartition:
    """One partition = a set of core edge ids (into the parent KG)."""

    core_edge_ids: np.ndarray  # (E_i,) int64, disjoint across partitions
                               # for vertex-cut/random; overlapping for
                               # edge-cut (replicated cut edges).

    def num_core_edges(self) -> int:
        return int(self.core_edge_ids.shape[0])


def core_vertices(kg: KnowledgeGraph, part: EdgePartition) -> np.ndarray:
    """Vertices touched by the partition's core edges."""
    e = part.core_edge_ids
    return np.unique(np.concatenate([kg.src[e], kg.dst[e]]))


# ====================================================================== #
# Vertex-cut: streaming HDRF / DBH hybrid
# ====================================================================== #
def vertex_cut_partition(
    kg: KnowledgeGraph,
    num_partitions: int,
    seed: int = 0,
    balance_slack: float = 1.05,
    hdrf_lambda: float = 1.0,
    chunk_size: int = 4096,
) -> List[EdgePartition]:
    """Greedy streaming vertex-cut (HDRF).

    For each edge (u, v) pick the partition p maximizing::

        C_rep(u,v,p) + lambda * (maxload - load_p) / (eps + maxload - minload)

    where C_rep rewards partitions already holding u or v, weighted towards
    the LOWER-degree endpoint (HDRF's "highest-degree replicated first":
    replicate hubs, keep tails whole).  Hard balance cap at
    ``balance_slack * E / P``.

    Chunked streaming: the replication-gain matrices for a whole block of
    ``chunk_size`` edges are scored with one numpy gather (endpoint degrees,
    theta weights, ``replicas`` rows); the sequential sweep inside a chunk
    only re-gathers the rows of vertices whose replica set changed since the
    chunk was scored ("dirty" rows).  Bitwise identical to the per-edge
    reference (``_vertex_cut_partition_loop``) — same IEEE op order per edge
    — while amortizing the Python/numpy dispatch overhead over the block.
    """
    p = num_partitions
    if p <= 0:
        raise ValueError("num_partitions must be >= 1")
    e = kg.num_edges
    if p == 1:
        return [EdgePartition(np.arange(e, dtype=np.int64))]

    rng = np.random.default_rng(seed)
    order = rng.permutation(e)
    deg = kg.degrees().astype(np.float64)

    # replica sets as bitmaps: (N, P) bool — fine for host preprocessing at
    # the scales we run; production would use hash sets per vertex.
    replicas = np.zeros((kg.num_entities, p), dtype=bool)
    dirty = np.zeros(kg.num_entities, dtype=bool)
    load = np.zeros(p, dtype=np.int64)
    cap = int(np.ceil(balance_slack * e / p))
    assign = np.empty(e, dtype=np.int32)
    lam = hdrf_lambda

    src, dst = kg.src, kg.dst
    for lo in range(0, e, chunk_size):
        chunk = order[lo: lo + chunk_size]
        us = src[chunk].astype(np.int64)
        vs = dst[chunk].astype(np.int64)
        du = deg[us]
        dv = deg[vs]
        theta_u = du / (du + dv + 1e-9)
        theta_v = 1.0 - theta_u
        # HDRF degree-weighted replication gain: +1 (+ bias towards the
        # smaller-degree endpoint) for each endpoint already present.
        w_u = 1.0 + (1.0 - theta_u)
        w_v = 1.0 + (1.0 - theta_v)
        g_u_blk = replicas[us] * w_u[:, None]     # (C, P) block score
        g_v_blk = replicas[vs] * w_v[:, None]
        dirty[us] = False                         # block rows are fresh
        dirty[vs] = False
        # maxload/minload tracked incrementally (only load[best] changes per
        # step) — same values as load.max()/load.min(), fewer reductions.
        maxload = int(load.max())
        minload = int(load.min())
        n_capped = int((load >= cap).sum())
        for j in range(chunk.shape[0]):
            u = us[j]
            v = vs[j]
            g_u = replicas[u] * w_u[j] if dirty[u] else g_u_blk[j]
            g_v = replicas[v] * w_v[j] if dirty[v] else g_v_blk[j]
            bal = lam * (maxload - load) / (1e-9 + maxload - minload + 1.0)
            score = g_u + g_v + bal
            if n_capped:
                score[load >= cap] = -np.inf
            best = int(np.argmax(score))
            assign[chunk[j]] = best
            old = int(load[best])
            load[best] = old + 1
            if old + 1 > maxload:
                maxload = old + 1
            if old == minload and not (load == minload).any():
                minload += 1          # load only ever grows by 1
            if old + 1 == cap:
                n_capped += 1
            if not replicas[u, best]:
                replicas[u, best] = True
                dirty[u] = True
            if not replicas[v, best]:
                replicas[v, best] = True
                dirty[v] = True

    return [
        EdgePartition(np.nonzero(assign == i)[0].astype(np.int64))
        for i in range(p)
    ]


def _vertex_cut_partition_loop(
    kg: KnowledgeGraph,
    num_partitions: int,
    seed: int = 0,
    balance_slack: float = 1.05,
    hdrf_lambda: float = 1.0,
) -> List[EdgePartition]:
    """Per-edge reference HDRF (the pre-vectorization implementation), kept
    for the chunked-equivalence tests."""
    p = num_partitions
    if p <= 0:
        raise ValueError("num_partitions must be >= 1")
    e = kg.num_edges
    if p == 1:
        return [EdgePartition(np.arange(e, dtype=np.int64))]

    rng = np.random.default_rng(seed)
    order = rng.permutation(e)
    deg = kg.degrees().astype(np.float64)

    replicas = np.zeros((kg.num_entities, p), dtype=bool)
    load = np.zeros(p, dtype=np.int64)
    cap = int(np.ceil(balance_slack * e / p))
    assign = np.empty(e, dtype=np.int32)

    src, dst = kg.src, kg.dst
    for eid in order:
        u, v = int(src[eid]), int(dst[eid])
        du, dv = deg[u], deg[v]
        theta_u = du / (du + dv + 1e-9)
        theta_v = 1.0 - theta_u
        g_u = replicas[u] * (1.0 + (1.0 - theta_u))
        g_v = replicas[v] * (1.0 + (1.0 - theta_v))
        maxload = load.max()
        minload = load.min()
        bal = hdrf_lambda * (maxload - load) / (1e-9 + maxload - minload + 1.0)
        score = g_u + g_v + bal
        score[load >= cap] = -np.inf
        best = int(np.argmax(score))
        assign[eid] = best
        load[best] += 1
        replicas[u, best] = True
        replicas[v, best] = True

    return [
        EdgePartition(np.nonzero(assign == i)[0].astype(np.int64))
        for i in range(p)
    ]


# ====================================================================== #
# Edge-cut: METIS-like vertex clustering baseline
# ====================================================================== #
def _vertex_clusters(
    kg: KnowledgeGraph, num_partitions: int, seed: int = 0,
    refine_iters: int = 3,
) -> np.ndarray:
    """Balanced vertex clustering: BFS region-growing from random seeds,
    followed by a few label-propagation refinement sweeps with a balance
    cap.  A stand-in for METIS (no external deps available offline)."""
    n = kg.num_entities
    p = num_partitions
    rng = np.random.default_rng(seed)
    label = -np.ones(n, dtype=np.int64)
    cap = int(np.ceil(1.05 * n / p))

    # adjacency (undirected) CSR over vertices
    u = np.concatenate([kg.src, kg.dst]).astype(np.int64)
    v = np.concatenate([kg.dst, kg.src]).astype(np.int64)
    order = np.argsort(u, kind="stable")
    u_s, v_s = u[order], v[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(u_s, minlength=n), out=indptr[1:])

    def neighbors(x: int) -> np.ndarray:
        return v_s[indptr[x]: indptr[x + 1]]

    # multi-source BFS
    seeds = rng.choice(n, size=p, replace=False)
    from collections import deque
    queues = [deque([int(s)]) for s in seeds]
    sizes = np.zeros(p, dtype=np.int64)
    for i, s in enumerate(seeds):
        label[s] = i
        sizes[i] += 1
    active = True
    while active:
        active = False
        for i in range(p):
            q = queues[i]
            grown = 0
            while q and grown < 64 and sizes[i] < cap:
                x = q.popleft()
                for y in neighbors(x):
                    if label[y] < 0 and sizes[i] < cap:
                        label[y] = i
                        sizes[i] += 1
                        q.append(int(y))
                        grown += 1
                active = active or bool(q)
            if grown:
                active = True
    # isolated / unreached vertices -> least-loaded partition
    for x in np.nonzero(label < 0)[0]:
        i = int(np.argmin(sizes))
        label[x] = i
        sizes[i] += 1

    # label propagation refinement (cut reduction) with balance cap
    for _ in range(refine_iters):
        for x in rng.permutation(n):
            nb = neighbors(int(x))
            if nb.size == 0:
                continue
            counts = np.bincount(label[nb], minlength=p)
            best = int(np.argmax(counts))
            cur = int(label[x])
            if best != cur and counts[best] > counts[cur] and \
                    sizes[best] < cap:
                label[x] = best
                sizes[best] += 1
                sizes[cur] -= 1
    return label


def edge_cut_partition(
    kg: KnowledgeGraph, num_partitions: int, seed: int = 0,
) -> List[EdgePartition]:
    """METIS-style baseline: core edges of partition i are ALL edges incident
    to a vertex labeled i (paper §4.5.5: "the first hop neighbors of vertices
    are the core edges").  Cut edges therefore appear in 2 partitions —
    the replication pathology of Fig. 4(b)."""
    label = _vertex_clusters(kg, num_partitions, seed)
    parts = []
    for i in range(num_partitions):
        verts = np.nonzero(label == i)[0]
        vmask = np.zeros(kg.num_entities, dtype=bool)
        vmask[verts] = True
        eids = np.nonzero(vmask[kg.src] | vmask[kg.dst])[0].astype(np.int64)
        parts.append(EdgePartition(eids))
    return parts


# ====================================================================== #
# Random edge partitioning
# ====================================================================== #
def random_partition(
    kg: KnowledgeGraph, num_partitions: int, seed: int = 0,
) -> List[EdgePartition]:
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, num_partitions, size=kg.num_edges)
    return [
        EdgePartition(np.nonzero(assign == i)[0].astype(np.int64))
        for i in range(num_partitions)
    ]


PARTITIONERS = {
    "vertex_cut": vertex_cut_partition,
    "edge_cut": edge_cut_partition,
    "random": random_partition,
}


def partition_graph(
    kg: KnowledgeGraph, num_partitions: int, strategy: str = "vertex_cut",
    seed: int = 0,
) -> List[EdgePartition]:
    if strategy not in PARTITIONERS:
        raise ValueError(
            f"unknown strategy {strategy!r}; choose from {sorted(PARTITIONERS)}")
    return PARTITIONERS[strategy](kg, num_partitions, seed=seed)


# ====================================================================== #
# Quality metrics (paper Eq. 7)
# ====================================================================== #
def replication_factor(
    kg: KnowledgeGraph, parts: Sequence[EdgePartition],
) -> float:
    """RF = (1/|V|) * sum_i |V(E_i)| over partitions (paper Eq. 7)."""
    total = 0
    for part in parts:
        total += core_vertices(kg, part).shape[0]
    return total / float(kg.num_entities)


def load_balance(parts: Sequence[EdgePartition]) -> float:
    """max/mean core-edge count — 1.0 is perfectly balanced."""
    sizes = np.array([p.num_core_edges() for p in parts], dtype=np.float64)
    return float(sizes.max() / (sizes.mean() + 1e-9))
