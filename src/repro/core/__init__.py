"""Paper core: self-sufficient partitions, constraint-based negative
sampling, edge mini-batch training (Sheikh et al., 2022)."""
from repro.core.graph import (
    KnowledgeGraph, make_synthetic_kg, split_train_valid_test, triplet_set,
)
from repro.core.partition import (
    EdgePartition, partition_graph, vertex_cut_partition, edge_cut_partition,
    random_partition, replication_factor, load_balance, core_vertices,
)
from repro.core.expansion import (
    SelfSufficientPartition, expand_partition, expand_all, pad_partitions,
    PaddedPartitionBatch, verify_self_sufficiency,
)
from repro.core.negative import (
    constraint_based_negatives, global_closed_world_negatives, mix_pos_neg,
    corrupt_triplets,
)
from repro.core.minibatch import (
    EdgeMiniBatch, BatchBudget, plan_budgets, build_comp_graph,
    build_edge_minibatch, iterate_edge_minibatches, stack_minibatches,
    sample_epoch_negatives,
)

__all__ = [n for n in dir() if not n.startswith("_")]
