"""Attention variants for the assigned architectures.

One GQA implementation covers MQA (kv=1, gemma/recurrentgemma), GQA
(glm4/qwen*), qk-norm (qwen3), QKV bias (qwen2.5/qwen2-vl), sliding windows
(recurrentgemma local attention, gemma long-context variant), M-RoPE
(qwen2-vl) and cross-attention (whisper).  DeepSeek's MLA (multi-head latent
attention, compressed KV cache) is its own pair of functions.

Shapes: activations (B, S, d); caches (B, S_max, H_kv, hd) — batch-major so
the decode cache shards over (data=batch, model=sequence) per DESIGN.md §5.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import (
    apply_m_rope, apply_rope, dense_init, rmsnorm, rmsnorm_params, softcap,
)


# ====================================================================== #
# GQA family
# ====================================================================== #
def attn_params(key: jax.Array, d: int, num_heads: int, num_kv_heads: int,
                head_dim: int, *, qkv_bias: bool = False,
                qk_norm: bool = False, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], d, num_heads * head_dim, dtype),
        "w_k": dense_init(ks[1], d, num_kv_heads * head_dim, dtype),
        "w_v": dense_init(ks[2], d, num_kv_heads * head_dim, dtype),
        "w_o": dense_init(ks[3], num_heads * head_dim, d, dtype),
    }
    if qkv_bias:
        p["b_q"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["b_k"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["b_v"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    if qk_norm:
        p["q_norm"] = rmsnorm_params(head_dim, dtype)
        p["k_norm"] = rmsnorm_params(head_dim, dtype)
    return p


def _project_qkv(p: Dict, x: jax.Array, num_heads: int, num_kv_heads: int,
                 head_dim: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    q = x @ p["w_q"]
    k = x @ p["w_k"]
    v = x @ p["w_v"]
    if "b_q" in p:
        q = q + p["b_q"]
        k = k + p["b_k"]
        v = v + p["b_v"]
    q = q.reshape(b, s, num_heads, head_dim)
    k = k.reshape(b, s, num_kv_heads, head_dim)
    v = v.reshape(b, s, num_kv_heads, head_dim)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    return q, k, v


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array], *, logit_cap: Optional[float] = None,
          ) -> jax.Array:
    """q (B,Sq,H,hd); k/v (B,Sk,Hkv,hd); GQA by head-group broadcast.
    mask broadcastable to (B, H, Sq, Sk), True = attend."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    scores = softcap(scores, logit_cap)
    if mask is not None:
        m = jnp.broadcast_to(mask, (b, h, sq, scores.shape[-1])) \
            .reshape(b, hkv, group, sq, -1)
        scores = jnp.where(m, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h * hd).astype(q.dtype)


MEA_MIN_SEQ = 2048    # use chunked online-softmax attention at/above this
MEA_Q_CHUNK = 1024
MEA_K_CHUNK = 1024


def _mea(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
         window: Optional[int], logit_cap: Optional[float] = None,
         q_chunk: int = MEA_Q_CHUNK, k_chunk: int = MEA_K_CHUNK
         ) -> jax.Array:
    """Memory-efficient attention: lax.scan over query blocks × key blocks
    with online softmax (flash-attention scheduling in pure JAX).  Temp
    memory is O(q_chunk · k_chunk) instead of O(S²) — this is what lets the
    train_4k/prefill_32k dry-runs fit HBM (EXPERIMENTS.md §Perf notes the
    XLA-materialized S² baseline it replaced)."""
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    vd = v.shape[-1]          # may differ from hd (MLA)
    g = h // hkv
    assert sq % q_chunk == 0 and sk % k_chunk == 0, (sq, sk)
    nq, nk = sq // q_chunk, sk // k_chunk
    scale = hd ** -0.5

    qb = jnp.moveaxis(
        q.reshape(b, nq, q_chunk, hkv, g, hd), 1, 0)     # (nq,b,qc,hkv,g,hd)
    kb = jnp.moveaxis(k.reshape(b, nk, k_chunk, hkv, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, k_chunk, hkv, vd), 1, 0)

    i_q = jnp.arange(q_chunk)
    i_k = jnp.arange(k_chunk)

    def q_body(_, xs):
        qi, q_blk = xs
        q32 = q_blk.astype(jnp.float32)

        def k_body(carry, kxs):
            m, l, acc = carry
            ki, k_blk, v_blk = kxs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q32,
                           k_blk.astype(jnp.float32)) * scale
            s = softcap(s, logit_cap)
            rows = qi * q_chunk + i_q                     # global q index
            cols = ki * k_chunk + i_k
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= cols[None, :] <= rows[:, None]
            if window is not None:
                mask &= cols[None, :] > rows[:, None] - window
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, vd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_body, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (b,hkv,g,qc,vd)
        out = jnp.moveaxis(out, 3, 1).reshape(b, q_chunk, h * vd)
        return None, out

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), qb))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h * vd).astype(q.dtype)


def causal_mask(sq: int, sk: int, window: Optional[int] = None) -> jax.Array:
    i = jnp.arange(sq)[:, None]
    j = jnp.arange(sk)[None, :]
    m = j <= i
    if window is not None:
        m = m & (j > i - window)
    return m[None, None]   # (1, 1, Sq, Sk)


def attention(
    p: Dict, x: jax.Array, *,
    num_heads: int, num_kv_heads: int, head_dim: int,
    positions: jax.Array,                 # (B, S) or (B, S, 3) for m_rope
    rope_base: float = 10000.0,
    m_rope: bool = False,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim)
    if m_rope:
        q = apply_m_rope(q, positions, rope_base)
        k = apply_m_rope(k, positions, rope_base)
    else:
        q = apply_rope(q, positions, rope_base)
        k = apply_rope(k, positions, rope_base)
    s = x.shape[1]
    if s >= MEA_MIN_SEQ and s % MEA_Q_CHUNK == 0:
        out = _mea(q, k, v, causal=causal, window=window,
                   logit_cap=logit_cap)
    else:
        mask = causal_mask(s, s, window) if causal else None
        out = _sdpa(q, k, v, mask, logit_cap=logit_cap)
    return out @ p["w_o"]


def attention_decode(
    p: Dict, x: jax.Array, cache: Dict[str, jax.Array], pos: jax.Array, *,
    num_heads: int, num_kv_heads: int, head_dim: int,
    rope_base: float = 10000.0,
    m_rope: bool = False,
    positions_3d: Optional[jax.Array] = None,   # (B, 1, 3) for m_rope
    window: Optional[jax.Array | int] = None,
    logit_cap: Optional[float] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode: write k/v at ``pos`` into the cache, attend over the
    valid prefix.  x (B, 1, d); cache k/v (B, S_max, Hkv, hd); pos (B,)."""
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim)
    if m_rope:
        q = apply_m_rope(q, positions_3d, rope_base)
        k = apply_m_rope(k, positions_3d, rope_base)
    else:
        q = apply_rope(q, pos[:, None], rope_base)
        k = apply_rope(k, pos[:, None], rope_base)

    def write(buf, val):
        # per-batch dynamic row write at pos
        return jax.vmap(
            lambda bb, vv, pp: jax.lax.dynamic_update_slice_in_dim(
                bb, vv, pp, axis=0))(buf, val, pos)

    k_cache = write(cache["k"], k)
    v_cache = write(cache["v"], v)
    s_max = k_cache.shape[1]
    j = jnp.arange(s_max)[None, :]                  # (1, S)
    valid = j <= pos[:, None]
    if window is not None:
        valid = valid & (j > pos[:, None] - window)
    mask = valid[:, None, None, :]                  # (B, 1, 1, S)
    out = _sdpa(q, k_cache, v_cache, mask, logit_cap=logit_cap)
    return out @ p["w_o"], {"k": k_cache, "v": v_cache}


def cross_attention(
    p: Dict, x: jax.Array, kv_source: jax.Array, *,
    num_heads: int, num_kv_heads: int, head_dim: int,
    cached_kv: Optional[Dict[str, jax.Array]] = None,
) -> jax.Array:
    """Whisper-style encoder-decoder cross attention (no positions on k/v —
    whisper uses learned positions upstream; none needed here).

    ``cached_kv`` (§Perf): decode recomputes K/V from the 1500-frame encoder
    output on EVERY token step × every layer otherwise; the serving cache
    precomputes them once per request (``cross_kv_cache``)."""
    b, s, _ = x.shape
    q = (x @ p["w_q"]).reshape(b, s, num_heads, head_dim)
    if cached_kv is not None:
        k, v = cached_kv["k"], cached_kv["v"]
    else:
        se = kv_source.shape[1]
        k = (kv_source @ p["w_k"]).reshape(b, se, num_kv_heads, head_dim)
        v = (kv_source @ p["w_v"]).reshape(b, se, num_kv_heads, head_dim)
    out = _sdpa(q, k, v, None)
    return out @ p["w_o"]


def cross_kv_cache(p: Dict, kv_source: jax.Array, *, num_kv_heads: int,
                   head_dim: int) -> Dict[str, jax.Array]:
    """Precompute cross-attention K/V from encoder output (once/request)."""
    b, se, _ = kv_source.shape
    return {
        "k": (kv_source @ p["w_k"]).reshape(b, se, num_kv_heads, head_dim),
        "v": (kv_source @ p["w_v"]).reshape(b, se, num_kv_heads, head_dim),
    }


# ====================================================================== #
# MLA — DeepSeek-V2 multi-head latent attention
# ====================================================================== #
def mla_params(key: jax.Array, d: int, num_heads: int, *,
               kv_lora_rank: int, qk_nope_head_dim: int,
               qk_rope_head_dim: int, v_head_dim: int,
               dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    qd = qk_nope_head_dim + qk_rope_head_dim
    return {
        "w_q": dense_init(ks[0], d, num_heads * qd, dtype),
        "w_dkv": dense_init(ks[1], d, kv_lora_rank, dtype),
        "w_krope": dense_init(ks[2], d, qk_rope_head_dim, dtype),
        "kv_norm": rmsnorm_params(kv_lora_rank, dtype),
        "w_ukv": dense_init(
            ks[3], kv_lora_rank,
            num_heads * (qk_nope_head_dim + v_head_dim), dtype),
        "w_o": dense_init(ks[4], num_heads * v_head_dim, d, dtype),
    }


def _mla_expand(p: Dict, c_kv: jax.Array, num_heads: int,
                qk_nope_head_dim: int, v_head_dim: int
                ) -> Tuple[jax.Array, jax.Array]:
    """Expand compressed latent (B,S,rank) → k_nope/v (B,S,H,·)."""
    b, s, _ = c_kv.shape
    kv = (c_kv @ p["w_ukv"]).reshape(
        b, s, num_heads, qk_nope_head_dim + v_head_dim)
    return kv[..., :qk_nope_head_dim], kv[..., qk_nope_head_dim:]


def mla_attention(
    p: Dict, x: jax.Array, *, num_heads: int, kv_lora_rank: int,
    qk_nope_head_dim: int, qk_rope_head_dim: int, v_head_dim: int,
    positions: jax.Array, rope_base: float = 10000.0, causal: bool = True,
) -> jax.Array:
    """Full-sequence MLA (training / prefill)."""
    b, s, _ = x.shape
    qd = qk_nope_head_dim + qk_rope_head_dim
    q = (x @ p["w_q"]).reshape(b, s, num_heads, qd)
    q_nope, q_rope = q[..., :qk_nope_head_dim], q[..., qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, rope_base)

    c_kv = rmsnorm(p["kv_norm"], x @ p["w_dkv"])       # (B,S,rank)
    k_rope = apply_rope(
        (x @ p["w_krope"])[:, :, None, :], positions, rope_base)  # (B,S,1,r)
    k_nope, v = _mla_expand(p, c_kv, num_heads, qk_nope_head_dim, v_head_dim)

    if s >= MEA_MIN_SEQ and s % MEA_Q_CHUNK == 0:
        # concat-form MLA → shared chunked online-softmax path (scale is
        # qd^-0.5 in both formulations)
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,H,qd)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(
                k_rope, (b, s, num_heads, qk_rope_head_dim))], axis=-1)
        out = _mea(q_cat, k_cat, v, causal=causal, window=None)
        return out @ p["w_o"]

    scale = 1.0 / (qd ** 0.5)
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32)) +
              jnp.einsum("bqhd,bkxd->bhqk", q_rope.astype(jnp.float32),
                         k_rope.astype(jnp.float32))) * scale
    if causal:
        mask = causal_mask(s, s)[0]                     # (1, Sq, Sk)
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    out = out.reshape(b, s, num_heads * v_head_dim).astype(x.dtype)
    return out @ p["w_o"]


def mla_decode(
    p: Dict, x: jax.Array, cache: Dict[str, jax.Array], pos: jax.Array, *,
    num_heads: int, kv_lora_rank: int, qk_nope_head_dim: int,
    qk_rope_head_dim: int, v_head_dim: int, rope_base: float = 10000.0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token MLA decode.  The cache is COMPRESSED: c_kv (B,S,rank) +
    k_rope (B,S,rope_dim) — MLA's entire point (paper-assigned arch note):
    cache bytes/token = rank + rope_dim instead of 2·H·hd.

    Baseline implementation re-expands the latent per step; the absorbed
    (w_uk folded into q) variant is a §Perf candidate."""
    b = x.shape[0]
    qd = qk_nope_head_dim + qk_rope_head_dim
    q = (x @ p["w_q"]).reshape(b, 1, num_heads, qd)
    q_nope, q_rope = q[..., :qk_nope_head_dim], q[..., qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, pos[:, None], rope_base)

    c_new = rmsnorm(p["kv_norm"], x @ p["w_dkv"])       # (B,1,rank)
    kr_new = apply_rope((x @ p["w_krope"])[:, :, None, :],
                        pos[:, None], rope_base)[:, :, 0, :]  # (B,1,r)

    def write(buf, val):
        return jax.vmap(
            lambda bb, vv, pp: jax.lax.dynamic_update_slice_in_dim(
                bb, vv, pp, axis=0))(buf, val, pos)

    c_cache = write(cache["c_kv"], c_new)
    kr_cache = write(cache["k_rope"], kr_new)

    k_nope, v = _mla_expand(p, c_cache, num_heads, qk_nope_head_dim,
                            v_head_dim)                  # (B,S,H,·)
    s_max = c_cache.shape[1]
    scale = 1.0 / (qd ** 0.5)
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32)) +
              jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(jnp.float32),
                         kr_cache.astype(jnp.float32))) * scale
    valid = (jnp.arange(s_max)[None, :] <= pos[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    out = out.reshape(b, 1, num_heads * v_head_dim).astype(x.dtype)
    return out @ p["w_o"], {"c_kv": c_cache, "k_rope": kr_cache}
