"""Transformer/SSM substrate for the assigned architectures."""
from repro.nn.transformer import (
    ArchConfig, init_params, forward, loss_fn, prefill, decode_step,
    init_decode_cache, stack_plan, count_params,
)
__all__ = ["ArchConfig", "init_params", "forward", "loss_fn", "prefill",
           "decode_step", "init_decode_cache", "stack_plan", "count_params"]
