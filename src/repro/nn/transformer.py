"""Composable model definition covering the 10 assigned architectures.

One ``ArchConfig`` describes any of: dense decoder LMs (glm4, qwen3, qwen2.5,
gemma), MoE LMs (arctic, deepseek-v2 w/ MLA), attention-free RWKV-6, the
RG-LRU+local-attention hybrid (recurrentgemma), the Whisper encoder-decoder
backbone, and the Qwen2-VL VLM backbone (M-RoPE + projected patch
embeddings).

Layer stacks are ``lax.scan``-ed over stacked parameters (fast compile on
64-layer configs, remat-friendly); heterogeneous stacks scan over their
repeating pattern group.  Three entry points per architecture:

* ``loss_fn``      — next-token cross-entropy training step body
* ``prefill``      — full-sequence forward that also writes the decode cache
* ``decode_step``  — one token against a ``seq_len`` cache/state

Modality frontends are STUBS by assignment: whisper consumes precomputed
frame embeddings, qwen2-vl consumes precomputed patch embeddings
(``input_specs`` in repro.launch provides them).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import attention as attn
from repro.nn import moe as moe_lib
from repro.nn import recurrent as rec
from repro.nn.layers import (
    dense_init, embed_init, mlp_apply, mlp_params, rmsnorm, rmsnorm_params,
)
from repro.sharding.context import shard_activation, shard_logits

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str            # dense | moe | rwkv | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention knobs
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_base: float = 10000.0
    m_rope: bool = False
    sliding_window: Optional[int] = None     # set => sub-quadratic attention
    # mlp
    mlp_act: str = "silu"
    mlp_glu: bool = True
    # moe
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    d_ff_expert: Optional[int] = None
    moe_dense_residual: bool = False         # arctic parallel dense branch
    first_k_dense: int = 0                   # deepseek: first layer(s) dense
    router_aux_coef: float = 0.01
    moe_dispatch: str = "dense"              # "dense" | "capacity" (§Perf)
    moe_capacity_factor: float = 1.25
    # MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # rwkv / hybrid
    rwkv_head_dim: int = 64
    rwkv_mode: str = "sequential"            # "sequential" | "chunked" §Perf
    rwkv_chunk: int = 64
    hybrid_pattern: Tuple[str, ...] = ()     # e.g. ("rec","rec","attn")
    lru_width: Optional[int] = None
    conv1d_width: int = 4
    local_window: int = 2048                 # hybrid local-attn window
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500
    cache_cross_kv: bool = False   # §Perf: precompute decode cross-K/V
    # vlm
    vision_dim: int = 0
    # misc
    act_seq_shard: bool = False   # §Perf: shard (B,S,d) seq dim over model
    remat_policy: str = "nothing"  # "nothing" | "dots" (§Perf)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    remat: bool = True
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model ≤ 512, ≤ 4 experts — same
        family, CPU-runnable."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        hd = 64 if self.head_dim else d // heads
        n_exp = min(self.num_experts, 4) if self.num_experts else 0
        pattern = self.hybrid_pattern
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=len(pattern) if pattern else 2,
            d_model=d, num_heads=heads, num_kv_heads=kv,
            head_dim=hd if self.head_dim else None,
            d_ff=min(self.d_ff, 512),
            d_ff_expert=(min(self.d_ff_expert, 128)
                         if self.d_ff_expert else None),
            vocab_size=min(self.vocab_size, 512),
            num_experts=n_exp,
            top_k=min(self.top_k, max(1, n_exp)) if n_exp else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            kv_lora_rank=min(self.kv_lora_rank, 64),
            qk_nope_head_dim=min(self.qk_nope_head_dim, 32),
            qk_rope_head_dim=min(self.qk_rope_head_dim, 16),
            v_head_dim=min(self.v_head_dim, 32),
            lru_width=min(self.lru_width, d) if self.lru_width else None,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=min(self.encoder_frames, 32),
            vision_dim=min(self.vision_dim, 64) if self.vision_dim else 0,
            first_k_dense=min(self.first_k_dense, 1),
            sliding_window=(min(self.sliding_window, 64)
                            if self.sliding_window else None),
            local_window=min(self.local_window, 32),
            remat=False,
        )


# ====================================================================== #
# Block parameter init
# ====================================================================== #
def _block_params(key: jax.Array, cfg: ArchConfig, kind: str,
                  dtype) -> Dict:
    """kind: dense | moe | rec | attn (hybrid member) | enc | dec."""
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict = {"norm1": rmsnorm_params(d, dtype),
               "norm2": rmsnorm_params(d, dtype)}
    hd = cfg.resolved_head_dim

    if kind in ("dense", "moe", "enc", "dec", "attn"):
        if cfg.use_mla:
            p["attn"] = attn.mla_params(
                ks[0], d, cfg.num_heads, kv_lora_rank=cfg.kv_lora_rank,
                qk_nope_head_dim=cfg.qk_nope_head_dim,
                qk_rope_head_dim=cfg.qk_rope_head_dim,
                v_head_dim=cfg.v_head_dim, dtype=dtype)
        else:
            p["attn"] = attn.attn_params(
                ks[0], d, cfg.num_heads, cfg.num_kv_heads, hd,
                qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=dtype)
    if kind == "dec":
        p["cross_attn"] = attn.attn_params(
            ks[3], d, cfg.num_heads, cfg.num_heads, hd, dtype=dtype)
        p["norm_cross"] = rmsnorm_params(d, dtype)
    if kind == "rec":
        if cfg.arch_type == "rwkv":
            p["rec"] = rec.rwkv_params(ks[0], d, cfg.rwkv_head_dim,
                                       dtype=dtype)
        else:
            p["rec"] = rec.rglru_params(
                ks[0], d, cfg.lru_width or d,
                conv_width=cfg.conv1d_width, dtype=dtype)

    if kind == "moe":
        p["moe"] = moe_lib.moe_params(
            ks[1], d, num_experts=cfg.num_experts,
            d_ff_expert=cfg.d_ff_expert or cfg.d_ff,
            num_shared=cfg.num_shared_experts,
            dense_residual_ff=cfg.d_ff if cfg.moe_dense_residual else 0,
            glu=cfg.mlp_glu, dtype=dtype)
    elif cfg.arch_type == "rwkv" and kind == "rec":
        # RWKV channel mix (token-shifted squared-relu FFN)
        p["cmix"] = {
            "mu_k": jnp.full((d,), 0.5, dtype),
            "mu_r": jnp.full((d,), 0.5, dtype),
            "w_k": dense_init(ks[1], d, cfg.d_ff, dtype),
            "w_v": dense_init(ks[2], cfg.d_ff, d, dtype),
            "w_r": dense_init(ks[3], d, d, dtype),
        }
    else:
        p["mlp"] = mlp_params(ks[1], d, cfg.d_ff, cfg.mlp_glu, dtype)
    return p


# ====================================================================== #
# Block apply — full sequence
# ====================================================================== #
def _attn_full(p, cfg: ArchConfig, h, positions, *, causal=True,
               window=None, encoder_out=None, kind="dense"):
    if cfg.use_mla:
        return attn.mla_attention(
            p["attn"], h, num_heads=cfg.num_heads,
            kv_lora_rank=cfg.kv_lora_rank,
            qk_nope_head_dim=cfg.qk_nope_head_dim,
            qk_rope_head_dim=cfg.qk_rope_head_dim,
            v_head_dim=cfg.v_head_dim, positions=positions,
            rope_base=cfg.rope_base, causal=causal)
    return attn.attention(
        p["attn"], h, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        positions=positions, rope_base=cfg.rope_base,
        m_rope=cfg.m_rope, causal=causal, window=window)


def _channel_full(p, cfg: ArchConfig, h):
    """MLP or MoE second half; returns (out, aux)."""
    if "moe" in p:
        if cfg.moe_dispatch == "capacity":
            return moe_lib.moe_apply_capacity(
                p["moe"], h, top_k=cfg.top_k, act=cfg.mlp_act,
                capacity_factor=cfg.moe_capacity_factor)
        return moe_lib.moe_apply(p["moe"], h, top_k=cfg.top_k,
                                 act=cfg.mlp_act)
    if "cmix" in p:
        c = p["cmix"]
        h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        k = (h + (h_prev - h) * c["mu_k"]) @ c["w_k"]
        r = jax.nn.sigmoid((h + (h_prev - h) * c["mu_r"]) @ c["w_r"])
        return r * (jnp.square(jax.nn.relu(k)) @ c["w_v"]), 0.0
    return mlp_apply(p["mlp"], h, cfg.mlp_act), 0.0


def block_apply(p: Dict, cfg: ArchConfig, h: jax.Array, positions, *,
                kind: str, encoder_out=None) -> Tuple[jax.Array, jax.Array]:
    """Pre-norm residual block.  Returns (h, moe_aux)."""
    if kind == "rec":
        if cfg.arch_type == "rwkv":
            xin = rmsnorm(p["norm1"], h)
            if cfg.rwkv_mode == "chunked" and \
                    xin.shape[1] % cfg.rwkv_chunk == 0:
                mix = rec.rwkv_apply_chunked(p["rec"], xin,
                                             cfg.rwkv_head_dim,
                                             chunk=cfg.rwkv_chunk)
            elif cfg.rwkv_mode == "chunked_kernel":
                mix = rec.rwkv_apply_kernel(p["rec"], xin,
                                            cfg.rwkv_head_dim,
                                            chunk=cfg.rwkv_chunk)
            else:
                mix = rec.rwkv_apply(p["rec"], xin, cfg.rwkv_head_dim)
        else:
            mix = rec.rglru_apply(p["rec"], rmsnorm(p["norm1"], h))
    else:
        window = None
        causal = kind != "enc"
        if kind == "attn":                     # hybrid local attention
            window = cfg.local_window
        elif cfg.sliding_window is not None:
            window = cfg.sliding_window
        mix = _attn_full(p, cfg, rmsnorm(p["norm1"], h), positions,
                         causal=causal, window=window)
    h = h + mix
    if kind == "dec":
        h = h + attn.cross_attention(
            p["cross_attn"], rmsnorm(p["norm_cross"], h), encoder_out,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_heads,
            head_dim=cfg.resolved_head_dim)
    out, aux = _channel_full(p, cfg, rmsnorm(p["norm2"], h))
    return h + out, aux


# ====================================================================== #
# Block apply — single-token decode
# ====================================================================== #
def block_decode(p: Dict, cfg: ArchConfig, h: jax.Array, cache: Dict,
                 pos: jax.Array, *, kind: str, encoder_out=None,
                 positions_3d=None) -> Tuple[jax.Array, Dict]:
    new_cache = {}
    x = rmsnorm(p["norm1"], h)
    if kind == "rec":
        if cfg.arch_type == "rwkv":
            mix, new_cache["rec"] = rec.rwkv_decode(
                p["rec"], x, cache["rec"], cfg.rwkv_head_dim)
        else:
            mix, new_cache["rec"] = rec.rglru_decode(
                p["rec"], x, cache["rec"])
    elif cfg.use_mla:
        mix, new_cache["attn"] = attn.mla_decode(
            p["attn"], x, cache["attn"], pos, num_heads=cfg.num_heads,
            kv_lora_rank=cfg.kv_lora_rank,
            qk_nope_head_dim=cfg.qk_nope_head_dim,
            qk_rope_head_dim=cfg.qk_rope_head_dim,
            v_head_dim=cfg.v_head_dim, rope_base=cfg.rope_base)
    else:
        window = cfg.local_window if kind == "attn" else cfg.sliding_window
        mix, new_cache["attn"] = attn.attention_decode(
            p["attn"], x, cache["attn"], pos, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_base=cfg.rope_base, m_rope=cfg.m_rope,
            positions_3d=positions_3d, window=window)
    h = h + mix
    if kind == "dec":
        h = h + attn.cross_attention(
            p["cross_attn"], rmsnorm(p["norm_cross"], h), encoder_out,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_heads,
            head_dim=cfg.resolved_head_dim,
            cached_kv=cache.get("cross_kv"))
        if "cross_kv" in cache:
            new_cache["cross_kv"] = cache["cross_kv"]

    x2 = rmsnorm(p["norm2"], h)
    if "cmix" in p:
        c = p["cmix"]
        x_prev = cache["cmix_x_prev"]
        x2_t = x2[:, 0]
        k = (x2_t + (x_prev - x2_t) * c["mu_k"]) @ c["w_k"]
        r = jax.nn.sigmoid((x2_t + (x_prev - x2_t) * c["mu_r"]) @ c["w_r"])
        out = (r * (jnp.square(jax.nn.relu(k)) @ c["w_v"]))[:, None]
        new_cache["cmix_x_prev"] = x2_t
    elif "moe" in p:
        if cfg.moe_dispatch == "capacity":
            out, _ = moe_lib.moe_apply_capacity(
                p["moe"], x2, top_k=cfg.top_k, act=cfg.mlp_act,
                capacity_factor=cfg.moe_capacity_factor)
        else:
            out = moe_lib.moe_apply_decode(p["moe"], x2, top_k=cfg.top_k,
                                           act=cfg.mlp_act)
    else:
        out = mlp_apply(p["mlp"], x2, cfg.mlp_act)
    return h + out, new_cache


def _block_cache(cfg: ArchConfig, kind: str, batch: int, seq_len: int,
                 dtype) -> Dict:
    """Empty decode cache for one block."""
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    c: Dict = {}
    if kind == "dec" and cfg.cache_cross_kv:
        c["cross_kv"] = {
            "k": jnp.zeros((batch, cfg.encoder_frames, cfg.num_heads, hd),
                           dtype),
            "v": jnp.zeros((batch, cfg.encoder_frames, cfg.num_heads, hd),
                           dtype),
        }
    if kind == "rec":
        if cfg.arch_type == "rwkv":
            c["rec"] = rec.rwkv_init_state(batch, d, cfg.rwkv_head_dim,
                                           dtype)
            c["cmix_x_prev"] = jnp.zeros((batch, d), dtype)
        else:
            c["rec"] = rec.rglru_init_state(batch, cfg.lru_width or d,
                                            cfg.conv1d_width, dtype)
    elif cfg.use_mla:
        c["attn"] = {
            "c_kv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, seq_len, cfg.qk_rope_head_dim),
                                dtype),
        }
    else:
        s = seq_len
        if kind == "attn":                 # hybrid local attn: window cache
            s = min(seq_len, cfg.local_window)
        elif cfg.sliding_window is not None:
            s = min(seq_len, cfg.sliding_window)
        c["attn"] = {
            "k": jnp.zeros((batch, s, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, s, cfg.num_kv_heads, hd), dtype),
        }
    return c


# ====================================================================== #
# Layer-stack plan: (kind, count, scanned) groups
# ====================================================================== #
def stack_plan(cfg: ArchConfig):
    """Returns a list of (kind, n_layers, scan: bool) groups covering the
    decoder stack in order."""
    L = cfg.num_layers
    if cfg.arch_type in ("dense", "vlm"):
        return [("dense", L, True)]
    if cfg.arch_type == "moe":
        plan = []
        if cfg.first_k_dense:
            plan.append(("dense", cfg.first_k_dense, False))
        plan.append(("moe", L - cfg.first_k_dense, True))
        return plan
    if cfg.arch_type == "rwkv":
        return [("rec", L, True)]
    if cfg.arch_type == "hybrid":
        pattern = cfg.hybrid_pattern or ("rec", "rec", "attn")
        reps, rem = divmod(L, len(pattern))
        plan = [("pattern", reps, True)] if reps else []
        for k in pattern[:rem]:
            plan.append((k, 1, False))
        return plan
    if cfg.arch_type == "encdec":
        return [("dec", L, True)]
    raise ValueError(cfg.arch_type)


# ====================================================================== #
# Full-model init
# ====================================================================== #
def init_params(key: jax.Array, cfg: ArchConfig,
                dtype=jnp.bfloat16) -> PyTree:
    keys = jax.random.split(key, 16)
    params: Dict = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_params(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model,
                                       cfg.vocab_size, dtype)
    if cfg.vision_dim:
        params["vision_proj"] = dense_init(keys[2], cfg.vision_dim,
                                           cfg.d_model, dtype)

    def stacked(key, n, kinds):
        """init n copies of a (multi-kind pattern) block, stacked."""
        def one(k):
            if len(kinds) == 1:
                return _block_params(k, cfg, kinds[0], dtype)
            sub = jax.random.split(k, len(kinds))
            return {f"sub{i}": _block_params(sub[i], cfg, kd, dtype)
                    for i, kd in enumerate(kinds)}
        return jax.vmap(one)(jax.random.split(key, n))

    groups = []
    for gi, (kind, n, scan) in enumerate(stack_plan(cfg)):
        k = keys[4 + (gi % 10)]
        if kind == "pattern":
            groups.append(stacked(k, n, list(cfg.hybrid_pattern)))
        elif scan:
            groups.append(stacked(k, n, [kind]))
        else:
            sub = jax.random.split(k, n)
            groups.append([_block_params(sk, cfg, kind, dtype)
                           for sk in sub])
    params["groups"] = groups

    if cfg.arch_type == "encdec":
        params["encoder"] = {
            "groups": [jax.vmap(
                lambda k: _block_params(k, cfg, "enc", dtype))(
                jax.random.split(keys[3], cfg.encoder_layers))],
            "final_norm": rmsnorm_params(cfg.d_model, dtype),
        }
    return params


# ====================================================================== #
# Forward (training / prefill path)
# ====================================================================== #
def _run_group(gparams, cfg: ArchConfig, h, positions, kind, scanned, *,
               encoder_out=None, remat=False):
    """Run one stack group; returns (h, aux_sum)."""
    if not scanned:   # python list of per-layer params
        aux = 0.0
        for lp in gparams:
            h, a = block_apply(lp, cfg, h, positions, kind=kind,
                               encoder_out=encoder_out)
            aux = aux + a
        return h, aux

    if kind == "pattern":
        kinds = list(cfg.hybrid_pattern)

        def body(carry, lp):
            hh = carry
            aux = 0.0
            for i, kd in enumerate(kinds):
                hh, a = block_apply(lp[f"sub{i}"], cfg, hh, positions,
                                    kind=kd, encoder_out=encoder_out)
                aux = aux + a
            return hh, aux
    else:
        def body(carry, lp):
            hh, a = block_apply(lp, cfg, carry, positions, kind=kind,
                                encoder_out=encoder_out)
            return hh, a

    if remat:
        policy = None
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    h, auxs = jax.lax.scan(body, h, gparams)
    return h, jnp.sum(auxs)


def embed_tokens(params, cfg: ArchConfig, tokens: jax.Array,
                 vision_embeds: Optional[jax.Array] = None) -> jax.Array:
    h = params["embed"][tokens] * (cfg.d_model ** 0.5)
    if cfg.vision_dim and vision_embeds is not None:
        h = h + vision_embeds @ params["vision_proj"]
    return h


def forward(params, cfg: ArchConfig, tokens: jax.Array, *,
            positions: Optional[jax.Array] = None,
            vision_embeds=None, audio_frames=None,
            train: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward → (logits (B,S,V), moe_aux)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.m_rope:
            positions = jnp.broadcast_to(positions[..., None], (b, s, 3))

    encoder_out = None
    if cfg.arch_type == "encdec":
        assert audio_frames is not None, "whisper needs frame embeddings"
        enc_pos = jnp.broadcast_to(
            jnp.arange(audio_frames.shape[1])[None],
            audio_frames.shape[:2])
        eh = audio_frames
        eh, _ = _run_group(params["encoder"]["groups"][0], cfg, eh,
                           enc_pos, "enc", True, remat=cfg.remat and train)
        encoder_out = shard_activation(
            rmsnorm(params["encoder"]["final_norm"], eh))

    h = shard_activation(embed_tokens(params, cfg, tokens, vision_embeds),
                         seq_over_model=cfg.act_seq_shard)
    aux = 0.0
    for gparams, (kind, n, scanned) in zip(params["groups"],
                                           stack_plan(cfg)):
        h, a = _run_group(gparams, cfg, h, positions, kind, scanned,
                          encoder_out=encoder_out,
                          remat=cfg.remat and train)
        h = shard_activation(h, seq_over_model=cfg.act_seq_shard)
        aux = aux + a
    h = rmsnorm(params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    return shard_logits(logits), aux


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (train_step body)."""
    logits, aux = forward(
        params, cfg, batch["tokens"],
        positions=batch.get("positions"),
        vision_embeds=batch.get("vision_embeds"),
        audio_frames=batch.get("audio_frames"),
        train=True)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    total = nll + cfg.router_aux_coef * aux / max(cfg.num_layers, 1)
    return total, {"nll": nll, "moe_aux": jnp.asarray(aux, jnp.float32)}


# ====================================================================== #
# Decode
# ====================================================================== #
def init_decode_cache(cfg: ArchConfig, batch: int, seq_len: int,
                      dtype=jnp.bfloat16) -> PyTree:
    """Cache pytree matching the stack plan (stacked along scan dim for
    scanned groups)."""
    groups = []
    for kind, n, scanned in stack_plan(cfg):
        if kind == "pattern":
            one = {f"sub{i}": _block_cache(cfg, kd, batch, seq_len, dtype)
                   for i, kd in enumerate(cfg.hybrid_pattern)}
        else:
            one = _block_cache(cfg, kind, batch, seq_len, dtype)
        if scanned:
            groups.append(jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy()
                if n > 1 else x[None], one))
        else:
            groups.append([one for _ in range(n)])
    cache: Dict = {"groups": groups}
    if cfg.arch_type == "encdec":
        cache["encoder_out"] = jnp.zeros(
            (batch, cfg.encoder_frames, cfg.d_model), dtype)
    return cache


def decode_step(params, cfg: ArchConfig, tokens: jax.Array,
                cache: PyTree, pos: jax.Array, *,
                positions_3d=None, vision_embeds=None
                ) -> Tuple[jax.Array, PyTree]:
    """One-token decode: tokens (B, 1), pos (B,) current write index.
    Returns (logits (B, 1, V), new_cache)."""
    encoder_out = cache.get("encoder_out")
    h = shard_activation(embed_tokens(params, cfg, tokens, vision_embeds))
    new_groups = []
    for gparams, gcache, (kind, n, scanned) in zip(
            params["groups"], cache["groups"], stack_plan(cfg)):
        if not scanned:
            ncs = []
            for lp, lc in zip(gparams, gcache):
                h, nc = block_decode(lp, cfg, h, lc, pos, kind=kind,
                                     encoder_out=encoder_out,
                                     positions_3d=positions_3d)
                ncs.append(nc)
            new_groups.append(ncs)
            continue

        if kind == "pattern":
            kinds = list(cfg.hybrid_pattern)

            def body(carry, xs):
                hh = carry
                lp, lc = xs
                nc = {}
                for i, kd in enumerate(kinds):
                    hh, nci = block_decode(
                        lp[f"sub{i}"], cfg, hh, lc[f"sub{i}"], pos,
                        kind=kd, encoder_out=encoder_out,
                        positions_3d=positions_3d)
                    nc[f"sub{i}"] = nci
                return hh, nc
        else:
            def body(carry, xs):
                lp, lc = xs
                hh, nc = block_decode(lp, cfg, carry, lc, pos, kind=kind,
                                      encoder_out=encoder_out,
                                      positions_3d=positions_3d)
                return hh, nc
        h, ncache = jax.lax.scan(body, h, (gparams, gcache))
        new_groups.append(ncache)

    h = rmsnorm(params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    new_cache = dict(cache)
    new_cache["groups"] = new_groups
    return shard_logits(logits), new_cache


def prefill(params, cfg: ArchConfig, tokens: jax.Array, *,
            positions=None, vision_embeds=None, audio_frames=None
            ) -> Tuple[jax.Array, jax.Array]:
    """Prefill forward: returns (last-position logits, full logits dropped).
    The dry-run lowers this for the ``prefill_32k`` shape; cache
    materialization for chained decode reuses ``forward`` activations in the
    serving layer."""
    logits, _ = forward(params, cfg, tokens, positions=positions,
                        vision_embeds=vision_embeds,
                        audio_frames=audio_frames, train=False)
    return logits[:, -1], logits[:, -1].argmax(-1)


def count_params(params) -> int:
    import numpy as np
    return int(sum(np.prod(x.shape)
                   for x in jax.tree_util.tree_leaves(params)))
