"""Shared NN building blocks for the assigned-architecture substrate.

Functional style: parameters are plain nested dicts, apply functions are pure.
Compute dtype follows the input; norm/softmax statistics accumulate in fp32.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------- #
# Initializers
# ---------------------------------------------------------------------- #
def dense_init(key: jax.Array, d_in: int, d_out: int,
               dtype=jnp.float32) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int,
               dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * d ** -0.5).astype(dtype)


# ---------------------------------------------------------------------- #
# Norms
# ---------------------------------------------------------------------- #
def rmsnorm_params(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_params(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------- #
# Rotary embeddings — standard RoPE and Qwen2-VL's M-RoPE
# ---------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, base: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (base ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array,
               base: float = 10000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S) int32.

    Half-split convention (rotate_half), matching Llama/GLM/Qwen."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, base)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    angles = angles[..., None, :]                          # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x: jax.Array, positions_3d: jax.Array,
                 base: float = 10000.0,
                 sections: Optional[Tuple[int, int, int]] = None
                 ) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the rotary dim is split into
    (temporal, height, width) sections, each rotated by its own position
    stream.  x: (B, S, H, hd); positions_3d: (B, S, 3) int32.
    ``sections`` are in HALF-dim units and must sum to hd/2; default is the
    Qwen2-VL 1:1.5:1.5 split ((16, 24, 24) at hd=128)."""
    hd = x.shape[-1]
    half = hd // 2
    if sections is None:
        t = half // 4
        h_sec = (half - t) // 2
        sections = (t, h_sec, half - t - h_sec)
    assert sum(sections) == half, (sections, half)
    inv = rope_frequencies(hd, base)                       # (half,)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half)
    # pick, per frequency index, the position stream of its section
    pos = jnp.take_along_axis(
        positions_3d.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :],
                         positions_3d.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1)                                           # (B, S, half)
    angles = pos * inv                                     # (B, S, half)
    angles = angles[..., None, :]                          # (B, S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# MLPs
# ---------------------------------------------------------------------- #
def mlp_params(key: jax.Array, d: int, d_ff: int, glu: bool,
               dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"w_out": dense_init(ks[0], d_ff, d, dtype)}
    p["w_in"] = dense_init(ks[1], d, d_ff, dtype)
    if glu:
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp_apply(p: Dict, x: jax.Array, act: str = "silu") -> jax.Array:
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
         "gelu_tanh": lambda v: jax.nn.gelu(v, approximate=True)}[act]
    h = x @ p["w_in"]
    if "w_gate" in p:
        h = a(x @ p["w_gate"]) * h          # GeGLU / SwiGLU
    else:
        h = a(h)
    return h @ p["w_out"]


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
