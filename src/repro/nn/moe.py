"""Mixture-of-Experts layer (arctic-480b, deepseek-v2-lite).

TPU-native dense dispatch (DESIGN.md §5): tokens are routed with a top-k
softmax router and dispatched via one-hot combine einsums rather than a
dynamic all-to-all — shapes stay static, the expert dimension shards over the
``model`` mesh axis, and XLA lowers the dispatch/combine contractions to
all-gather/reduce-scatter on that axis.  This is the one layer where the
paper's "no cross-partition traffic" invariant cannot hold (experts live on
other chips); EXPERIMENTS.md quantifies the resulting collective bytes.

Supports: routed experts (top_k), optional shared experts (deepseek), an
optional parallel dense-FFN residual branch (arctic), and the standard
load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import dense_init, mlp_apply, mlp_params
from repro.sharding.context import _STATE as _MESH_STATE, _constraint


def moe_params(key: jax.Array, d: int, *, num_experts: int,
               d_ff_expert: int, num_shared: int = 0,
               dense_residual_ff: int = 0, glu: bool = True,
               dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    p: Dict = {
        "router": dense_init(ks[0], d, num_experts, dtype=jnp.float32),
        # experts as stacked tensors (E, d, ff) / (E, ff, d): expert axis
        # shards over `model`
        "w_in": _expert_init(ks[1], num_experts, d, d_ff_expert, dtype),
        "w_out": _expert_init(ks[2], num_experts, d_ff_expert, d, dtype),
    }
    if glu:
        p["w_gate"] = _expert_init(ks[3], num_experts, d, d_ff_expert, dtype)
    if num_shared:
        p["shared"] = mlp_params(ks[4], d, d_ff_expert * num_shared, glu,
                                 dtype)
    if dense_residual_ff:
        p["dense"] = mlp_params(ks[5], d, dense_residual_ff, glu, dtype)
    return p


def _expert_init(key, e, d_in, d_out, dtype):
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (e, d_in, d_out)) * scale).astype(dtype)


def moe_apply(p: Dict, x: jax.Array, *, top_k: int, act: str = "silu",
              router_noise_key=None) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) → (out (B, S, d), aux_loss scalar).

    Dense dispatch: combine weights (B,S,E) are zero outside the top-k, so
    the einsum over E computes only-selected experts' results mathematically;
    XLA shards the E axis so each chip computes its local experts for ALL
    tokens — compute is O(E_local·tokens) dense, the standard TPU trade
    (static shapes, MXU-friendly) against ragged dispatch.
    """
    b, s, d = x.shape
    e = p["router"].shape[1]
    logits = (x.astype(jnp.float32) @ p["router"])          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, top_k)         # (B,S,k)
    # renormalize selected weights (deepseek/arctic convention)
    top_vals = top_vals / jnp.sum(top_vals, -1, keepdims=True)
    combine = jnp.sum(
        jax.nn.one_hot(top_idx, e, dtype=probs.dtype)
        * top_vals[..., None], axis=-2)                     # (B,S,E)

    # expert compute on all tokens, combine-weighted
    h_in = jnp.einsum("bsd,edf->bsef", x, p["w_in"])
    if "w_gate" in p:
        a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
        h = a(jnp.einsum("bsd,edf->bsef", x, p["w_gate"])) * h_in
    else:
        h = jax.nn.silu(h_in)
    y = jnp.einsum("bsef,efd->bsed", h, p["w_out"])
    out = jnp.einsum("bsed,bse->bsd", y,
                     combine.astype(y.dtype))

    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, act)
    if "dense" in p:
        out = out + mlp_apply(p["dense"], x, act)

    # load-balance aux (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.mean((combine > 0).astype(jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return out.astype(x.dtype), aux


def moe_apply_capacity(p: Dict, x: jax.Array, *, top_k: int,
                       act: str = "silu", capacity_factor: float = 1.25
                       ) -> Tuple[jax.Array, jax.Array]:
    """Capacity-bounded sparse dispatch (§Perf hillclimb vs dense dispatch).

    Dense dispatch computes ALL experts for ALL tokens — compute waste
    factor E/top_k (64× for arctic's 128e top-2).  Here tokens are sorted by
    assigned expert and each expert processes at most
    ``C = ceil(T·top_k/E · capacity_factor)`` tokens (overflow dropped, the
    standard GShard/Switch trade).  Expert FLOPs drop by
    ``E/(top_k·capacity_factor)`` (≈51× for arctic).  Gather/scatter is
    sort-based — static shapes, TPU-friendly; the expert dim still shards
    over ``model``.
    """
    b, s, d = x.shape
    t = b * s
    e = p["router"].shape[1]
    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ p["router"]           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, top_k)         # (T, k)
    top_vals = top_vals / jnp.sum(top_vals, -1, keepdims=True)

    flat_expert = top_idx.reshape(-1)                       # (T·k,)
    flat_token = jnp.arange(t * top_k, dtype=jnp.int32) // top_k
    flat_gate = top_vals.reshape(-1)

    cap = int(-(-t * top_k * capacity_factor // e))         # ceil
    cap = max(8, ((cap + 7) // 8) * 8)                      # align

    order = jnp.argsort(flat_expert)                        # group by expert
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    counts = jnp.sum(jax.nn.one_hot(flat_expert, e, dtype=jnp.int32),
                     axis=0)                                # (E,)
    offsets = jnp.cumsum(counts) - counts                   # exclusive
    slot = offsets[:, None] + jnp.arange(cap)[None, :]      # (E, C)
    valid = (jnp.arange(cap)[None, :] < counts[:, None])
    slot = jnp.clip(slot, 0, t * top_k - 1)
    tok = sorted_token[slot]                                # (E, C)
    gate = jnp.where(valid, sorted_gate[slot], 0.0)         # (E, C)
    # guard: slots past an expert's count may alias other experts' tokens;
    # gate==0 there so they contribute nothing, but compute still touches
    # them — that is the capacity contract.
    xe = xf[tok]                                            # (E, C, d)
    # §Perf: pin the dispatched buffer to expert-parallel layout so the
    # token movement lowers as a dispatch (all-to-all-like) instead of a
    # full activation all-gather on the expert axis
    xe = _shard_expert_buffer(xe)

    h_in = jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    if "w_gate" in p:
        a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
        h = a(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * h_in
    else:
        h = jax.nn.silu(h_in)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_out"])
    y = y * gate[..., None].astype(y.dtype)

    out = jax.ops.segment_sum(
        y.reshape(-1, d), tok.reshape(-1), num_segments=t)  # combine
    out = out.reshape(b, s, d)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, act)
    if "dense" in p:
        out = out + mlp_apply(p["dense"], x, act)

    me = jnp.mean(probs, axis=(0,))
    combine_mask = jnp.sum(jax.nn.one_hot(top_idx, e), axis=1)  # (T, E)
    ce = jnp.mean(combine_mask, axis=0)
    aux = e * jnp.sum(me * ce) / max(top_k, 1)
    return out.astype(x.dtype), aux


def _shard_expert_buffer(xe: jax.Array) -> jax.Array:
    """(E, C, d) dispatched tokens: expert dim over ``model`` when a mesh is
    installed and E divides it (no-op otherwise)."""
    from jax.sharding import PartitionSpec as P
    mesh = _MESH_STATE.get("mesh")
    if mesh is None or "model" not in mesh.axis_names:
        return xe
    if xe.shape[0] % mesh.shape["model"]:
        return xe
    return _constraint(xe, P("model", None, None))


def moe_apply_decode(p: Dict, x: jax.Array, *, top_k: int,
                     act: str = "silu") -> jax.Array:
    out, _ = moe_apply(p, x, top_k=top_k, act=act)
    return out
