"""Recurrent sequence-mixing layers: RWKV-6 "Finch" and RG-LRU
(RecurrentGemma / Griffin).

Both keep ALL recurrent state shard-local (batch-sharded) — the paper's
self-sufficiency invariant carries over: no cross-shard traffic during the
scan, only gradient AllReduce (DESIGN.md §4).

Three RWKV training forms with one semantics (tested equal):
``rwkv_apply`` — the faithful per-token ``lax.scan`` (paper-baseline);
``rwkv_apply_chunked`` — block-parallel WKV (§Perf winner, 330× memory-term
reduction at train_4k); ``rwkv_apply_kernel`` — the Pallas TPU kernel of the
chunked form (VMEM-resident state).  Decoding is the single-step recurrence
with explicit state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import dense_init, rmsnorm, rmsnorm_params


# ====================================================================== #
# RWKV-6 (Finch): token-shift + data-dependent decay WKV
# ====================================================================== #
def rwkv_params(key: jax.Array, d: int, head_dim: int, *,
                lora_rank: int = 64, dtype=jnp.float32) -> Dict:
    h = d // head_dim
    ks = jax.random.split(key, 10)
    return {
        # token-shift mixing coefficients (v6 ddlerp, lite: static mu +
        # data-dependent lora term)
        "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "w_r": dense_init(ks[0], d, d, dtype),
        "w_k": dense_init(ks[1], d, d, dtype),
        "w_v": dense_init(ks[2], d, d, dtype),
        "w_g": dense_init(ks[3], d, d, dtype),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": jnp.full((d,), -6.0, dtype),
        "decay_A": dense_init(ks[4], d, lora_rank, dtype),
        "decay_B": dense_init(ks[5], lora_rank, d, dtype),
        "bonus_u": (jax.random.normal(ks[6], (h, head_dim)) * 0.1
                    ).astype(dtype),
        "w_o": dense_init(ks[7], d, d, dtype),
        "ln_x": rmsnorm_params(d, dtype),
    }


def _rwkv_mix(p: Dict, x: jax.Array, x_prev: jax.Array
              ) -> Tuple[jax.Array, ...]:
    """Token shift: lerp(x, x_prev, mu) per projection stream.
    Returns (r, k, v, g, decay) with ``decay = exp(log_decay)``;
    ``_rwkv_mix_logw`` exposes log_decay directly for the chunked path."""
    r, k, v, g, logw = _rwkv_mix_logw(p, x, x_prev)
    return r, k, v, g, jnp.exp(logw)


def _rwkv_mix_logw(p: Dict, x: jax.Array, x_prev: jax.Array
                   ) -> Tuple[jax.Array, ...]:
    def mix(mu):
        return x + (x_prev - x) * mu
    r = mix(p["mu_r"]) @ p["w_r"]
    k = mix(p["mu_k"]) @ p["w_k"]
    v = mix(p["mu_v"]) @ p["w_v"]
    g = mix(p["mu_g"]) @ p["w_g"]
    wx = mix(p["mu_w"])
    log_decay = -jnp.exp(
        p["decay_w0"].astype(jnp.float32) +
        jnp.tanh(wx.astype(jnp.float32) @ p["decay_A"].astype(jnp.float32))
        @ p["decay_B"].astype(jnp.float32))
    return r, k, v, g, log_decay


def rwkv_apply(p: Dict, x: jax.Array, head_dim: int) -> jax.Array:
    """Training-mode RWKV-6 time mix: x (B, S, d) → (B, S, d).

    WKV recurrence per head (state S: (hd_k, hd_v))::

        out_t = r_t · (S_{t-1} + diag(u) k_t^T v_t)
        S_t   = diag(w_t) S_{t-1} + k_t^T v_t
    """
    b, s, d = x.shape
    h = d // head_dim
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, decay = _rwkv_mix(p, x, x_prev)

    def heads(t):
        return t.reshape(b, s, h, head_dim).astype(jnp.float32)
    r_, k_, v_, w_ = heads(r), heads(k), heads(v), heads(decay)
    u = p["bonus_u"].astype(jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp           # (B, H, hd)
        kv = k_t[..., :, None] * v_t[..., None, :]        # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         state + u[None, :, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, out

    init = jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r_, k_, v_, w_))
    _, outs = jax.lax.scan(step, init, xs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)       # (B,S,d)
    out = rmsnorm(p["ln_x"], out.astype(x.dtype))
    out = out * jax.nn.silu(g)
    return out @ p["w_o"]


def rwkv_apply_chunked(p: Dict, x: jax.Array, head_dim: int,
                       chunk: int = 64) -> jax.Array:
    """Chunked (block-parallel) WKV — the §Perf optimized form.

    The sequential scan reads/writes the (B, H, hd, hd) state EVERY token:
    at train_4k that is ~8,400 s of HBM traffic per step (see EXPERIMENTS.md
    §Perf).  Standard linear-attention chunking [used by all production RWKV
    kernels] turns the recurrence into per-chunk matmuls:

        within chunk (L = exclusive-cumsum of log decay):
          out = tril_strict( (r·e^{L}) (k·e^{-L-logw})^T ) v
                + diag(Σ r·u·k) v  +  (r·e^{L}) S_in
          S_out = e^{L_total} ⊙ S_in + (k·e^{L_total - L - logw})^T v

    State now moves once per CHUNK (64× less traffic) and everything is an
    MXU matmul.  Numerics: the e^{±L} factorization is exact in fp32 for the
    near-1 decays RWKV parameterizes (|L_total| ≲ chunk·|log w|); production
    kernels renormalize per chunk for extreme decays.
    Matches ``rwkv_apply`` (tested to 1e-3)."""
    b, s, d = x.shape
    h = d // head_dim
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, logw = _rwkv_mix_logw(p, x, x_prev)

    def heads(t):
        return jnp.moveaxis(
            t.reshape(b, nc, chunk, h, head_dim).astype(jnp.float32),
            1, 0)                                   # (nc, b, K, h, hd)
    r_, k_, v_, w_ = heads(r), heads(k), heads(v), heads(logw)
    u = p["bonus_u"].astype(jnp.float32)            # (h, hd)

    def chunk_step(state, inp):
        rc, kc, vc, lw = inp                        # (b, K, h, hd)
        l_exc = jnp.cumsum(lw, axis=1) - lw         # L_tau (exclusive)
        l_inc = l_exc + lw                          # L_{tau+1}
        l_tot = l_inc[:, -1:]                       # (b, 1, h, hd)
        r_t = rc * jnp.exp(l_exc)
        k_t = kc * jnp.exp(-l_inc)
        scores = jnp.einsum("bihd,bjhd->bhij", r_t, k_t)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        intra = jnp.einsum("bhij,bjhd->bihd", scores, vc)
        bonus = jnp.sum(rc * u[None, None] * kc, axis=-1)   # (b, K, h)
        diag = bonus[..., None] * vc
        cross = jnp.einsum("bihk,bhkv->bihv", r_t, state)
        out = intra + diag + cross                  # (b, K, h, hd_v)
        k_out = kc * jnp.exp(l_tot - l_inc)
        state = jnp.exp(l_tot[:, 0])[..., None] * state + \
            jnp.einsum("bihk,bihv->bhkv", k_out, vc)
        return state, out

    init = jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
    _, outs = jax.lax.scan(chunk_step, init, (r_, k_, v_, w_))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)     # (b, S, d)
    out = rmsnorm(p["ln_x"], out.astype(x.dtype))
    out = out * jax.nn.silu(g)
    return out @ p["w_o"]


def rwkv_apply_kernel(p: Dict, x: jax.Array, head_dim: int,
                      chunk: int = 64) -> jax.Array:
    """Chunked WKV through the Pallas kernel (``kernels.wkv_chunk``) — the
    TPU deployment path of ``rwkv_apply_chunked`` (same math; on CPU the
    kernel runs in interpret mode, so CPU training prefers the jnp chunked
    form)."""
    from repro.kernels.ops import wkv_chunked_op
    b, s, d = x.shape
    h = d // head_dim
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, logw = _rwkv_mix_logw(p, x, x_prev)

    def flat(t):
        # (B, S, d) -> (B·H, S, hd)
        return jnp.moveaxis(t.reshape(b, s, h, head_dim), 2, 1) \
            .reshape(b * h, s, head_dim).astype(jnp.float32)

    u = jnp.broadcast_to(p["bonus_u"].astype(jnp.float32)[None],
                         (b, h, head_dim)).reshape(b * h, head_dim)
    out = wkv_chunked_op(flat(r), flat(k), flat(v), flat(logw), u, chunk)
    out = jnp.moveaxis(out.reshape(b, h, s, head_dim), 1, 2) \
        .reshape(b, s, d)
    out = rmsnorm(p["ln_x"], out.astype(x.dtype))
    out = out * jax.nn.silu(g)
    return out @ p["w_o"]


def rwkv_decode(p: Dict, x: jax.Array, state: Dict[str, jax.Array],
                head_dim: int) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token RWKV step.  state = {"wkv": (B,H,hd,hd),
    "x_prev": (B,d)}; x (B, 1, d)."""
    b, _, d = x.shape
    h = d // head_dim
    x_t = x[:, 0]
    r, k, v, g, decay = _rwkv_mix(p, x_t, state["x_prev"])

    def heads(t):
        return t.reshape(b, h, head_dim).astype(jnp.float32)
    r_, k_, v_, w_ = heads(r), heads(k), heads(v), heads(decay)
    u = p["bonus_u"].astype(jnp.float32)
    kv = k_[..., :, None] * v_[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv",
                     r_, state["wkv"] + u[None, :, :, None] * kv)
    new_wkv = w_[..., :, None] * state["wkv"] + kv
    out = out.reshape(b, d).astype(x.dtype)
    out = rmsnorm(p["ln_x"], out)
    out = out * jax.nn.silu(g)
    return (out @ p["w_o"])[:, None, :], \
        {"wkv": new_wkv, "x_prev": x_t}


def rwkv_init_state(b: int, d: int, head_dim: int,
                    dtype=jnp.float32) -> Dict[str, jax.Array]:
    h = d // head_dim
    return {"wkv": jnp.zeros((b, h, head_dim, head_dim), jnp.float32),
            "x_prev": jnp.zeros((b, d), dtype)}


# ====================================================================== #
# RG-LRU (RecurrentGemma / Griffin)
# ====================================================================== #
def rglru_params(key: jax.Array, d: int, lru_width: int, *,
                 conv_width: int = 4, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    w = lru_width
    return {
        "w_x": dense_init(ks[0], d, w, dtype),     # input branch
        "w_y": dense_init(ks[1], d, w, dtype),     # gate branch (GeGLU-ish)
        "conv_w": (jax.random.normal(ks[2], (conv_width, w)) * 0.1
                   ).astype(dtype),
        # recurrence gates
        "w_input_gate": dense_init(ks[3], w, w, dtype),
        "w_rec_gate": dense_init(ks[4], w, w, dtype),
        # Λ parameter: a = exp(-c·softplus(Λ)·sigmoid(rec_gate))
        "log_lambda": jnp.linspace(0.5, 4.0, w).astype(dtype),
        "w_o": dense_init(ks[5], w, d, dtype),
    }


_RG_C = 8.0


def _rglru_gates(p: Dict, xw: jax.Array):
    """Per-step gate computation: xw (..., w)."""
    i_gate = jax.nn.sigmoid(xw.astype(jnp.float32)
                            @ p["w_input_gate"].astype(jnp.float32))
    r_gate = jax.nn.sigmoid(xw.astype(jnp.float32)
                            @ p["w_rec_gate"].astype(jnp.float32))
    log_a = -_RG_C * jax.nn.softplus(
        p["log_lambda"].astype(jnp.float32)) * r_gate
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) normalizer, computed stably from log a
    norm = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12))
    return a, norm * i_gate


def rglru_apply(p: Dict, x: jax.Array) -> jax.Array:
    """Training-mode recurrent block: x (B, S, d) → (B, S, d).
    conv1d (causal, width 4) → gated LRU scan → GeGLU-style merge."""
    b, s, d = x.shape
    xw = x @ p["w_x"]                                     # (B,S,w)
    gate = jax.nn.gelu(x @ p["w_y"])
    # causal depthwise conv
    cw = p["conv_w"].shape[0]
    pad = jnp.pad(xw, ((0, 0), (cw - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + s] * p["conv_w"][i] for i in range(cw))
    a, scale = _rglru_gates(p, conv)                      # (B,S,w) each
    v = scale * conv.astype(jnp.float32)

    def step(h, inp):
        a_t, v_t = inp
        h = a_t * h + v_t
        return h, h

    init = jnp.zeros((b, xw.shape[-1]), jnp.float32)
    _, hs = jax.lax.scan(step, init,
                         (jnp.moveaxis(a, 1, 0), jnp.moveaxis(v, 1, 0)))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)            # (B,S,w)
    return (h * gate) @ p["w_o"]


def rglru_decode(p: Dict, x: jax.Array, state: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-step RG-LRU.  state = {"h": (B,w), "conv": (B,cw-1,w)}."""
    b, _, d = x.shape
    x_t = x[:, 0]
    xw = x_t @ p["w_x"]                                   # (B,w)
    gate = jax.nn.gelu(x_t @ p["w_y"])
    cw = p["conv_w"].shape[0]
    hist = jnp.concatenate([state["conv"], xw[:, None, :]], axis=1)
    conv = jnp.einsum("bcw,cw->bw", hist, p["conv_w"])
    a, scale = _rglru_gates(p, conv)
    h = a * state["h"] + scale * conv.astype(jnp.float32)
    out = (h.astype(x.dtype) * gate) @ p["w_o"]
    return out[:, None, :], {"h": h, "conv": hist[:, 1:]}


def rglru_init_state(b: int, lru_width: int, conv_width: int = 4,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {"h": jnp.zeros((b, lru_width), jnp.float32),
            "conv": jnp.zeros((b, conv_width - 1, lru_width), dtype)}
