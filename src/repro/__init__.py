"""repro: production-grade JAX reproduction of "Scaling Knowledge Graph
Embedding Models" (Sheikh et al., 2022) — self-sufficient graph partitions,
constraint-based negative sampling, edge mini-batch distributed training —
plus the assigned 10-architecture transformer substrate sharing the same
distributed runtime."""
__version__ = "0.1.0"
