"""Input pipelines: host mini-batch construction decoupled from the device
step (paper Fig. 6; DGL-KE's overlap argument).

The paper's component breakdown shows ``getComputeGraph`` — host mini-batch
construction — dominating epoch time on their stack.  Our serial trainer
reproduced that: build every partition's batch, then block on the device.
This module turns the host data path into a proper pipeline:

    worker thread (one per partition)
        iterate_edge_minibatches → bounded prefetch queue
    collator
        zip one batch per partition → stack on the trainer axis
    double buffer
        host→device transfer of batch k+1 while the device runs batch k

Three implementations share one contract (``InputPipeline``):

* ``SerialMinibatchPipeline`` — the reference: build inline, no overlap.
  Defines the ground-truth batch stream; the async pipeline must match it
  bitwise (see ``tests/test_pipeline.py``).
* ``AsyncMinibatchPipeline`` — one background worker per partition feeding a
  bounded ``queue.Queue``; batch streams are identical because each partition
  owns a deterministic per-epoch RNG and the collator zips queues in
  partition order (exactly the serial zip, truncated at the shortest stream).
* ``FullGraphPipeline`` — the full-edge-batch mode (one resident padded
  batch per epoch); trivially prefetched since the batch is device-cached.

Sharded embedding tables (``repro.sharding.embedding``): when a pipeline is
built with a ``table_layout``, the collator also precomputes each batch's
``ShardedGatherPlan`` — per-shard LOCAL gather indices + ownership masks for
the row-sharded entity table — on host, and ships it with the batch through
the same double-buffered transfer path (device keys ``shard_local_ids`` /
``shard_owned``).  The device step then never does index arithmetic for the
embedding exchange.

Real-mesh transfer (``BatchShardings``): with a mesh-aware sharding set, the
transfer thread ``jax.device_put``s each batch with per-axis
``NamedSharding``s instead of a single-device ``jnp.asarray`` — every
partition's slice of the stacked trainer axis lands directly on its own
``data``-axis device, and each table shard's gather-plan block on its own
``model``-axis device, so the double buffer overlaps host→ICI transfer with
the device step and no device ever holds another trainer's batch.  The
values are bitwise identical to the single-device path (``device_put`` moves
bits, it never rewrites them); on a 1-device mesh the two paths are
indistinguishable, which ``tests/test_pipeline.py`` enforces against the
serial reference.

Timing contract (``PipelineStats``): the steady-state clock starts at the
FIRST CONSUMED BATCH — the wait for it (queue warm-up / pipeline fill) is
reported separately as ``warmup_s``.  ``host_build_s`` is the construction
time of batches the consumer actually took after that point (prefetched
tail batches that are built but never consumed do not count — they hid
nothing); ``exposed_wait_s`` is the post-warm-up wait on the critical path.
``overlap_fraction`` = 1 − exposed/build is the benchmark's headline
number, now honest on short epochs.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.expansion import PaddedPartitionBatch, SelfSufficientPartition
from repro.core.minibatch import (
    BatchBudget, EdgeMiniBatch, _PartitionCSR, iterate_edge_minibatches,
    stack_minibatches,
)
from repro.sharding.embedding import (
    PLAN_BATCH_KEYS, ShardedGatherPlan, ShardedTableLayout,
    plan_local_gather,
)


class BatchShardings:
    """Per-axis device placements for the host→device batch transfer.

    Built from a mesh with a ``data`` axis (trainer/partition parallel) and
    a ``model`` axis (table shards): stacked batch fields — leading trainer
    axis — are ``device_put`` with ``P(data_axis)`` so each partition's
    slice lands on its own data-axis device, and the ``(P, S, V_b)`` gather
    plans with ``P(data_axis, model_axis)`` so each table shard's index
    block lands on its own model-axis device.  ``device_put`` of a host
    numpy array only places bits, so the transferred values are bitwise
    identical to the single-device ``jnp.asarray`` path — the sharded
    transfer changes WHERE batches live, never what they hold.
    """

    def __init__(self, mesh, data_axis: str = "data",
                 model_axis: str = "model"):
        from jax.sharding import NamedSharding, PartitionSpec as P
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        self.batch = NamedSharding(mesh, P(data_axis))
        self.plan = NamedSharding(mesh, P(data_axis, model_axis))

    @property
    def data_size(self) -> int:
        return int(self.mesh.shape[self.data_axis])

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    def check(self, num_partitions: int,
              table_layout: Optional["ShardedTableLayout"]) -> None:
        """Fail fast on layouts the mesh cannot split evenly (device_put
        would raise later, from inside a transfer thread)."""
        if num_partitions % self.data_size:
            raise ValueError(
                f"{num_partitions} partitions cannot be sharded over a "
                f"{self.data_size}-device {self.data_axis!r} axis")
        if table_layout is not None and \
                table_layout.num_shards % self.model_size:
            raise ValueError(
                f"{table_layout.num_shards} table shards cannot be sharded "
                f"over a {self.model_size}-device {self.model_axis!r} axis")


@dataclasses.dataclass
class PipelineStats:
    """Per-epoch host-side timing of one pipeline run.

    The clock starts at the first consumed batch: ``warmup_s`` is the wait
    for that batch (pipeline fill — unavoidable, and previously conflated
    with steady-state exposure), while ``host_build_s`` /
    ``exposed_wait_s`` cover only the steady state after it.
    ``host_build_s`` counts construction time of CONSUMED batches (build
    times travel with each batch from its worker), so the prefetched tail
    past the shortest partition stream no longer inflates the overlap
    fraction on short epochs.  When workers overlap the device step the
    build times include GIL/scheduler interference, so they upper-bound the
    pure CPU cost (serial runs measure the pure cost).
    """

    host_build_s: float = 0.0    # build time of consumed steady-state batches
    exposed_wait_s: float = 0.0  # construction time on the critical path
    warmup_s: float = 0.0        # wait for the first batch (pipeline fill)
    num_batches: int = 0

    def overlap_fraction(self) -> float:
        """Fraction of steady-state host build time hidden behind the
        device step."""
        if self.host_build_s <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.exposed_wait_s / self.host_build_s)


def to_device_batch(
    mb: EdgeMiniBatch,
    table_layout: Optional[ShardedTableLayout] = None,
    shardings: Optional[BatchShardings] = None,
    dedup_gather: bool = False,
) -> Dict[str, "jax.Array"]:
    """Host→device transfer of one stacked mini-batch (field-name dict, the
    layout the SPMD step consumes).  With a ``table_layout`` the batch also
    carries its host-precomputed per-shard gather plan
    (``shard_local_ids`` / ``shard_owned``, trainer axis leading); with
    ``dedup_gather`` the plan covers each trainer row's UNIQUE ids plus the
    ``shard_inverse`` expansion map, so the device exchange moves each hot
    entity once (bitwise-identical output — same rows, gathered once).
    With ``shardings`` the transfer is a per-axis ``jax.device_put`` — each
    partition slice to its own ``data``-axis device, each gather-plan shard
    block to its own ``model``-axis device, the ``(P, V_b)`` inverse riding
    the batch placement — instead of a single-device ``jnp.asarray``; the
    values are bitwise identical either way."""
    import jax
    import jax.numpy as jnp
    if shardings is None:
        put_batch = put_plan = jnp.asarray
    else:
        def put_batch(x):
            return jax.device_put(x, shardings.batch)

        def put_plan(x):
            return jax.device_put(x, shardings.plan)
    out = {f.name: put_batch(getattr(mb, f.name))
           for f in dataclasses.fields(mb)}
    if table_layout is not None:
        plan = ShardedGatherPlan.for_stacked(
            table_layout, mb.gather_global, dedup=dedup_gather)
        out["shard_local_ids"] = put_plan(plan.local_ids)
        out["shard_owned"] = put_plan(plan.owned)
        if plan.inverse is not None:
            out["shard_inverse"] = put_batch(plan.inverse)
    return out


class InputPipeline:
    """One training epoch's worth of device-ready batches.

    ``epoch_batches(epoch)`` yields the HOST-side batch stream (stacked
    ``EdgeMiniBatch`` for mini-batch pipelines, a field dict for the
    full-graph pipeline); ``device_batches(epoch)`` yields the same stream as
    device arrays.  ``last_stats`` describes the most recently completed
    epoch.  Streams are deterministic functions of (seed, epoch), so any two
    implementations with the same parameters are interchangeable.
    """

    def __init__(
        self, table_layout: Optional[ShardedTableLayout] = None,
        shardings: Optional[BatchShardings] = None,
        dedup_gather: bool = False,
    ) -> None:
        self._stats = PipelineStats()
        self.table_layout = table_layout
        self.shardings = shardings
        self.dedup_gather = dedup_gather

    @property
    def last_stats(self) -> PipelineStats:
        return self._stats

    def epoch_batches(self, epoch: int) -> Iterator:
        raise NotImplementedError

    def device_batches(self, epoch: int) -> Iterator[Dict]:
        for mb in self.epoch_batches(epoch):
            yield to_device_batch(mb, self.table_layout, self.shardings,
                                  self.dedup_gather)

    def close(self) -> None:
        """Release background resources (workers are per-epoch, so the base
        implementation has nothing to do)."""


# ====================================================================== #
# Mini-batch pipelines (Algorithm 1 inner loop)
# ====================================================================== #
class _MinibatchPipelineBase(InputPipeline):
    def __init__(
        self,
        partitions: Sequence[SelfSufficientPartition],
        batch_size: int,
        num_negatives: int,
        num_hops: int,
        budget: BatchBudget,
        seed: int = 0,
        sampler: str = "constraint",
        csrs: Optional[Sequence[_PartitionCSR]] = None,
        table_layout: Optional[ShardedTableLayout] = None,
        shardings: Optional[BatchShardings] = None,
        dedup_gather: bool = False,
    ):
        super().__init__(table_layout, shardings, dedup_gather)
        if shardings is not None:
            shardings.check(len(partitions), table_layout)
        self.partitions = list(partitions)
        self.batch_size = batch_size
        self.num_negatives = num_negatives
        self.num_hops = num_hops
        self.budget = budget
        self.seed = seed
        self.sampler = sampler
        self.csrs = list(csrs) if csrs is not None else [
            _PartitionCSR(p) for p in self.partitions]

    def partition_stream(self, epoch: int, i: int) -> Iterator[EdgeMiniBatch]:
        """Partition ``i``'s deterministic batch stream for ``epoch`` — the
        unit of work a serial step or an async worker consumes.  The RNG
        derivation is the pipeline's reproducibility contract: any two
        pipelines with equal (seed, epoch, i) produce equal streams."""
        rng = np.random.default_rng(
            hash((self.seed, epoch, i)) % (2 ** 31))
        return iterate_edge_minibatches(
            rng, self.partitions[i], self.batch_size, self.num_negatives,
            self.num_hops, self.budget, self.csrs[i], self.sampler)


class SerialMinibatchPipeline(_MinibatchPipelineBase):
    """Reference implementation: builds every partition's batch inline, so
    all host work is exposed (``overlap_fraction == 0``)."""

    def epoch_batches(self, epoch: int) -> Iterator[EdgeMiniBatch]:
        stats = self._stats = PipelineStats()
        iters = [self.partition_stream(epoch, i)
                 for i in range(len(self.partitions))]
        while True:
            t0 = time.perf_counter()
            try:
                mbs = [next(it) for it in iters]
            except StopIteration:
                break
            dt = time.perf_counter() - t0
            if stats.num_batches == 0:
                # the serial analogue of pipeline fill: the first batch's
                # build IS its wait, and the steady-state clock starts after
                stats.warmup_s += dt
            else:
                stats.host_build_s += dt
                stats.exposed_wait_s += dt
            stats.num_batches += 1
            yield stack_minibatches(mbs)


class _PipelineError:
    """Sentinel carrying a worker exception to the consumer thread."""

    def __init__(self, exc: BaseException):
        self.exc = exc


_END = object()


def _put(q: "queue.Queue", item, stop: threading.Event) -> bool:
    """Blocking put that gives up when the consumer signalled stop (so
    workers never deadlock on a full queue after early termination)."""
    while not stop.is_set():
        try:
            q.put(item, timeout=0.05)
            return True
        except queue.Full:
            continue
    return False


def _get(q: "queue.Queue", stop: threading.Event):
    """Blocking get that resolves to end-of-stream when stop is signalled
    and nothing is left (a producer that aborted on stop puts no sentinel)."""
    while True:
        try:
            return q.get(timeout=0.05)
        except queue.Empty:
            if stop.is_set():
                return _END


class AsyncMinibatchPipeline(_MinibatchPipelineBase):
    """One background worker per partition feeding a bounded prefetch queue;
    ``device_batches`` adds a collator thread that stacks + transfers the
    next batch while the device executes the current one (double buffer).

    Yields the bitwise-identical stream to ``SerialMinibatchPipeline``: each
    partition's RNG and batch order live entirely in its own worker, and the
    collator consumes queues in partition order, stopping at the first
    exhausted stream — the same zip-shortest semantics as the serial loop.
    """

    def __init__(self, *args, prefetch: int = 2, **kwargs):
        super().__init__(*args, **kwargs)
        if prefetch < 1:
            raise ValueError("prefetch depth must be >= 1")
        self.prefetch = prefetch

    # ------------------------------------------------------------------ #
    def _start_workers(self, epoch: int, stop: threading.Event):
        n = len(self.partitions)
        queues: List[queue.Queue] = [
            queue.Queue(maxsize=self.prefetch) for _ in range(n)]

        def work(i: int) -> None:
            try:
                it = self.partition_stream(epoch, i)
                while not stop.is_set():
                    t0 = time.perf_counter()
                    try:
                        mb = next(it)
                    except StopIteration:
                        break
                    # ship the build time WITH the batch: only consumed
                    # batches count toward host_build_s (the prefetched
                    # tail hid nothing)
                    if not _put(queues[i],
                                (mb, time.perf_counter() - t0), stop):
                        return
                _put(queues[i], _END, stop)
            except BaseException as exc:  # propagate into the consumer
                _put(queues[i], _PipelineError(exc), stop)

        threads = [
            threading.Thread(target=work, args=(i,),
                             name=f"pipeline-worker-{i}", daemon=True)
            for i in range(n)
        ]
        for t in threads:
            t.start()
        return queues, threads

    def _shutdown(self, stop, queues, threads) -> None:
        stop.set()
        for q in queues:            # unblock workers stuck on a full queue
            while True:
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
        for t in threads:
            t.join(timeout=5.0)

    def _collate(self, queues, stats: PipelineStats, stop: threading.Event,
                 timed: bool):
        """Zip one batch per partition queue (partition order), stacking on
        the trainer axis; stop at the first exhausted stream.  Yields
        ``(stacked, build_s)`` pairs so the consumer end accounts build
        time only for batches actually taken.  With ``timed`` the stats
        are recorded here, lazily at the consumer's ``next()`` (the first
        batch's wait is queue warm-up, ``warmup_s``; the steady-state
        clock starts after it); untimed mode (the device path, where a
        collator thread runs ahead of the consumer) mutates no stats."""
        first = True
        while True:
            mbs = []
            wait = build = 0.0
            for q in queues:
                t0 = time.perf_counter()
                item = _get(q, stop)
                wait += time.perf_counter() - t0
                if isinstance(item, _PipelineError):
                    raise RuntimeError(
                        "input pipeline worker failed") from item.exc
                if item is _END:
                    return
                mb, dt = item
                build += dt
                mbs.append(mb)
            if timed:
                if first:
                    stats.warmup_s += wait
                else:
                    stats.host_build_s += build
                    stats.exposed_wait_s += wait
                stats.num_batches += 1
            first = False
            yield stack_minibatches(mbs), build

    # ------------------------------------------------------------------ #
    def epoch_batches(self, epoch: int) -> Iterator[EdgeMiniBatch]:
        stats = self._stats = PipelineStats()
        stop = threading.Event()
        queues, threads = self._start_workers(epoch, stop)
        try:
            for mb, _build in self._collate(queues, stats, stop,
                                            timed=True):
                yield mb
        finally:
            self._shutdown(stop, queues, threads)

    def device_batches(self, epoch: int) -> Iterator[Dict]:
        """Double-buffered host→device path: a collator thread stacks the
        partition batches, attaches the sharded-table gather plan (when a
        ``table_layout`` is set) and issues the device transfer — a
        per-axis sharded ``device_put`` when the pipeline carries
        ``BatchShardings`` — one step ahead, so the consumer's ``next()``
        returns an already-resident (and already-placed) batch while the
        device executes the previous one."""
        stats = self._stats = PipelineStats()
        stop = threading.Event()
        queues, threads = self._start_workers(epoch, stop)
        xfer_q: queue.Queue = queue.Queue(maxsize=2)   # double buffer

        def collate_and_transfer() -> None:
            try:
                for mb, build in self._collate(queues, stats, stop,
                                               timed=False):
                    if not _put(xfer_q,
                                (to_device_batch(mb, self.table_layout,
                                                 self.shardings,
                                                 self.dedup_gather),
                                 build),
                                stop):
                        return
                _put(xfer_q, _END, stop)
            except BaseException as exc:
                _put(xfer_q, _PipelineError(exc), stop)

        collator = threading.Thread(
            target=collate_and_transfer, name="pipeline-collator",
            daemon=True)
        collator.start()
        try:
            first = True
            while True:
                t0 = time.perf_counter()
                item = _get(xfer_q, stop)
                dt = time.perf_counter() - t0
                if isinstance(item, _PipelineError):
                    raise RuntimeError(
                        "input pipeline worker failed") from item.exc
                if item is _END:
                    return
                batch, build = item
                # consumed-batch accounting only: the collator runs up to
                # the transfer-queue depth ahead, and batches it built
                # that the consumer never takes must not count
                if first:
                    stats.warmup_s += dt
                    first = False
                else:
                    stats.host_build_s += build
                    stats.exposed_wait_s += dt
                stats.num_batches += 1
                yield batch
        finally:
            stop.set()
            while True:
                try:
                    xfer_q.get_nowait()
                except queue.Empty:
                    break
            collator.join(timeout=5.0)
            self._shutdown(stop, queues, threads)


# ====================================================================== #
# Full-graph pipeline (paper's FB15k-237 configuration)
# ====================================================================== #
class FullGraphPipeline(InputPipeline):
    """One full-edge-batch per epoch: every padded partition stacked on the
    trainer axis, transferred to device ONCE and reused every epoch (the
    batch is epoch-invariant; per-epoch randomness lives in the PRNG keys).
    With a ``table_layout`` the resident batch carries its gather plan for
    ``local_to_global`` (also epoch-invariant, so precomputed once)."""

    def __init__(self, padded: PaddedPartitionBatch,
                 table_layout: Optional[ShardedTableLayout] = None,
                 shardings: Optional[BatchShardings] = None):
        super().__init__(table_layout, shardings)
        if shardings is not None:
            shardings.check(padded.num_partitions, table_layout)
        self._host = {f.name: getattr(padded, f.name)
                      for f in dataclasses.fields(padded)}
        if table_layout is not None:
            plan = ShardedGatherPlan.for_stacked(
                table_layout, self._host["local_to_global"])
            self._host["shard_local_ids"] = plan.local_ids
            self._host["shard_owned"] = plan.owned
        self._device: Optional[Dict] = None

    def epoch_batches(self, epoch: int) -> Iterator[Dict]:
        self._stats = PipelineStats(num_batches=1)
        yield self._host

    def device_batches(self, epoch: int) -> Iterator[Dict]:
        import jax
        import jax.numpy as jnp
        if self._device is None:
            if self.shardings is None:
                self._device = {k: jnp.asarray(v)
                                for k, v in self._host.items()}
            else:
                self._device = {
                    k: jax.device_put(
                        v, self.shardings.plan if k in PLAN_BATCH_KEYS
                        else self.shardings.batch)
                    for k, v in self._host.items()}
        self._stats = PipelineStats(num_batches=1)
        yield self._device


def eval_partition_batches(
    padded: PaddedPartitionBatch,
    table_layout: Optional[ShardedTableLayout] = None,
) -> Iterator[Dict]:
    """Per-partition device batches for the eval-time encoder pass.

    The evaluation twin of ``FullGraphPipeline``'s resident batch: yields
    one partition slice of the padded batch at a time (the encoder streams
    partitions instead of materializing one full-graph mega-partition), and
    with a row-sharded entity table attaches the host-precomputed
    ``ShardedGatherPlan`` for the slice's ``local_to_global`` gather — the
    same plan the training collator ships with every mini-batch, so
    ``encode_partition`` never plans indices in-jit on this path.
    """
    import jax.numpy as jnp
    for i in range(padded.num_partitions):
        part = {f.name: jnp.asarray(getattr(padded, f.name)[i])
                for f in dataclasses.fields(padded)}
        if table_layout is not None:
            local, owned = plan_local_gather(
                table_layout, np.asarray(padded.local_to_global[i]))
            part["shard_local_ids"] = jnp.asarray(local)
            part["shard_owned"] = jnp.asarray(owned)
        yield part


# ====================================================================== #
# Factory
# ====================================================================== #
PIPELINES = {
    "serial": SerialMinibatchPipeline,
    "async": AsyncMinibatchPipeline,
}


def make_input_pipeline(
    kind: str,
    partitions: Sequence[SelfSufficientPartition],
    *,
    batch_size: int,
    num_negatives: int,
    num_hops: int,
    budget: BatchBudget,
    seed: int = 0,
    sampler: str = "constraint",
    csrs: Optional[Sequence[_PartitionCSR]] = None,
    prefetch: int = 2,
    table_layout: Optional[ShardedTableLayout] = None,
    shardings: Optional[BatchShardings] = None,
    dedup_gather: bool = False,
) -> InputPipeline:
    """Build a mini-batch input pipeline (``serial`` reference or ``async``
    prefetching); ``table_layout`` makes every device batch carry its
    sharded-table gather plan (deduplicated per trainer row with
    ``dedup_gather``), ``shardings`` makes the transfer a per-axis sharded
    ``device_put`` onto a real mesh."""
    if kind not in PIPELINES:
        raise ValueError(
            f"unknown pipeline {kind!r}; choose from {sorted(PIPELINES)}")
    kw = dict(batch_size=batch_size, num_negatives=num_negatives,
              num_hops=num_hops, budget=budget, seed=seed, sampler=sampler,
              csrs=csrs, table_layout=table_layout, shardings=shardings,
              dedup_gather=dedup_gather)
    if kind == "async":
        kw["prefetch"] = prefetch
    return PIPELINES[kind](partitions, **kw)
