"""Data pipeline: KG datasets (real-format loader + synthetic stand-ins),
LM token streams, and the async/serial input pipelines feeding the SPMD
training step."""
from repro.data.datasets import (
    load_fb15k_format, synthetic_fb15k, synthetic_citation2,
    load_or_synthesize, TokenStream,
)
from repro.data.pipeline import (
    AsyncMinibatchPipeline, BatchShardings, FullGraphPipeline, InputPipeline,
    PipelineStats, SerialMinibatchPipeline, eval_partition_batches,
    make_input_pipeline, to_device_batch,
)
__all__ = ["load_fb15k_format", "synthetic_fb15k", "synthetic_citation2",
           "load_or_synthesize", "TokenStream",
           "AsyncMinibatchPipeline", "BatchShardings", "FullGraphPipeline",
           "InputPipeline", "PipelineStats", "SerialMinibatchPipeline",
           "make_input_pipeline", "eval_partition_batches",
           "to_device_batch"]
