"""Data pipeline: KG datasets (real-format loader + synthetic stand-ins) and
LM token streams."""
from repro.data.datasets import (
    load_fb15k_format, synthetic_fb15k, synthetic_citation2,
    load_or_synthesize, TokenStream,
)
__all__ = ["load_fb15k_format", "synthetic_fb15k", "synthetic_citation2",
           "load_or_synthesize", "TokenStream"]
