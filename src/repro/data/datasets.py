"""Data pipeline: KG dataset loading + synthetic benchmark graphs.

* FB15k-237-format loader: ``train.txt``/``valid.txt``/``test.txt`` TSV of
  ``head<TAB>relation<TAB>tail`` surface forms (the standard distribution
  format); builds entity/relation vocabularies from the train split.
* ``synthetic_fb15k`` / ``synthetic_citation2`` — offline stand-ins with the
  same *shape characteristics* (relation count, skew, feature presence) at
  reduced scale, used by tests and benchmarks (no internet in this
  container; real files drop in transparently).
* ``TokenStream`` — deterministic token batches for LM smoke tests.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.graph import KnowledgeGraph, make_synthetic_kg, \
    split_train_valid_test


def load_fb15k_format(directory: str) -> Dict[str, KnowledgeGraph]:
    """Load a directory of {train,valid,test}.txt triplet TSVs."""
    vocabs: Dict[str, Dict[str, int]] = {"ent": {}, "rel": {}}

    def intern(table: Dict[str, int], key: str) -> int:
        if key not in table:
            table[key] = len(table)
        return table[key]

    raw: Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
    for split in ("train", "valid", "test"):
        path = os.path.join(directory, f"{split}.txt")
        src, rel, dst = [], [], []
        with open(path) as f:
            for line in f:
                h, r, t = line.rstrip("\n").split("\t")
                src.append(intern(vocabs["ent"], h))
                rel.append(intern(vocabs["rel"], r))
                dst.append(intern(vocabs["ent"], t))
        raw[split] = (np.array(src, np.int32), np.array(rel, np.int32),
                      np.array(dst, np.int32))

    n_ent = len(vocabs["ent"])
    n_rel = len(vocabs["rel"])
    return {
        split: KnowledgeGraph(
            src=s, rel=r, dst=d, num_entities=n_ent, num_relations=n_rel)
        for split, (s, r, d) in raw.items()
    }


def synthetic_fb15k(scale: float = 0.05, seed: int = 0
                    ) -> Dict[str, KnowledgeGraph]:
    """FB15k-237-shaped synthetic KG: many relation types, no features,
    transductive (learned entity embeddings)."""
    n_ent = max(200, int(14541 * scale))
    n_rel = max(8, int(237 * scale))
    n_edge = max(2000, int(272115 * scale))
    kg = make_synthetic_kg(n_ent, n_rel, n_edge, seed=seed)
    return split_train_valid_test(kg, 0.06, 0.07, seed=seed)


def synthetic_citation2(scale: float = 0.002, seed: int = 0
                        ) -> Dict[str, KnowledgeGraph]:
    """ogbl-citation2-shaped synthetic KG: single relation, 128-d features."""
    n_ent = max(500, int(2_927_963 * scale))
    n_edge = max(4000, int(30_387_995 * scale))
    kg = make_synthetic_kg(n_ent, 1, n_edge, seed=seed, feature_dim=128)
    return split_train_valid_test(kg, 0.003, 0.003, seed=seed)


def load_or_synthesize(name: str, data_root: Optional[str] = None,
                       **kw) -> Dict[str, KnowledgeGraph]:
    """Use real data when present under ``data_root/<name>``, else the
    synthetic stand-in (documented in EXPERIMENTS.md)."""
    if data_root:
        path = os.path.join(data_root, name)
        if os.path.isdir(path):
            return load_fb15k_format(path)
    if name == "fb15k-237":
        return synthetic_fb15k(**kw)
    if name == "ogbl-citation2":
        return synthetic_citation2(**kw)
    raise ValueError(f"unknown dataset {name!r}")


class TokenStream:
    """Deterministic synthetic LM token batches (data pipeline for the
    transformer-substrate smoke tests and the example trainers)."""

    def __init__(self, vocab_size: int, batch_size: int, seq_len: int,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.batch_size = batch_size
        self.seq_len = seq_len
        self._rng = np.random.default_rng(seed)

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        # Markov-ish stream so the loss has learnable structure.
        base = self._rng.integers(
            0, self.vocab_size, (self.batch_size, self.seq_len + 1))
        base[:, 1::2] = (base[:, 0::2][:, : base[:, 1::2].shape[1]]
                         * 31 + 7) % self.vocab_size
        return {
            "tokens": base[:, :-1].astype(np.int32),
            "labels": base[:, 1:].astype(np.int32),
        }
