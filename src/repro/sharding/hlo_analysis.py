"""HLO-text analysis: loop-aware per-device FLOPs / HBM bytes / collective
bytes for the roofline.

Why not ``compiled.cost_analysis()``: the Python API counts a ``while`` body
ONCE regardless of trip count, so a scanned 64-layer stack (or a nested
chunked-attention scan) under-reports by the trip product.  This module
parses the post-optimization (SPMD, per-device) HLO module text instead:

1. computations are split out; ``while`` ops give a body/condition graph;
2. each loop's trip count is recovered from the loop-condition computation
   (scan lowers to ``i < N`` with a literal ``constant(N)``) — nested loops
   multiply;
3. FLOPs come from ``dot`` ops (2 · numel(result) · contraction size) —
   validated at 98% of XLA's own flops on trip-1 modules;
4. HBM bytes follow XLA's convention (operand + result bytes per
   instruction), restricted to top-level computations (entry + loop bodies;
   fusion-internal ops are accounted by their fusion's operands/results);
5. collective bytes sum result sizes of all-reduce (×2, ring) / all-gather /
   reduce-scatter / all-to-all / collective-permute.

All byte/FLOP numbers are per device (the module is the per-device SPMD
program).

The instruction/shape grammar and the dtype/collective tables live in
``repro.analysis.hlo`` — one parsing core shared with the SPMD contract
auditor (``repro.analysis.contracts``), so rank-0 (``f32[]``) and
nested-tuple collective results are counted correctly here too.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

from repro.analysis.hlo import (
    COLLECTIVE_KINDS, COLLECTIVE_WIRE_FACTOR, HEADER_RE, HloModule,
    OPERAND_RE, PARAM_RE, first_shape_dims, iter_collectives,
    parse_instruction, shape_bytes,
)

_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def collective_stats(hlo_text: str, loop_trip_count: int = 1
                     ) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes}, per device, loop-scaled.
    ``loop_trip_count`` is only the FALLBACK when a loop condition's trip
    constant can't be parsed."""
    mod = HloModule(hlo_text, default_trip=loop_trip_count)
    stats = {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVE_KINDS}
    for c in iter_collectives(mod):
        stats[c.kind]["count"] += c.scale
        stats[c.kind]["bytes"] += c.wire_bytes
    return stats


def total_collective_bytes(hlo_text: str, loop_trip_count: int = 1
                           ) -> Tuple[float, Dict]:
    stats = collective_stats(hlo_text, loop_trip_count)
    return sum(v["bytes"] for v in stats.values()), stats


# ops charged to HBM traffic in top-level computations
_MEM_OPS = {"fusion", "dot", "copy", "convert", "bitcast-convert",
            "transpose", "reshape", "broadcast", "reduce", "concatenate",
            "dynamic-update-slice", "dynamic-slice", "slice", "pad",
            "gather", "scatter", "iota", "add", "multiply", "subtract",
            "divide", "exponential", "tanh", "select", "compare",
            "maximum", "minimum", "rsqrt", "negate", "convolution"}


def analyze_hlo(hlo_text: str, loop_trip_count: int = 1
                ) -> Dict[str, float]:
    """Loop-aware {flops, bytes} totals (per device)."""
    mod = HloModule(hlo_text, default_trip=loop_trip_count)
    flops_total = 0.0
    bytes_total = 0.0

    for comp, lines in mod.comps.items():
        scale = mod.multiplier(comp)
        shapes: Dict[str, str] = {}
        header = HEADER_RE.match(lines[0]) if lines else None
        if header:
            for pname, ptype in PARAM_RE.findall(header.group(2)):
                shapes[pname] = ptype
        top = mod.top_level(comp)
        for line in lines[1:]:
            inst = parse_instruction(line)
            if inst is None:
                continue
            shapes[inst.name] = inst.type_str
            if inst.op == "dot":
                dims = _CONTRACT_RE.search(line)
                contract = 1
                operands = OPERAND_RE.findall(inst.rest.split(")")[0])
                if dims and operands:
                    lhs = first_shape_dims(shapes.get(operands[0], ""))
                    if lhs:
                        for d in dims.group(1).split(","):
                            if d:
                                contract *= lhs[int(d)]
                out_dims = first_shape_dims(inst.type_str) or []
                numel = 1
                for d in out_dims:
                    numel *= d
                flops_total += scale * 2.0 * numel * contract
            if top and inst.op in _MEM_OPS:
                operand_bytes = 0
                for name in OPERAND_RE.findall(inst.rest.split("),")[0]):
                    operand_bytes += shape_bytes(shapes.get(name, ""))
                bytes_total += scale * (shape_bytes(inst.type_str)
                                        + operand_bytes)
    return {"flops": flops_total, "bytes": bytes_total}


# re-exported for callers that sized buffers off the roofline tables
__all__ = [
    "COLLECTIVE_KINDS", "COLLECTIVE_WIRE_FACTOR", "analyze_hlo",
    "collective_stats", "total_collective_bytes",
]
