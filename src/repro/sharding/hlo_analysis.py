"""HLO-text analysis: loop-aware per-device FLOPs / HBM bytes / collective
bytes for the roofline.

Why not ``compiled.cost_analysis()``: the Python API counts a ``while`` body
ONCE regardless of trip count, so a scanned 64-layer stack (or a nested
chunked-attention scan) under-reports by the trip product.  This module
parses the post-optimization (SPMD, per-device) HLO module text instead:

1. computations are split out; ``while`` ops give a body/condition graph;
2. each loop's trip count is recovered from the loop-condition computation
   (scan lowers to ``i < N`` with a literal ``constant(N)``) — nested loops
   multiply;
3. FLOPs come from ``dot`` ops (2 · numel(result) · contraction size) —
   validated at 98% of XLA's own flops on trip-1 modules;
4. HBM bytes follow XLA's convention (operand + result bytes per
   instruction), restricted to top-level computations (entry + loop bodies;
   fusion-internal ops are accounted by their fusion's operands/results);
5. collective bytes sum result sizes of all-reduce (×2, ring) / all-gather /
   reduce-scatter / all-to-all / collective-permute.

All byte/FLOP numbers are per device (the module is the per-device SPMD
program).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\)|[\w.\-]+\[[0-9,]*\](?:\{[0-9,]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*((?:\([^)]*\)|[\w\[\],{} ]+))")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|comparator)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(1 + 1).split(",") if d]


class _Module:
    """Parsed HLO module: computations, loop graph, trip multipliers."""

    def __init__(self, hlo_text: str, default_trip: int = 1):
        self.comps: Dict[str, List[str]] = {}
        self.entry: str = ""
        cur: Optional[List[str]] = None
        for line in hlo_text.splitlines():
            h = _HEADER_RE.match(line)
            if h and line.rstrip().endswith("{"):
                name = h.group(1)
                cur = []
                self.comps[name] = cur
                if line.lstrip().startswith("ENTRY"):
                    self.entry = name
                # parameters as pseudo-defs for the shape table
                cur.append(line)
                continue
            if cur is not None:
                cur.append(line)
                if line.strip() == "}":
                    cur = None

        # loop graph: parent comp -> [(body, cond, trip)]
        self.loops: Dict[str, List[Tuple[str, str, int]]] = {}
        self.call_targets = set()
        for name, lines in self.comps.items():
            for line in lines:
                b = _BODY_RE.search(line)
                c = _COND_RE.search(line)
                if b and c:
                    trip = self._trip_from_cond(c.group(1), default_trip)
                    self.loops.setdefault(name, []).append(
                        (b.group(1), c.group(1), trip))
                for t in _CALLS_RE.findall(line):
                    self.call_targets.add(t)

        # multipliers by DFS from entry
        self.mult: Dict[str, float] = {}
        if self.entry:
            self._assign(self.entry, 1.0)
        # computations never reached (e.g. dead) default to 1 when visited

    def _trip_from_cond(self, cond: str, default: int) -> int:
        lines = self.comps.get(cond, [])
        consts = [int(m.group(1)) for line in lines
                  for m in [_CONST_RE.search(line)] if m]
        return max(consts) if consts else default

    def _assign(self, comp: str, mult: float, depth: int = 0) -> None:
        if depth > 32:
            return
        self.mult[comp] = max(self.mult.get(comp, 0.0), mult)
        for body, cond, trip in self.loops.get(comp, []):
            self._assign(body, mult * trip, depth + 1)
            self._assign(cond, mult * trip, depth + 1)

    def multiplier(self, comp: str) -> float:
        return self.mult.get(comp, 1.0)

    def top_level(self, comp: str) -> bool:
        """entry / loop bodies / conds — not fusion internals."""
        return comp == self.entry or comp not in self.call_targets


def collective_stats(hlo_text: str, loop_trip_count: int = 1
                     ) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes}, per device, loop-scaled.
    ``loop_trip_count`` is only the FALLBACK when a loop condition's trip
    constant can't be parsed."""
    mod = _Module(hlo_text, default_trip=loop_trip_count)
    stats = {k: {"count": 0.0, "bytes": 0.0} for k in _COLLECTIVES}
    op_re = re.compile(
        r"=\s*(\([^()]*\)|[\w.\-]+\[[0-9,]*\](?:\{[0-9,]*\})?)\s+(" +
        "|".join(_COLLECTIVES) + r")(-start)?\(")
    for comp, lines in mod.comps.items():
        scale = mod.multiplier(comp)
        for line in lines:
            if "-done(" in line:
                continue
            m = op_re.search(line)
            if not m:
                continue
            size = _shape_bytes(m.group(1))
            kind = m.group(2)
            mult = 2.0 if kind == "all-reduce" else 1.0
            stats[kind]["count"] += scale
            stats[kind]["bytes"] += scale * mult * size
    return stats


def total_collective_bytes(hlo_text: str, loop_trip_count: int = 1
                           ) -> Tuple[float, Dict]:
    stats = collective_stats(hlo_text, loop_trip_count)
    return sum(v["bytes"] for v in stats.values()), stats


# ops charged to HBM traffic in top-level computations
_MEM_OPS = {"fusion", "dot", "copy", "convert", "bitcast-convert",
            "transpose", "reshape", "broadcast", "reduce", "concatenate",
            "dynamic-update-slice", "dynamic-slice", "slice", "pad",
            "gather", "scatter", "iota", "add", "multiply", "subtract",
            "divide", "exponential", "tanh", "select", "compare",
            "maximum", "minimum", "rsqrt", "negate", "convolution"}


def analyze_hlo(hlo_text: str, loop_trip_count: int = 1
                ) -> Dict[str, float]:
    """Loop-aware {flops, bytes} totals (per device)."""
    mod = _Module(hlo_text, default_trip=loop_trip_count)
    flops_total = 0.0
    bytes_total = 0.0

    for comp, lines in mod.comps.items():
        scale = mod.multiplier(comp)
        shapes: Dict[str, str] = {}
        header = _HEADER_RE.match(lines[0]) if lines else None
        if header:
            for pname, ptype in _PARAM_RE.findall(header.group(2)):
                shapes[pname] = ptype
        top = mod.top_level(comp)
        for line in lines[1:]:
            m = _DEF_RE.match(line)
            if not m:
                continue
            var, vtype, op, rest = m.groups()
            shapes[var] = vtype
            if op == "dot":
                dims = _CONTRACT_RE.search(line)
                contract = 1
                operands = _OPERAND_RE.findall(rest.split(")")[0])
                if dims and operands:
                    lhs = _first_shape_dims(shapes.get(operands[0], ""))
                    if lhs:
                        for d in dims.group(1).split(","):
                            if d:
                                contract *= lhs[int(d)]
                out_dims = _first_shape_dims(vtype) or []
                numel = 1
                for d in out_dims:
                    numel *= d
                flops_total += scale * 2.0 * numel * contract
            if top and op in _MEM_OPS:
                operand_bytes = 0
                for name in _OPERAND_RE.findall(rest.split("),")[0]):
                    operand_bytes += _shape_bytes(shapes.get(name, ""))
                bytes_total += scale * (_shape_bytes(vtype) + operand_bytes)
    return {"flops": flops_total, "bytes": bytes_total}
