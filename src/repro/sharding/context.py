"""Activation-sharding context.

The model code is mesh-agnostic; drivers (dryrun/train/serve) install the
mesh here and ``shard_activation`` / ``shard_logits`` become
``with_sharding_constraint`` pins (batch over data(+pod), vocab over model).
Without an installed mesh they are no-ops, so tests and CPU examples run
unchanged.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE: dict = {"mesh": None, "dp": ()}


def install_mesh(mesh: Optional[Mesh]) -> None:
    if mesh is None:
        _STATE["mesh"] = None
        _STATE["dp"] = ()
        return
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    _STATE["mesh"] = mesh
    _STATE["dp"] = dp


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    prev = (_STATE["mesh"], _STATE["dp"])
    install_mesh(mesh)
    try:
        yield
    finally:
        _STATE["mesh"], _STATE["dp"] = prev


def _constraint(x: jax.Array, spec: P) -> jax.Array:
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _dp(batch_dim_size: int):
    """data-parallel axes if they divide the batch dim, else replicate."""
    mesh = _STATE["mesh"]
    dp = _STATE["dp"]
    if mesh is None or not dp:
        return None
    import numpy as np
    size = int(np.prod([mesh.shape[a] for a in dp]))
    if batch_dim_size % size:
        return None
    return dp if len(dp) > 1 else dp[0]


def shard_activation(h: jax.Array, seq_over_model: bool = False
                     ) -> jax.Array:
    """(B, S, d) residual-stream pin: batch over data(+pod); optionally the
    sequence dim over ``model`` (context parallelism — §Perf iteration for
    collective-bound prefill on head-indivisible archs)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return h
    spec = [None] * h.ndim
    spec[0] = _dp(h.shape[0])
    if seq_over_model and h.ndim >= 3 and \
            h.shape[1] % mesh.shape["model"] == 0:
        spec[1] = "model"
    return _constraint(h, P(*spec))


def shard_logits(logits: jax.Array) -> jax.Array:
    """(B, S, V) or (B, V): batch over data(+pod), vocab over model."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return logits
    v = logits.shape[-1]
    tensor = "model" if v % mesh.shape["model"] == 0 else None
    spec = [None] * logits.ndim
    spec[0] = _dp(logits.shape[0])
    spec[-1] = tensor
    return _constraint(logits, P(*spec))
