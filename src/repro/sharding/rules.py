"""Parameter / activation / cache sharding rules (DESIGN.md §5).

MaxText-style 2-D sharding: every large weight matrix is sharded over the
``fsdp`` axes (``data``, plus ``pod`` on the multi-pod mesh) on one dim and
over the ``tensor`` axis (``model``) on the other.  Expert tensors put the
expert dim on ``model`` (expert parallelism).  Rules are name-based with a
divisibility guard — a dim that doesn't divide the axis size falls back to
replication on that axis (recorded by the dry-run; several assigned archs
have head counts indivisible by 16, which is itself a roofline finding).

Logical axes:
  fsdp   → ("data",) single-pod, ("pod", "data") multi-pod
  tensor → ("model",)

KGE embedding tables (``repro.sharding.embedding``): the entity table is
row-sharded over ``model`` — as dense ``(V, d)`` the vocab dim goes on
``tensor``; in the prefetchable sharded layout ``(S, rows, d)`` the leading
shard dim goes on ``tensor`` (one row block per model-axis device).
Relation tables (``rel_diag`` / ``rel_vec`` / ``rel_complex`` /
``rel_phase`` — one per registered decoder) follow the
same row-wise rule for *storage* analysis; ``kge_param_specs`` — the spec
tree the shard_map train step consumes — keeps them replicated because the
compute path gathers them densely, and only the entity table goes through
the shard-local gather + psum exchange.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# trailing-dims logical rule per leaf name; leading (layer-stack) dims -> None
_RULES = {
    # embeddings / heads
    "embed": ("tensor", "fsdp"),          # (V, d): vocab on tensor
    "lm_head": ("fsdp", "tensor"),        # (d, V)
    "vision_proj": (None, "fsdp"),
    # attention
    "w_q": ("fsdp", "tensor"),
    "w_k": ("fsdp", "tensor"),
    "w_v": ("fsdp", "tensor"),
    "w_o": ("tensor", "fsdp"),
    "b_q": ("tensor",),
    "b_k": ("tensor",),
    "b_v": ("tensor",),
    # MLA
    "w_dkv": ("fsdp", None),
    "w_krope": ("fsdp", None),
    "w_ukv": (None, "tensor"),
    # MLP (2-D) and MoE experts (3-D, expert dim first)
    "w_in": ("fsdp", "tensor"),
    "w_gate": ("fsdp", "tensor"),
    "w_out": ("tensor", "fsdp"),
    "router": ("fsdp", None),
    # rwkv / rglru
    "w_r": ("fsdp", "tensor"),
    "w_g": ("fsdp", "tensor"),
    "w_x": ("fsdp", "tensor"),
    "w_y": ("fsdp", "tensor"),
    "w_input_gate": ("fsdp", "tensor"),
    "w_rec_gate": ("fsdp", "tensor"),
    "decay_A": ("fsdp", None),
    "decay_B": (None, "fsdp"),
    # KGE tables: rows over the model axis (repro.sharding.embedding)
    "entity_embedding": ("tensor", None),
    "rel_diag": ("tensor", None),
    "rel_vec": ("tensor", None),
    "rel_complex": ("tensor", None),
    "rel_phase": ("tensor", None),
}
_EXPERT_RULES = {   # under a "moe" scope, 3-D expert tensors
    "w_in": ("tensor", "fsdp", None),
    "w_gate": ("tensor", "fsdp", None),
    "w_out": ("tensor", None, "fsdp"),
}
# sharded-layout entity table (S, rows, d): shard dim on the model axis
_SHARDED_TABLE_RULES = {
    "entity_embedding": ("tensor", None, None),
}


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, logical) -> int:
    if logical == "fsdp":
        return int(np.prod([mesh.shape[a] for a in fsdp_axes(mesh)]))
    if logical == "tensor":
        return int(mesh.shape["model"])
    return 1


def _resolve(logical, mesh: Mesh, mode: str = "2d"):
    if logical == "fsdp":
        ax = fsdp_axes(mesh)
        return ax if len(ax) > 1 else ax[0]
    if logical == "tensor":
        # "1d" mode (§Perf hillclimb): no tensor parallelism — replicate on
        # the model axis, eliminating per-layer activation all-reduces.
        # The paper's self-sufficiency argument applied to the arch layer.
        return None if mode == "1d" else "model"
    return None


def spec_for_param(path_names: Sequence[str], shape: Tuple[int, ...],
                   mesh: Mesh, mode: str = "2d") -> P:
    """Sharding spec for one parameter leaf."""
    name = path_names[-1]
    in_moe = any(n in ("moe",) for n in path_names)
    rule = None
    if in_moe and name in _EXPERT_RULES and len(shape) >= 3:
        rule = _EXPERT_RULES[name]
    elif name in _SHARDED_TABLE_RULES and len(shape) == 3:
        rule = _SHARDED_TABLE_RULES[name]
    elif name in _RULES:
        rule = _RULES[name]
    if rule is None or len(shape) < len(rule):
        return P()
    lead = len(shape) - len(rule)
    spec = [None] * lead
    for dim, logical in zip(shape[lead:], rule):
        resolved = _resolve(logical, mesh, mode)
        if resolved is not None and dim % _axis_size(mesh, logical) == 0:
            spec.append(resolved)
        else:
            spec.append(None)
    return P(*spec)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
        else:
            names.append(str(p))
    return tuple(names)


def param_shardings(params: PyTree, mesh: Mesh,
                    mode: str = "2d") -> PyTree:
    """NamedSharding tree mirroring ``params`` (works on ShapeDtypeStructs).

    mode="2d": fsdp × tensor (baseline); mode="1d": fsdp only (no tensor
    parallelism — §Perf)."""
    def one(path, leaf):
        spec = spec_for_param(_path_names(path), np.shape(leaf), mesh, mode)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(opt_state, param_sh: PyTree, mesh: Mesh):
    """Adam moments follow their parameters; step scalar replicated."""
    rep = NamedSharding(mesh, P())
    mu = param_sh if opt_state.mu is not None else None
    nu = param_sh if opt_state.nu is not None else None
    return type(opt_state)(step=rep, mu=mu, nu=nu)


# ---------------------------------------------------------------------- #
# Batch / cache shardings
# ---------------------------------------------------------------------- #
def spec_for_batch_leaf(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Token-style inputs: leading batch dim over the data(+pod) axes."""
    dp = fsdp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    if len(shape) >= 1 and shape[0] % dp_size == 0:
        lead = dp if len(dp) > 1 else dp[0]
        return P(lead, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(batch: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, spec_for_batch_leaf(np.shape(x), mesh)),
        batch)


def spec_for_cache_leaf(path_names: Sequence[str], shape: Tuple[int, ...],
                        mesh: Mesh) -> P:
    """Decode caches: batch over data(+pod); the long sequence dim over
    ``model`` when divisible (KV-head counts here are mostly < 16, so
    sequence sharding is the general-purpose choice — DESIGN.md §5)."""
    dp = fsdp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    tensor = int(mesh.shape["model"])
    name = path_names[-1]
    spec = [None] * len(shape)
    # find the batch dim: first dim whose index matches B conventions:
    # attn k/v: (L, B, S, H, hd) or (B, S, H, hd); states: (L, B, ...)
    nd = len(shape)
    b_idx = nd - 4 if name in ("k", "v") else (1 if nd >= 3 else 0)
    if name in ("c_kv", "k_rope"):
        b_idx = nd - 3
    if 0 <= b_idx < nd and shape[b_idx] % dp_size == 0 and shape[b_idx] > 1:
        spec[b_idx] = dp if len(dp) > 1 else dp[0]
    # sequence dim (right after batch for k/v and c_kv/k_rope)
    if name in ("k", "v", "c_kv", "k_rope"):
        s_idx = b_idx + 1
        if shape[s_idx] % tensor == 0:
            spec[s_idx] = "model"
    elif name in ("wkv",):
        # (L, B, H, hd, hd): shard heads over model when divisible
        if shape[-3] % tensor == 0:
            spec[-3] = "model"
    elif name in ("h", "conv", "x_prev", "cmix_x_prev", "encoder_out"):
        if shape[-1] % tensor == 0:
            spec[-1] = "model"
    return P(*spec)


def cache_shardings(cache: PyTree, mesh: Mesh) -> PyTree:
    def one(path, leaf):
        spec = spec_for_cache_leaf(_path_names(path), np.shape(leaf), mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, cache)


# ---------------------------------------------------------------------- #
# KGE parameter specs for the shard_map train step
# ---------------------------------------------------------------------- #
def kge_param_specs(params: PyTree, mesh: Mesh) -> PyTree:
    """PartitionSpec tree for the KGE ``shard_map`` step: a sharded-layout
    entity table ``(S, rows, d)`` splits its shard dim over ``model`` (one
    row block per model-axis device — requires ``S == mesh.shape['model']``);
    every other leaf is replicated (relation tables are gathered densely in
    compute, so they stay replicated here even though ``_RULES`` records a
    row-wise storage rule for them).

    Batch-side plans need no specs here: the ``(P, S, V_b)`` gather plans
    (deduped or not) ride ``BatchShardings.plan`` through the transfer and
    the step's leading-axis batch spec, and the ``(P, V_b)`` dedup inverse
    rides the plain batch placement."""
    model = int(mesh.shape.get("model", 1))

    def one(path, leaf):
        names, shape = _path_names(path), np.shape(leaf)
        if names[-1] == "entity_embedding" and len(shape) == 3:
            if shape[0] != model:
                raise ValueError(
                    f"entity table has {shape[0]} shards but the model "
                    f"axis has {model} devices")
            return P("model", None, None)
        if (names[-1] in ("codes", "scales") and len(names) >= 2
                and names[-2] == "entity_embedding"):
            # quantized table (serving/export form): int8 codes
            # (S, rows, d) and fp32 scales (S, rows) both split the shard
            # dim over the model axis, like the fp32 stack they encode
            if shape[0] != model:
                raise ValueError(
                    f"quantized entity table has {shape[0]} shards but "
                    f"the model axis has {model} devices")
            return P("model", *([None] * (len(shape) - 1)))
        return P()
    return jax.tree_util.tree_map_with_path(one, params)


def tree_named_shardings(spec_tree: PyTree, mesh: Mesh) -> PyTree:
    """PartitionSpec tree → ``NamedSharding`` tree for ``jax.device_put``.

    Places a params (or optimizer-state) pytree on the mesh BEFORE the
    first spmd step, so the row-sharded entity table and its moments start
    — and stay — distributed instead of being resharded out of a
    replicated copy on the first dispatch.  A single ``PartitionSpec``
    (e.g. the ``P()`` every-leaf default) broadcasts over the whole tree;
    ``None`` subtrees (absent SGD moments) pass through untouched.
    """
    def one(spec):
        return NamedSharding(mesh, spec)
    if isinstance(spec_tree, P):
        return one(spec_tree)
    return jax.tree_util.tree_map(
        one, spec_tree, is_leaf=lambda x: isinstance(x, P))
