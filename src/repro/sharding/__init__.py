"""Sharding rules: 2-D (fsdp × tensor) parameter layout, batch/cache specs."""
from repro.sharding.rules import (
    param_shardings, opt_state_shardings, batch_shardings, cache_shardings,
    spec_for_param, spec_for_batch_leaf, spec_for_cache_leaf, fsdp_axes,
)
__all__ = [n for n in dir() if not n.startswith("_")]
