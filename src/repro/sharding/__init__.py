"""Sharding rules: 2-D (fsdp × tensor) parameter layout, batch/cache specs,
and the model-axis row-sharded embedding table (``repro.sharding.embedding``)."""
from repro.sharding.embedding import (
    PLAN_BATCH_KEYS, ShardedGatherPlan, ShardedTableLayout,
    convert_table_layout,
    plan_local_gather, plan_local_gather_block, plan_local_gather_device,
    shard_bias_blocks, shard_table, shard_table_block, sharded_gather,
    unshard_table,
)
from repro.sharding.rules import (
    param_shardings, opt_state_shardings, batch_shardings, cache_shardings,
    kge_param_specs, spec_for_param, spec_for_batch_leaf, spec_for_cache_leaf,
    fsdp_axes, tree_named_shardings,
)
__all__ = [n for n in dir() if not n.startswith("_")]
