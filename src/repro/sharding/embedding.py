"""Model-axis-sharded embedding tables (ROADMAP: sharded entity table).

The paper's self-sufficient partitions eliminate cross-partition *activation*
traffic, but the entity embedding table itself was still replicated on every
device — the memory wall that caps entity count per device (the scaling axis
DGL-KE attacks with partitioned embedding storage).  This module shards the
table row-wise over the ``model`` mesh axis and keeps the math bitwise
identical to the replicated gather:

* ``ShardedTableLayout`` — the layout contract: ``num_rows`` logical rows
  split into ``num_shards`` contiguous row blocks of ``rows_per_shard``
  (= ceil(num_rows / num_shards)); the table is zero-padded to
  ``padded_rows`` and stored as ``(num_shards, rows_per_shard, d)``.
* ``shard_table`` / ``unshard_table`` — dense ``(V, d)`` ⇄ sharded
  ``(S, rows, d)`` conversion (checkpoint interop uses the same functions).
* ``plan_local_gather`` (host numpy) / ``plan_local_gather_device`` (jnp) —
  turn global gather ids into per-shard LOCAL ids + ownership masks.  The
  host version is what the input pipeline precomputes per batch (a
  ``ShardedGatherPlan``, double-buffered with the rest of the prefetch
  path); the device version is the in-jit fallback for paths that build
  their gather ids on device (full-graph training, evaluation).  Both use
  the same integer arithmetic, so their outputs are identical.
* ``plan_unique_gather`` / ``ShardedGatherPlan.for_stacked(dedup=True)`` —
  host-side plan dedup: KGE minibatches repeat hot entities heavily, so the
  collator gathers each unique id once, exchanges only the deduped rows,
  and the device expands with a cheap ``take`` (the ``inverse`` map) after
  the exchange.  Unique lists are padded to a bucket multiple with a
  sentinel id that no shard owns (→ exact zero rows), keeping shapes
  static for jit.
* ``sharded_gather`` — shard-local gather + exchange.  Exactly one shard
  owns every row, so each output element is one real value plus zeros —
  bitwise equal to the dense ``table[ids]`` gather under EVERY exchange
  layout (and the transpose scatter-adds the same cotangents per row, so
  gradients match bitwise too; ``tests/test_sharded_embedding.py`` enforces
  this with ``==`` gates).  In the single-device simulation
  (``axis_name=None``) the default is the fused flat-index gather
  (``repro.kernels.ops.fused_sharded_gather``; ``exchange="masked_sum"``
  keeps the original take → mask → sum chain).  Under ``shard_map`` the
  default is ``psum_scatter`` (reduce only owned rows, then re-gather);
  ``"psum"`` is the original dense replicated AllReduce and ``"alltoall"``
  routes each shard's owned chunk point-to-point.  See ``docs/sharding.md``
  for when to use which.
* ``QuantizedTableLayout`` / ``quantize_rows`` / ``dequantize_rows`` —
  row-wise symmetric int8 storage (``table_dtype="int8"``): int8 codes in
  ``[-127, 127]`` plus one fp32 scale per row.  Scales are snapped to the
  smallest POWER OF TWO ``>= amax / 127`` (clamped to the fp32 subnormal
  floor ``2^-149``; exactly ``0.0`` for all-zero rows), which makes both
  directions exact fp32 arithmetic: ``codes = rint(x / scale)`` divides by
  a power of two and ``dequant = codes * scale`` multiplies by one, so
  quantize ∘ dequantize is bitwise idempotent, the elementwise error obeys
  ``|x - codes·scale| <= scale / 2``, and dequantization commutes bitwise
  with the gather/exchange (``docs/sharding.md`` § Quantized tables).  The
  training path keeps the fp32 master table as the parameter and routes
  through a straight-through fused-dequant gather
  (``repro.kernels.ops.quantized_sharded_gather``) whose backward is the
  IDENTICAL scatter-add the fp32 gather uses, so optimizer and gradients
  are untouched; eval/serving store only codes+scales and dequantize one
  ``(rows, d)`` block at a time in-program.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardedTableLayout:
    """Row-block layout of one embedding table over the ``model`` axis."""

    num_rows: int     # logical rows (e.g. num_entities)
    num_shards: int   # model-axis size the table is split over

    def __post_init__(self):
        if self.num_rows < 1 or self.num_shards < 1:
            raise ValueError(
                f"invalid layout: {self.num_rows} rows / "
                f"{self.num_shards} shards")

    @property
    def rows_per_shard(self) -> int:
        return -(-self.num_rows // self.num_shards)   # ceil division

    @property
    def padded_rows(self) -> int:
        return self.num_shards * self.rows_per_shard

    def bytes_per_shard(self, dim: int, itemsize: int = 4) -> int:
        """Per-device table footprint — the quantity sharding shrinks."""
        return self.rows_per_shard * dim * itemsize

    def shard_row_span(self, shard: int) -> Tuple[int, int]:
        """Global row range ``[lo, hi)`` of the REAL rows shard ``shard``
        stores — ``hi - lo < rows_per_shard`` on ragged tail shards, whose
        remaining local rows are layout padding (zero rows holding no
        entity; scoring paths mask them with ``-inf``)."""
        lo = shard * self.rows_per_shard
        return lo, max(lo, min(self.num_rows, lo + self.rows_per_shard))


@dataclasses.dataclass(frozen=True)
class QuantizedTableLayout(ShardedTableLayout):
    """Row-block layout for the int8-quantized entity table.

    Same row-block geometry as :class:`ShardedTableLayout` (gather plans,
    ``shard_row_span`` and layout conversions interchange 1:1), but the
    per-device footprint counts the ``(rows, d)`` int8 codes plus the
    ``(rows,)`` fp32 scale sidecar instead of ``(rows, d)`` fp32 — the
    ``(d + 4) / (4 d)`` compression that multiplies the 1/S sharding win
    (e.g. 0.266x at d=64)."""

    def bytes_per_shard(self, dim: int, itemsize: int = 1) -> int:
        """int8 codes (``itemsize=1``) + one fp32 scale per row."""
        return self.rows_per_shard * (dim * itemsize + 4)


# ---------------------------------------------------------------------- #
# Row-wise symmetric int8 quantization (power-of-two scales)
# ---------------------------------------------------------------------- #
TABLE_DTYPES = ("fp32", "int8")
INT8_QMAX = 127          # symmetric code range [-127, 127]
_MIN_SCALE_EXP = -149    # exponent of the smallest positive fp32


def _bitcast_i32(x, xp):
    if xp is np:
        return np.ascontiguousarray(x).view(np.int32)
    import jax
    return jax.lax.bitcast_convert_type(x, xp.int32)


def _bitcast_f32(bits, xp):
    if xp is np:
        return np.ascontiguousarray(bits.astype(np.int32)).view(np.float32)
    import jax
    return jax.lax.bitcast_convert_type(bits.astype(xp.int32), xp.float32)


def _pow2_f32(e, xp):
    """``2.0^e`` for integer ``e`` in the NORMAL range ``[-126, 127]``,
    built from the raw bit pattern — exact under numpy and XLA
    (``jnp.ldexp`` flushes subnormal results and XLA's CPU backend
    flushes subnormal *operands*, so no float arithmetic touches
    anything subnormal here)."""
    return _bitcast_f32((e + 127) << 23, xp)


def _pow2_scales(amax, xp):
    """Per element: the smallest power of two ``>= amax / 127``, clamped
    to ``[2^-149, 2^127]`` (exactly ``0.0`` where ``amax == 0``), plus
    its integer exponent.

    XLA's CPU backend flushes subnormal float operands to zero (numpy
    does not), so a subnormal ``amax`` is rebuilt as a NORMAL float from
    its integer mantissa (an exact int→float conversion of
    ``amax · 2^149`` — for ``amax >= 0`` the raw bit pattern IS the
    scaled magnitude) before any float op sees it.  With
    ``amax = m · 2^e`` (``m in [0.5, 1)``), ``127 · 2^(e-7) >= amax``
    iff ``m <= 127/128`` — so the exponent is ``e - 7`` or ``e - 6`` and
    the scale is built from its raw fp32 bit pattern."""
    amax = amax.astype(xp.float32)
    bits_in = _bitcast_i32(amax, xp)
    is_sub = bits_in < (1 << 23)       # biased exponent 0: subnormal or 0
    a_eff = xp.where(is_sub, bits_in.astype(xp.float32), amax)
    m, e = xp.frexp(a_eff)
    e = (e - xp.where(is_sub, 149, 0)).astype(xp.int32)
    e = xp.where(m > xp.float32(127.0 / 128.0), e - 6, e - 7)
    e = xp.clip(e, _MIN_SCALE_EXP, 127).astype(xp.int32)
    bits = xp.where(
        e >= -126,
        (xp.clip(e, -126, 127) + 127) << 23,          # normal 2^e
        xp.int32(1) << xp.clip(e + 149, 0, 22))       # subnormal 2^e
    scale = _bitcast_f32(bits, xp)
    # positivity via the integer bits — XLA CPU flushes subnormal float
    # COMPARE operands too (subnormal > 0 is False under jit)
    return xp.where(bits_in > 0, scale, xp.float32(0.0)), e


def quantize_rows(table):
    """Row-wise symmetric int8 quantization: ``(..., rows, d)`` fp32 →
    ``(codes (..., rows, d) int8, scales (..., rows) f32)``.

    Works on numpy or jax arrays (bitwise-identical results — the host
    pipeline and the in-jit training path must agree).  Per row,
    ``scale`` is the smallest power of two ``>= amax / 127``
    (:func:`_pow2_scales`), so ``codes = rint(x / scale)`` is an EXACT
    division landing in ``[-127, 127]`` and dequantization is an exact
    multiply; the round-trip error is ``<= scale / 2`` per element and
    ``quantize(dequantize(codes, scales))`` returns the same
    ``(codes, scales)`` bitwise.  All-zero rows get ``scale == 0`` and
    all-zero codes."""
    import jax.numpy as jnp
    xp = np if isinstance(table, np.ndarray) else jnp
    table = table.astype(xp.float32)
    bits = _bitcast_i32(table, xp)
    mag = bits & 0x7FFFFFFF
    # amax from the integer magnitudes: for non-negative fp32 the bit
    # pattern is monotone in the value, and integer max never flushes
    # subnormals the way XLA CPU float arithmetic does
    amax = _bitcast_f32(xp.max(mag, axis=-1), xp)
    scales, e = _pow2_scales(amax, xp)
    # codes = rint(x / 2^e) computed flush-proof: subnormal elements are
    # rebuilt as normal floats from their integer mantissa (exactly
    # x · 2^149), and the pow2 division becomes two exact multiplies by
    # NORMAL powers of two (the exponent split keeps every intermediate
    # that could still round to a nonzero code in the normal range, so
    # numpy and XLA agree bitwise; intermediates that underflow only
    # occur when the true quotient rounds to 0 on both)
    is_sub = mag < (1 << 23)
    sign = xp.where(bits < 0, xp.float32(-1.0), xp.float32(1.0))
    x_eff = xp.where(is_sub, sign * mag.astype(xp.float32), table)
    b_total = (-e)[..., None] - xp.where(is_sub, 149, 0)
    b1 = xp.clip(b_total, -126, 126)
    b2 = xp.clip(b_total - b1, -126, 126)
    q = (x_eff * _pow2_f32(b1, xp)) * _pow2_f32(b2, xp)
    codes = xp.clip(xp.rint(q), -INT8_QMAX, INT8_QMAX).astype(xp.int8)
    return codes, scales


def dequantize_rows(codes, scales):
    """``codes (..., rows, d) int8 × scales (..., rows) f32 → fp32`` — one
    exact power-of-two multiply per element (see :func:`quantize_rows`)."""
    import jax.numpy as jnp
    xp = (np if isinstance(codes, np.ndarray)
          and isinstance(scales, np.ndarray) else jnp)
    return codes.astype(xp.float32) * scales[..., None]


def quantize_table(table):
    """Stacked ``(S, rows, d)`` (or dense ``(V, d)``) fp32 table → the
    ``{"codes", "scales"}`` dict checkpoint/serving representation."""
    codes, scales = quantize_rows(table)
    return {"codes": codes, "scales": scales}


def dequantize_table(quantized):
    """Inverse of :func:`quantize_table` (same stacked/dense shape)."""
    return dequantize_rows(quantized["codes"], quantized["scales"])


def shard_table(table, layout: ShardedTableLayout):
    """Dense ``(num_rows, d)`` → sharded ``(num_shards, rows_per_shard, d)``
    (zero-padded tail; works on numpy or jax arrays)."""
    import jax.numpy as jnp
    xp = jnp if not isinstance(table, np.ndarray) else np
    v, d = table.shape
    if v != layout.num_rows:
        raise ValueError(f"table has {v} rows, layout expects "
                         f"{layout.num_rows}")
    pad = layout.padded_rows - v
    if pad:
        table = xp.concatenate(
            [table, xp.zeros((pad, d), table.dtype)], axis=0)
    return table.reshape(layout.num_shards, layout.rows_per_shard, d)


def shard_table_block(table, layout: ShardedTableLayout, shard: int):
    """One shard's ``(rows_per_shard, d)`` row block of the dense
    ``(num_rows, d)`` table — the per-shard twin of ``shard_table``
    (zero-padded on the ragged last shard; same numpy-or-jax dispatch), so
    a multi-host loader can realize ONLY its own devices' blocks instead
    of the full stack.
    ``shard_table(t, layout)[s] == shard_table_block(t, layout, s)``."""
    import jax.numpy as jnp
    xp = jnp if not isinstance(table, np.ndarray) else np
    v, d = table.shape
    if v != layout.num_rows:
        raise ValueError(f"table has {v} rows, layout expects "
                         f"{layout.num_rows}")
    rows = layout.rows_per_shard
    block = table[shard * rows: (shard + 1) * rows]
    if block.shape[0] < rows:
        block = xp.concatenate(
            [block, xp.zeros((rows - block.shape[0], d), table.dtype)])
    return block


def unshard_table(shards, num_rows: int):
    """Sharded ``(S, rows, d)`` → dense ``(num_rows, d)`` (padding rows are
    at the flattened tail, by construction of ``shard_table``)."""
    s, rows, d = shards.shape
    if num_rows > s * rows:
        raise ValueError(f"layout holds {s * rows} rows, need {num_rows}")
    return shards.reshape(s * rows, d)[:num_rows]


# ---------------------------------------------------------------------- #
# Gather planning: global ids -> (per-shard local ids, ownership masks)
# ---------------------------------------------------------------------- #
def plan_local_gather(layout: ShardedTableLayout,
                      global_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Host (numpy) gather plan for ids of shape ``(...,)``.

    Returns ``(local_ids, owned)`` with shard axis LEADING:
    ``local_ids[s] = clip(global_ids - s * rows, 0, rows - 1)`` (int32) and
    ``owned[s]`` marking the ids shard ``s`` actually stores.  Every valid
    global id is owned by exactly one shard.
    """
    rows = layout.rows_per_shard
    g = np.asarray(global_ids, dtype=np.int64)
    offsets = (np.arange(layout.num_shards, dtype=np.int64) * rows
               ).reshape((layout.num_shards,) + (1,) * g.ndim)
    local = g[None, ...] - offsets
    owned = (local >= 0) & (local < rows)
    return np.clip(local, 0, rows - 1).astype(np.int32), owned


def plan_local_gather_block(layout: ShardedTableLayout,
                            global_ids: np.ndarray,
                            shard: int) -> Tuple[np.ndarray, np.ndarray]:
    """One shard's ``(local_ids, owned)`` slice of :func:`plan_local_gather`
    — the same integer arithmetic, so stacking the blocks over shards
    reproduces the full plan bit-for-bit.  A multi-host mesh builds only
    its own shards' plan blocks with this."""
    rows = layout.rows_per_shard
    local = np.asarray(global_ids, dtype=np.int64) - shard * rows
    owned = (local >= 0) & (local < rows)
    return np.clip(local, 0, rows - 1).astype(np.int32), owned


def plan_local_gather_device(num_shards: int, rows_per_shard: int,
                             global_ids):
    """In-jit (jnp) twin of ``plan_local_gather`` for ``(V,)`` ids — same
    integer arithmetic, so host and device plans are identical."""
    import jax.numpy as jnp
    g = global_ids.astype(jnp.int32)
    offsets = (jnp.arange(num_shards, dtype=jnp.int32)
               * rows_per_shard)[:, None]
    local = g[None, :] - offsets
    owned = (local >= 0) & (local < rows_per_shard)
    return jnp.clip(local, 0, rows_per_shard - 1), owned


def plan_unique_gather(
        layout: ShardedTableLayout, global_ids: np.ndarray,
        pad_multiple: int = 64,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deduplicated host gather plan for ``(V,)`` ids.

    Returns ``(local_ids (S, U), owned (S, U), inverse (V,))`` where ``U``
    is the unique-id count rounded up to ``pad_multiple`` (static bucket
    shapes bound jit recompilation across batches).  Padding slots carry a
    sentinel id ``-1`` that no shard owns, so they gather exact zeros; the
    exchange moves ``U ≤ V`` rows and ``out[inverse]`` restores the
    original (duplicated) slot order on device AFTER the exchange —
    bitwise the same rows, gathered once each.
    """
    g = np.asarray(global_ids, dtype=np.int64)
    if g.ndim != 1:
        raise ValueError(f"plan_unique_gather expects (V,) ids, "
                         f"got {g.shape}")
    uniq, inverse = np.unique(g, return_inverse=True)
    bucket = max(pad_multiple,
                 -(-len(uniq) // pad_multiple) * pad_multiple)
    padded = np.full(bucket, -1, np.int64)
    padded[:len(uniq)] = uniq
    local, owned = plan_local_gather(layout, padded)
    return local, owned, inverse.astype(np.int32)


@dataclasses.dataclass
class ShardedGatherPlan:
    """Host-precomputed per-shard gather indices for one stacked batch.

    ``local_ids`` / ``owned`` are ``(P, S, V_b)`` — trainer axis leading
    (matching the stacked batch the SPMD step consumes), then the shard
    axis.  Emitted by the input-pipeline collator alongside each batch and
    double-buffered with it, so the device step never computes index
    arithmetic for the embedding exchange.

    With ``dedup=True`` the plan covers each trainer row's UNIQUE ids
    (bucket-padded with unowned sentinels to a common ``(P, S, U)``) and
    ``inverse`` is the ``(P, V_b)`` expansion map the device applies after
    the exchange; without dedup ``inverse`` is ``None``.
    """

    local_ids: np.ndarray   # (P, S, V_b) int32   (V_b = U when deduped)
    owned: np.ndarray       # (P, S, V_b) bool
    inverse: "np.ndarray | None" = None   # (P, V_b) int32 when deduped

    @classmethod
    def for_stacked(cls, layout: ShardedTableLayout,
                    gather_global: np.ndarray, *, dedup: bool = False,
                    pad_multiple: int = 64) -> "ShardedGatherPlan":
        """Plan for a trainer-stacked ``(P, V_b)`` global-id array."""
        if not dedup:
            local, owned = plan_local_gather(layout, gather_global)
            return cls(local_ids=np.moveaxis(local, 0, 1),
                       owned=np.moveaxis(owned, 0, 1))
        g = np.asarray(gather_global, dtype=np.int64)
        uniqs, inverses = zip(*(np.unique(row, return_inverse=True)
                                for row in g))
        # one bucket size across trainer rows — the stacked plan must be
        # rectangular, and a shared bucket keeps jit shapes batch-stable
        bucket = max(pad_multiple,
                     -(-max(len(u) for u in uniqs) // pad_multiple)
                     * pad_multiple)
        padded = np.full((g.shape[0], bucket), -1, np.int64)
        for p, u in enumerate(uniqs):
            padded[p, :len(u)] = u
        local, owned = plan_local_gather(layout, padded)  # (S, P, U)
        return cls(local_ids=np.moveaxis(local, 0, 1),
                   owned=np.moveaxis(owned, 0, 1),
                   inverse=np.stack(inverses).astype(np.int32))


# ---------------------------------------------------------------------- #
# Shard-local gather + exchange
# ---------------------------------------------------------------------- #
SIM_EXCHANGES = ("fused", "masked_sum")
SPMD_EXCHANGES = ("psum_scatter", "psum", "alltoall")

# batch keys carrying the stacked (P, S, V_b) sharded-gather plan: the
# transfer (``BatchShardings.plan``) and the spmd step's ``in_specs`` place
# them over BOTH the trainer (data) and shard (model) axes, so each device
# receives its own pre-sliced plan block
PLAN_BATCH_KEYS = ("shard_local_ids", "shard_owned")

# custom-VJP exchange closures, cached per (axis_name, exchange) so repeated
# traces reuse one function identity (stable jit cache keys)
_EXCHANGE_FNS: dict = {}


def _replicated_exchange(axis_name: str, exchange: str):
    """The named-axis exchange collective with a REPLICATED-LOSS backward.

    Forward: sum each device's masked owned-row block ``(V_pad, d)`` over
    ``axis_name`` into the replicated gather output (via ``psum``,
    ``psum_scatter`` + re-gather, or ``alltoall`` + local sum + re-gather —
    all bitwise equal: each element is one real value plus zeros).

    Backward: IDENTITY, not the collective transpose.  The SPMD training
    contract is that everything downstream of the exchange is replicated
    along ``axis_name`` (same batch slice, same replicated weights on every
    model-axis device), so each device's incoming cotangent already IS the
    full cotangent.  jax's default transpose of ``psum`` is ``psum`` —
    under ``shard_map(check_rep=False)`` (rep-tracking cannot be enabled
    for this body) that sums the S identical cotangent replicas and scales
    the entity-table gradient by S, which adam's scale-invariant first
    step masked historically.  Passing the cotangent through once is exact
    for any S; ``tests/test_sharded_embedding.py`` gates the whole step
    bitwise against the dense reference.
    """
    key = (axis_name, exchange)
    fn = _EXCHANGE_FNS.get(key)
    if fn is not None:
        return fn
    import jax
    import jax.numpy as jnp

    def collective(x):
        if exchange == "psum":
            return jax.lax.psum(x, axis_name)
        if exchange == "psum_scatter":
            y = jax.lax.psum_scatter(
                x, axis_name, scatter_dimension=0, tiled=True)
            return jax.lax.all_gather(y, axis_name, axis=0, tiled=True)
        s = jax.lax.psum(1, axis_name)            # static axis size
        pieces = jax.lax.all_to_all(
            x.reshape(s, x.shape[0] // s, x.shape[1]), axis_name,
            split_axis=0, concat_axis=0)          # (S, V_pad/S, d)
        return jax.lax.all_gather(
            jnp.sum(pieces, axis=0), axis_name, axis=0, tiled=True)

    @jax.custom_vjp
    def exchange_fn(x):
        return collective(x)

    exchange_fn.defvjp(lambda x: (collective(x), None),
                       lambda _res, ct: (ct,))
    _EXCHANGE_FNS[key] = exchange_fn
    return exchange_fn


def _quantized_exchange(axis_name: str, exchange: str):
    """The shard_map exchange for ``table_dtype="int8"``: int8 codes cross
    the wire, per-slot fp32 scales ride along as a sidecar.

    Forward: quantize this device's ``(1, rows, d)`` fp32 master block
    row-wise (in-jit, per step — the fp32 table is never stacked), gather
    the owned slots' int8 codes and fp32 scales locally, run the SAME
    collective layout as the fp32 exchange on both (exactly one device
    contributes a nonzero value per slot, so the int8 integer sum is
    exact), and dequantize AFTER the exchange — the same single
    power-of-two multiply a pre-exchange dequant would do, so the output
    is bitwise equal to the fp32 exchange over the dequantized master.

    Backward: straight-through — the identical masked scatter-add of the
    cotangent into the master block that the fp32 path composes (fused
    local gather backward ∘ identity exchange backward), so master-table
    gradients are bitwise equal to the fp32 path's on the same master.
    """
    key = (axis_name, exchange, "int8")
    fn = _EXCHANGE_FNS.get(key)
    if fn is not None:
        return fn
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    def collective(x):
        if exchange == "psum":
            return jax.lax.psum(x, axis_name)
        if exchange == "psum_scatter":
            y = jax.lax.psum_scatter(
                x, axis_name, scatter_dimension=0, tiled=True)
            return jax.lax.all_gather(y, axis_name, axis=0, tiled=True)
        s = jax.lax.psum(1, axis_name)            # static axis size
        pieces = jax.lax.all_to_all(
            x.reshape((s, x.shape[0] // s) + x.shape[1:]), axis_name,
            split_axis=0, concat_axis=0)
        return jax.lax.all_gather(
            jnp.sum(pieces, axis=0).astype(x.dtype), axis_name, axis=0,
            tiled=True)

    def gather_impl(table, local_ids, owned):
        codes, scales = quantize_rows(table)      # (1, rows, d) / (1, rows)
        rows = table.shape[1]
        flat, any_owned = ops.flat_gather_plan(local_ids, owned, rows)
        c = jnp.where(any_owned[:, None],
                      codes.reshape(rows, -1)[flat], jnp.int8(0))
        sc = jnp.where(any_owned, scales.reshape(rows)[flat],
                       jnp.float32(0.0))
        v = c.shape[0]
        if exchange == "psum":
            v_pad = v
        else:
            s = jax.lax.psum(1, axis_name)
            v_pad = -(-v // s) * s
        if v_pad != v:
            c = jnp.pad(c, ((0, v_pad - v), (0, 0)))
            sc = jnp.pad(sc, ((0, v_pad - v),))
        c = collective(c)[:v]
        sc = collective(sc)[:v]
        return c.astype(jnp.float32) * sc[:, None]

    @jax.custom_vjp
    def qx_gather(table, local_ids, owned):
        return gather_impl(table, local_ids, owned)

    qx_gather.defvjp(
        lambda t, li, ow: (gather_impl(t, li, ow), (li, ow, t)),
        ops.fsg_bwd)
    _EXCHANGE_FNS[key] = qx_gather
    return qx_gather


def sharded_dequant_gather(codes, scales, local_ids, owned, *,
                           inverse=None, interpret=None, use_kernel=None):
    """Gather ``(V_b, d)`` fp32 rows straight from a quantized stacked
    table (``codes (S, rows, d)`` int8 + ``scales (S, rows)`` f32) with
    the dequant fused into the gather — the eval/serving path, where only
    codes+scales live on device and the fp32 table never materializes.

    Bitwise equal to dequantizing the whole stack and gathering densely
    (each output row is one exact power-of-two multiply either way;
    ``kernels/ref.py: dequant_gather_ref`` is the oracle).  No gradient —
    training goes through ``ops.quantized_sharded_gather``, which keeps
    the fp32 master as the differentiable input."""
    import jax.numpy as jnp

    from repro.kernels import ops

    out = ops.dequant_sharded_gather(codes, scales, local_ids, owned,
                                     interpret=interpret,
                                     use_kernel=use_kernel)
    return out if inverse is None else jnp.take(out, inverse, axis=0)


def sharded_gather(table, local_ids, owned, *, axis_name=None,
                   exchange=None, inverse=None, table_dtype="fp32"):
    """Gather ``(V_b, d)`` rows from a row-sharded table.

    * ``axis_name=None`` (single-device simulation): ``table`` is the full
      ``(S, rows, d)`` stack.  ``exchange="fused"`` (default) collapses the
      plan into flat row indices and runs ONE masked gather with a fused
      scatter-add backward (``repro.kernels.ops.fused_sharded_gather``);
      ``"masked_sum"`` keeps the original per-shard take → mask → sum
      chain.  Both are bitwise equal to the dense ``table[ids]`` gather.
    * ``axis_name="model"`` (inside ``shard_map``): ``table`` is this
      device's ``(1, rows, d)`` block; each device gathers+masks its owned
      rows locally (fused) and the shards exchange:

      - ``"psum_scatter"`` (default): reduce-scatter the masked rows so
        each device sums only its ``V/S`` output chunk, then re-gather —
        same total payload as an AllReduce's reduce phase but no
        replicated broadcast-side accumulate work per device.
      - ``"psum"``: the original dense replicated AllReduce.
      - ``"alltoall"``: route each shard's owned chunk point-to-point,
        sum the S received chunks locally, re-gather.  Lowest exchange
        volume when ownership is chunk-aligned; see ``docs/sharding.md``.

      ``V_b`` is padded to a multiple of S around the collective (padding
      rows are unowned → exact zeros) and sliced back after, so every
      layout is bitwise equal to ``"psum"`` — each element is one real
      value plus zeros regardless of where the zeros are summed.

      The plan may be the replicated ``(S, V_b)`` stack (each device picks
      its own row) or this device's pre-sliced ``(1, V_b)`` block (the
      sharded-transfer placement).  The exchange's backward passes each
      device's cotangent through ONCE (see ``_replicated_exchange``): the
      loss downstream must be replicated along ``axis_name`` — the SPMD
      training contract — otherwise the default collective transpose
      would scale the table gradient by S.

    ``inverse`` (from a deduped plan) expands the exchanged unique rows
    back to batch slots with ``out[inverse]`` AFTER the exchange, so the
    exchange payload scales with unique ids, not batch slots.

    ``table_dtype="int8"`` routes through the straight-through quantized
    paths while keeping ``table`` the fp32 MASTER (the differentiable
    parameter): the forward quantizes row-wise in-jit and gathers with the
    fused-dequant kernel (``ops.quantized_sharded_gather`` on the sim
    path; ``_quantized_exchange`` — int8 codes + fp32 scale sidecar over
    the collective — under ``shard_map``), and the backward is the
    IDENTICAL scatter-add the fp32 path uses, so master gradients match
    the fp32 path bitwise on the same master.  Both sim exchange layouts
    coincide for int8 (a ``masked_sum`` chain through the quantizer would
    have zero gradient through ``rint``; the straight-through op is the
    one correct estimator).
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    if table_dtype not in TABLE_DTYPES:
        raise ValueError(
            f"unknown table_dtype {table_dtype!r}: one of {TABLE_DTYPES}")

    if axis_name is None:
        exchange = exchange or "fused"
        if exchange not in SIM_EXCHANGES:
            raise ValueError(
                f"unknown sim exchange {exchange!r}: one of {SIM_EXCHANGES}")
        if table_dtype == "int8":
            out = ops.quantized_sharded_gather(table, local_ids, owned)
        elif exchange == "fused":
            out = ops.fused_sharded_gather(table, local_ids, owned)
        else:
            g = jax.vmap(lambda t, i: t[i])(table, local_ids)  # (S, V, d)
            out = jnp.sum(jnp.where(owned[:, :, None], g, 0.0), axis=0)
        return out if inverse is None else jnp.take(out, inverse, axis=0)

    if table.shape[0] != 1:
        # a replicated (S, rows, d) table inside shard_map would gather
        # shard 0's rows against every shard's local ids and psum S wrong
        # answers with consistent shapes — fail at trace time instead
        raise ValueError(
            f"sharded_gather under shard_map expects this device's "
            f"(1, rows, d) row block, got {table.shape} — shard the table "
            f"over {axis_name!r} (see kge_param_specs)")
    exchange = exchange or "psum_scatter"
    if exchange not in SPMD_EXCHANGES:
        raise ValueError(
            f"unknown shard_map exchange {exchange!r}: "
            f"one of {SPMD_EXCHANGES}")
    if local_ids.shape[0] == 1:
        # pre-sliced per-device plan block: the sharded transfer
        # (BatchShardings) places each shard's (1, V_b) plan block on its
        # own model-axis device and the spmd step's in_specs keep it there,
        # so the plan is never replicated over the model axis
        li, ow = local_ids, owned
        s = jax.lax.psum(1, axis_name)            # static axis size
    else:
        # replicated (S, V_b) plan: pick this device's row
        i = jax.lax.axis_index(axis_name)
        li = jax.lax.dynamic_index_in_dim(local_ids, i, keepdims=True)
        ow = jax.lax.dynamic_index_in_dim(owned, i, keepdims=True)
        s = local_ids.shape[0]
    if table_dtype == "int8":
        out = _quantized_exchange(axis_name, exchange)(table, li, ow)
        return out if inverse is None else jnp.take(out, inverse, axis=0)
    # this device's masked local gather, via the fused S=1 flat-plan path
    x = ops.fused_sharded_gather(table, li, ow)                  # (V, d)
    if exchange == "psum":
        out = _replicated_exchange(axis_name, exchange)(x)
    else:
        v = x.shape[0]
        v_pad = -(-v // s) * s
        if v_pad != v:
            x = jnp.pad(x, ((0, v_pad - v), (0, 0)))
        out = _replicated_exchange(axis_name, exchange)(x)[:v]
    return out if inverse is None else jnp.take(out, inverse, axis=0)


def shard_bias_blocks(bias: np.ndarray,
                      layout: ShardedTableLayout) -> np.ndarray:
    """Split a per-batch ``(B, num_rows)`` candidate bias into per-shard
    column blocks ``(S, B, rows_per_shard)`` following the row-block layout.

    Columns beyond ``num_rows`` (the layout's zero-padded tail rows, which
    hold no real entity) get ``-inf``: a padded row's score is then ``-inf``
    and can neither outrank nor tie any real candidate, so rank counts over
    the padded blocks equal counts over the dense ``(B, num_rows)`` matrix.
    Shard ``s``'s block covers global rows ``[s * rows, (s+1) * rows)``.

    This is the DENSE-INPUT reference: the sharded ranking path
    (``repro.eval.sharded.shard_filter_bias_block``) builds each block
    straight from the CSR filter index's column-range form instead, so the
    ``(B, num_rows)`` input never has to exist; the two are tested
    bit-equal (``tests/test_eval_ranking.py``).
    """
    b, n = bias.shape
    if n != layout.num_rows:
        raise ValueError(f"bias has {n} columns, layout expects "
                         f"{layout.num_rows}")
    padded = np.full((b, layout.padded_rows), -np.inf, np.float32)
    padded[:, :n] = bias
    return np.ascontiguousarray(
        padded.reshape(b, layout.num_shards, layout.rows_per_shard)
        .transpose(1, 0, 2))


def _layout_row_range(shape) -> Tuple[int, int]:
    """Logical row counts a table shape can represent: a dense ``(V, d)``
    is exactly ``V``; a sharded ``(S, rows, d)`` is any ``V`` with
    ``rows == ceil(V / S)`` (the tail padding is less than one shard)."""
    if len(shape) == 2:
        return shape[0], shape[0]
    s, rows = shape[0], shape[1]
    return s * (rows - 1) + 1, s * rows


def convert_table_layout(arr: np.ndarray, target_shape,
                         num_rows: Optional[int] = None) -> np.ndarray:
    """Convert an embedding table between layouts: dense ``(V, d)`` ⇄
    sharded ``(S, rows, d)`` (any shard count).  Row blocks are contiguous,
    so flattening a sharded table recovers global row order with the zero
    padding at the tail; restores pad/trim that tail as needed.  Used by
    ``repro.training.checkpoint`` so checkpoints round-trip across layouts.

    Only LAYOUT differences convert: the two shapes must be able to
    describe the same logical row count (a mismatched vocabulary — e.g. a
    checkpoint from a different dataset — raises rather than being silently
    truncated or zero-padded).  A sharded shape hides the exact count
    inside its tail padding (any ``V`` with ``ceil(V/S) == rows`` fits), so
    pass ``num_rows`` — the model's true entity count — when known to close
    that ambiguity window; without it, mismatches smaller than one shard's
    padding are undetectable from the shapes alone.
    """
    target_shape = tuple(target_shape)
    arr = np.asarray(arr)
    if arr.shape == target_shape:
        return arr
    if arr.ndim not in (2, 3) or len(target_shape) not in (2, 3) or \
            arr.shape[-1] != target_shape[-1]:
        raise ValueError(
            f"cannot convert table layout {arr.shape} -> {target_shape}")
    lo_a, hi_a = _layout_row_range(arr.shape)
    lo_b, hi_b = _layout_row_range(target_shape)
    lo, hi = max(lo_a, lo_b), min(hi_a, hi_b)
    if num_rows is not None and not (lo_a <= num_rows <= hi_a and
                                     lo_b <= num_rows <= hi_b):
        raise ValueError(
            f"table layouts {arr.shape} / {target_shape} cannot hold "
            f"exactly {num_rows} logical rows "
            f"({lo_a}-{hi_a} vs {lo_b}-{hi_b})"
            " — refusing to truncate or zero-pad real embedding rows")
    if lo > hi:
        raise ValueError(
            f"table layouts {arr.shape} and {target_shape} describe "
            f"disjoint logical row counts ({lo_a}-{hi_a} vs {lo_b}-{hi_b})"
            " — refusing to truncate or zero-pad real embedding rows")
    d = arr.shape[-1]
    dense = arr.reshape(-1, d)
    need = int(np.prod(target_shape[:-1]))
    if dense.shape[0] < need:
        dense = np.concatenate(
            [dense, np.zeros((need - dense.shape[0], d), dense.dtype)])
    return np.ascontiguousarray(dense[:need].reshape(target_shape))
