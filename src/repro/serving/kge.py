"""Sharded top-k link-prediction serving (ROADMAP: serve KGE traffic).

``KGEServer`` answers ``(head, relation, ?)`` queries by scoring the full
dense ``(B, N)`` candidate matrix on one device — exactly the memory wall
the PR 2–6 row-sharded entity table was built to remove, and the DGL-KE
service shape (partitioned embedding stores behind a batched front-end)
this module reproduces:

* ``ShardedKGEServer`` — candidate-axis-sharded scoring + per-shard top-k.
  The entity table is row-sharded once (``repro.sharding.embedding``); each
  shard's ``(B, rows/S)`` score block comes from the same ``shard_scores``
  helper the sharded evaluation uses (row-local candidate preparation,
  cached per shard at construction so requests only prepare their ``(B, d)``
  queries), is reduced to ``(B, k')`` IMMEDIATELY by the Pallas top-k
  kernel (``repro.kernels.topk``), and the ``S · k'`` per-shard winners are
  k-way merged with one more top-k over ``(B, S·k')`` — the dense ``(B, N)``
  score matrix never exists on any device.

* Exactness contract (the benchmark gate): merged indices are EXACTLY
  ``==`` dense ``jax.lax.top_k`` for every registered decoder at any shard
  count.  Three facts compose: (1) candidate preparation is row-local, so
  each shard's score block is bitwise the matching dense columns; (2) the
  top-k kernel's selection (max over active columns, LOWEST index wins
  ties, winner deactivated) is arithmetic-free and matches ``lax.top_k``'s
  documented order; (3) shard row blocks are contiguous ascending
  global-id ranges and per-shard lists are internally lowest-local-index
  ordered, so among equal merged values a lower concat position IS a lower
  global id.  Per-shard ``k' = min(k, rows/S)`` suffices: any global top-k
  element has fewer than ``k'`` same-shard predecessors.

* Filtered serving: per-shard bias blocks come straight from the
  column-range ``CSRFilterIndex`` form (``shard_filter_bias_block``) with
  sentinel true-tail ``t = -1`` so EVERY known tail of ``(h, r)`` filters —
  a serving query has no held-out true tail to un-filter, unlike
  evaluation.  Layout-padded tail rows are always masked ``-inf``.

* ``KGEServeEngine`` — the dynamic-batching front-end (the LM
  ``ServeEngine`` slot pattern, adapted): queued requests are admitted into
  a fixed ``slots``-wide batch (pad slots repeat a dummy query and are
  dropped on the way out), every step computes the engine-wide ``max_k``
  so jit sees ONE static shape, and each request is answered with its own
  leading ``k`` columns (a top-k prefix is the top-k).  Responses attach to
  the submitted ``KGEQuery`` objects, so integrity is by identity — not
  completion order, which the ``smallest-k-first`` admission policy
  deliberately decouples from submission order.

* Optional hot-entity cache: KGE request streams are heavily skewed toward
  hot entities, so ``cache_size > 0`` keeps an LRU of head-embedding rows
  on the host and gathers only the misses through the PR-2 sharded
  exchange (deduped + bucket-padded, ``plan_unique_gather``).  Cached rows
  are the exchange's own output, so the cache changes latency, never bits.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.eval.ranking import CSRFilterIndex
from repro.eval.sharded import shard_filter_bias_block, shard_scores
from repro.kernels.ops import merge_topk, topk_padded
from repro.models.decoders import Decoder, get_decoder
from repro.sharding.embedding import (
    TABLE_DTYPES, ShardedTableLayout, dequantize_rows, plan_local_gather,
    plan_unique_gather, quantize_rows, shard_table, sharded_dequant_gather,
    sharded_gather,
)


class ShardedKGEServer:
    """Top-k tails over the row-sharded entity table, for any registered
    decoder — peak per-device score memory is one ``(B, rows/S)`` block.

    ``decoder_params`` is the decoder's own parameter tree (the trained
    model's ``params["decoder"]``).  The candidate side of the query form
    is prepared once per shard at construction and cached; ``filter_index``
    (a ``CSRFilterIndex`` or the dict reference form) enables
    ``filtered=True`` queries; ``cache_size`` bounds the hot-entity
    head-embedding LRU (0 disables it).
    """

    def __init__(self, entity_emb: np.ndarray, decoder_params,
                 decoder: Union[str, Decoder] = "distmult", *,
                 num_shards: int = 1, filter_index=None,
                 cache_size: int = 0, interpret: Optional[bool] = None,
                 table_dtype: str = "fp32"):
        if table_dtype not in TABLE_DTYPES:
            raise ValueError(
                f"table_dtype={table_dtype!r} not in {TABLE_DTYPES}")
        self.decoder = get_decoder(decoder)
        self.table_dtype = table_dtype
        emb = np.ascontiguousarray(np.asarray(entity_emb, np.float32))
        self.num_entities, self.dim = emb.shape
        self.layout = ShardedTableLayout(self.num_entities, num_shards)
        if table_dtype == "int8":
            # only the int8 codes + fp32 per-row scales live on device;
            # shard blocks are dequantized transiently inside the top-k
            # program (the replication audit proves no fp32 full-table
            # buffer exists in the lowered HLO), so the candidate cache
            # is rebuilt in-program instead of precomputed
            codes, scales = quantize_rows(shard_table(emb, self.layout))
            self.table: object = (jnp.asarray(codes), jnp.asarray(scales))
            self._prepared = None
        else:
            self.table = jnp.asarray(shard_table(emb, self.layout))
        self.params = jax.tree_util.tree_map(jnp.asarray, decoder_params)
        self.filter_index = filter_index
        self.interpret = interpret
        if table_dtype == "fp32":
            self._prepared = [
                self.decoder.prepare_candidates(self.params, self.table[s])
                for s in range(self.layout.num_shards)]
        # per-shard base bias: -inf on layout-padded tail columns (zero
        # rows holding no entity), 0 on real rows — shared by every batch
        rows = self.layout.rows_per_shard
        pad = np.zeros((self.layout.num_shards, rows), np.float32)
        for s in range(self.layout.num_shards):
            lo, hi = self.layout.shard_row_span(s)
            pad[s, hi - lo:] = -np.inf
        self._pad_bias = pad
        self._cache_size = int(cache_size)
        self._cache: "collections.OrderedDict[int, np.ndarray]" = \
            collections.OrderedDict()
        self.cache_hits = 0
        self.cache_misses = 0
        self._topk_programs: dict = {}      # k -> jitted program

    # ------------------------------------------------------------------ #
    # head-embedding fetch (sharded exchange + optional LRU)
    # ------------------------------------------------------------------ #
    def _gather(self, li, ow, inverse=None) -> jax.Array:
        """Sharded-exchange row fetch for either storage dtype: fp32 runs
        the PR-6 fused gather, int8 the fused dequantizing gather — both
        bitwise the dense gather over the (dequantized) table."""
        if self.table_dtype == "int8":
            codes, scales = self.table
            return sharded_dequant_gather(
                codes, scales, jnp.asarray(li), jnp.asarray(ow),
                inverse=None if inverse is None else jnp.asarray(inverse))
        return sharded_gather(
            self.table, jnp.asarray(li), jnp.asarray(ow),
            inverse=None if inverse is None else jnp.asarray(inverse))

    def head_embeddings(self, heads: np.ndarray) -> jax.Array:
        """``(B, d)`` head rows via the sharded gather exchange — bitwise
        the dense ``emb[heads]`` rows.  With ``cache_size > 0`` only cache
        misses touch the exchange (deduped, bucket-padded so jit shapes
        stay stable across miss counts)."""
        heads = np.asarray(heads, np.int64)
        if self._cache_size <= 0:
            li, ow = plan_local_gather(self.layout, heads)
            return self._gather(li, ow)
        uniq = np.unique(heads)
        missing = np.array([e for e in uniq if int(e) not in self._cache],
                           np.int64)
        self.cache_hits += len(uniq) - len(missing)
        self.cache_misses += len(missing)
        if len(missing):
            li, ow, inv = plan_unique_gather(self.layout, missing)
            rows = np.asarray(self._gather(li, ow, inverse=inv))
            for e, row in zip(missing, rows):
                self._cache[int(e)] = row
        for e in uniq:                       # LRU touch, then evict
            self._cache.move_to_end(int(e))
        while len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
        # rows evicted by this very batch (uniq count > cache_size) are
        # re-fetched above next time; assemble from the pre-evict snapshot
        rows_by_id = {int(e): self._cache.get(int(e)) for e in uniq}
        if any(v is None for v in rows_by_id.values()):
            # batch larger than the cache: fall back to a direct gather
            li, ow = plan_local_gather(self.layout, heads)
            return self._gather(li, ow)
        return jnp.asarray(np.stack([rows_by_id[int(e)] for e in heads]))

    # ------------------------------------------------------------------ #
    # sharded top-k
    # ------------------------------------------------------------------ #
    def topk_program(self, k: int):
        """The jitted sharded top-k program for one static ``k``: ONE
        device program per request — score each shard's row block,
        reduce it to ``(B, k')`` immediately, merge — so the whole
        serve step is a single lowered module the SPMD contract auditor
        (``repro.analysis.programs``) can statically check: no
        collectives, and no buffer with a full-vocabulary dimension.

        Signature: ``program(table, prepared, params, q, q_bias, bias)
        -> (values (B, k), tails (B, k))`` with ``table`` the ``(S,
        rows, d)`` shard stack, ``prepared`` the per-shard candidate
        cache, and ``bias`` the ``(S, B, rows)`` per-shard bias stack
        (``-inf`` on layout padding).  Cached per ``k``.
        """
        k = min(int(k), self.num_entities)
        prog = self._topk_programs.get(k)
        if prog is not None:
            return prog
        rows = self.layout.rows_per_shard
        kp = min(k, rows)    # per-shard k': enough for any global winner
        num_shards = self.layout.num_shards
        decoder, interpret = self.decoder, self.interpret
        quantized = self.table_dtype == "int8"

        def program(table, prepared, params, q, q_bias, bias):
            vals_parts, ids_parts = [], []
            for s in range(num_shards):
                if quantized:
                    # dequantize ONE shard's (rows, d) block transiently
                    # and prepare its candidate form in-program; the fp32
                    # (S, rows, d) stack never exists
                    block = dequantize_rows(table[0][s], table[1][s])
                    prep = None
                else:
                    block, prep = table[s], prepared[s]
                scores = shard_scores(
                    decoder, params, block, q, q_bias, bias[s],
                    interpret, prepared=prep)
                v, i = topk_padded(scores, kp, interpret=interpret)
                vals_parts.append(v)
                ids_parts.append(i + s * rows)   # local → global id
            vals = jnp.concatenate(vals_parts, axis=1)    # (B, S·k')
            ids = jnp.concatenate(ids_parts, axis=1)
            return merge_topk(vals, ids, k, interpret=interpret)

        prog = jax.jit(program)
        self._topk_programs[k] = prog
        return prog

    def lower_topk(self, batch_size: int, k: int = 10):
        """``jax.stages.Lowered`` of :meth:`topk_program` for a
        ``batch_size``-row request batch — the serve-side hook the SPMD
        contract auditor lowers through.  Queries come from the
        decoder's own ``prepare_query`` so the traced shapes match every
        registered decoder."""
        b = int(batch_size)
        h = jnp.zeros((b, self.dim), jnp.float32)
        rel = jnp.zeros((b,), jnp.int32)
        q, q_bias = self.decoder.prepare_query(self.params, h, rel)
        bias = jnp.zeros(
            (self.layout.num_shards, b, self.layout.rows_per_shard),
            jnp.float32)
        return self.topk_program(k).lower(
            self.table, self._prepared, self.params, q, q_bias, bias)

    def topk_tails(self, heads: np.ndarray, rels: np.ndarray, k: int = 10,
                   *, filtered: bool = False
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """``(scores (B, k), tails (B, k))`` — ``k`` clamped to the
        vocabulary, values descending, ties broken toward the lowest
        entity id; indices EXACTLY equal the dense ``jax.lax.top_k`` over
        the decoder's full score matrix (which is never materialized).

        ``filtered=True`` masks every known tail of each row's
        ``(head, relation)`` pair with the serving sentinel ``t = -1``
        (no held-out true tail is un-filtered, unlike evaluation)."""
        if k < 1:
            raise ValueError(f"k={k} must be >= 1")
        k = min(int(k), self.num_entities)
        heads = np.asarray(heads)
        rels = np.asarray(rels)
        b = heads.shape[0]
        h = self.head_embeddings(heads)
        q, q_bias = self.decoder.prepare_query(
            self.params, h, jnp.asarray(rels.astype(np.int32)))

        batch = resolved = None
        if filtered:
            if self.filter_index is None:
                raise ValueError(
                    "filtered=True needs a filter_index at construction")
            batch = np.stack(
                [heads.astype(np.int64), rels.astype(np.int64),
                 np.full(b, -1, np.int64)], axis=1)
            resolved = (self.filter_index.resolve_queries(batch)
                        if isinstance(self.filter_index, CSRFilterIndex)
                        else None)

        rows = self.layout.rows_per_shard
        if filtered:
            # column-range CSR form; fills layout padding with -inf
            bias = np.stack([
                shard_filter_bias_block(
                    self.filter_index, batch, self.layout, s, resolved)
                for s in range(self.layout.num_shards)])
        else:
            bias = np.broadcast_to(self._pad_bias[:, None, :],
                                   (self.layout.num_shards, b, rows))
        mv, mi = self.topk_program(k)(
            self.table, self._prepared, self.params, q, q_bias,
            jnp.asarray(bias))
        return np.asarray(mv), np.asarray(mi)


# ---------------------------------------------------------------------- #
# Dynamic request batching
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class KGEQuery:
    """One ``(head, relation, ?)`` request; ``scores``/``tails`` attach to
    THIS object when its batch completes — response integrity is by
    identity, not completion order."""

    request_id: int
    head: int
    relation: int
    k: int = 10
    scores: Optional[np.ndarray] = None   # (k',) descending
    tails: Optional[np.ndarray] = None    # (k',) global entity ids
    done: bool = False


ADMISSION_POLICIES = ("fifo", "smallest-k-first")


class KGEServeEngine:
    """Dynamic batching front-end over a :class:`ShardedKGEServer`.

    The LM ``ServeEngine`` slot pattern, adapted: queued requests are
    admitted up to ``slots`` per step into one fixed-width batch (pad slots
    repeat a dummy query and are dropped on the way out), the step always
    computes ``max_k`` columns so jit sees a single static shape, and each
    request receives its own leading ``min(k, N)`` columns — exact, because
    a top-k prefix is the top-k.  ``policy="smallest-k-first"`` batches
    cheap requests ahead of the queue (completion order decouples from
    submission order; responses stay attached to their own request).
    """

    def __init__(self, server: ShardedKGEServer, *, slots: int = 8,
                 max_k: int = 10, filtered: bool = False,
                 policy: str = "fifo"):
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}: "
                             f"one of {ADMISSION_POLICIES}")
        self.server = server
        self.slots = int(slots)
        self.max_k = min(int(max_k), server.num_entities)
        self.filtered = filtered
        self.policy = policy
        self._queue: "collections.deque[KGEQuery]" = collections.deque()
        self._next_id = 0

    def submit(self, head: int, relation: int, k: int = 10,
               request_id: Optional[int] = None) -> KGEQuery:
        """Enqueue one query; returns the (pending) request object."""
        if k < 1:
            raise ValueError(f"k={k} must be >= 1")
        if min(int(k), self.server.num_entities) > self.max_k:
            raise ValueError(
                f"k={k} exceeds the engine's max_k={self.max_k} — raise "
                f"max_k at construction (the jitted step shape depends on "
                f"it)")
        if request_id is None:
            request_id = self._next_id
        self._next_id = max(self._next_id, request_id) + 1
        req = KGEQuery(request_id, int(head), int(relation), int(k))
        self._queue.append(req)
        return req

    @property
    def pending(self) -> int:
        return len(self._queue)

    def step(self) -> List[KGEQuery]:
        """Admit one batch (≤ ``slots`` requests, per ``policy``), answer
        it, and return the completed requests."""
        if not self._queue:
            return []
        if self.policy == "smallest-k-first":
            reqs = sorted(self._queue,
                          key=lambda r: (r.k, r.request_id))[:self.slots]
            for r in reqs:
                self._queue.remove(r)
        else:
            reqs = [self._queue.popleft()
                    for _ in range(min(self.slots, len(self._queue)))]
        # fixed-width batch: pad slots repeat a dummy query (entity/rel 0
        # always exist) and are sliced away below
        heads = np.zeros(self.slots, np.int64)
        rels = np.zeros(self.slots, np.int64)
        for i, r in enumerate(reqs):
            heads[i] = r.head
            rels[i] = r.relation
        scores, tails = self.server.topk_tails(
            heads, rels, self.max_k, filtered=self.filtered)
        for i, r in enumerate(reqs):
            kk = min(r.k, self.server.num_entities)
            r.scores = scores[i, :kk]
            r.tails = tails[i, :kk]
            r.done = True
        return reqs

    def run(self) -> List[KGEQuery]:
        """Drain the queue; returns every completed request in completion
        order."""
        out: List[KGEQuery] = []
        while self._queue:
            out.extend(self.step())
        return out
