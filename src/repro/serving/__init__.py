"""Serving: batched LM decode engine + KGE link-prediction server."""
from repro.serving.engine import ServeEngine, Request, KGEServer
__all__ = ["ServeEngine", "Request", "KGEServer"]
