"""Serving: batched LM decode engine + KGE link-prediction servers
(dense ``KGEServer``; sharded top-k ``ShardedKGEServer`` + dynamic-batching
``KGEServeEngine`` — see ``docs/serving.md``)."""
from repro.serving.engine import ServeEngine, Request, KGEServer
from repro.serving.kge import KGEQuery, KGEServeEngine, ShardedKGEServer

__all__ = ["ServeEngine", "Request", "KGEServer", "KGEQuery",
           "KGEServeEngine", "ShardedKGEServer"]
