"""Batched serving engine for the assigned LM architectures plus a KGE
link-prediction service for the paper's models.

``ServeEngine`` is batch-synchronous static batching: up to ``slots``
requests run together from position 0 — while a slot still has prompt tokens
it consumes them (teacher forcing), afterwards it consumes its own generated
token.  One jitted ``serve_step`` per position, correct for both KV-cache
attention and recurrent-state (RWKV / RG-LRU) architectures.  On-pod the
same step runs with the cache sharded per DESIGN.md §5 — the dry-run lowers
exactly this function.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import topk_padded
from repro.launch.steps import make_serve_step
from repro.nn.transformer import ArchConfig, init_decode_cache


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray          # (P,) int32
    max_new_tokens: int = 16
    output: Optional[List[int]] = None
    done: bool = False          # produced its full max_new_tokens budget
    truncated: bool = False     # cut off by the engine's max_seq horizon


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_seq: int = 256, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.dtype = dtype
        self._step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    def _run_batch(self, reqs: List[Request]) -> None:
        n = self.slots
        cache = init_decode_cache(self.cfg, n, self.max_seq,
                                  dtype=self.dtype)
        if self.cfg.arch_type == "encdec":
            cache["encoder_out"] = jnp.zeros(
                (n, self.cfg.encoder_frames, self.cfg.d_model), self.dtype)
        prompts = [r.prompt for r in reqs] + \
            [np.zeros(1, np.int32)] * (n - len(reqs))
        plens = np.array([len(p) for p in prompts])
        budget = [r.max_new_tokens for r in reqs] + [0] * (n - len(reqs))
        horizon = int(min(self.max_seq - 1,
                          max(plens[i] + budget[i] for i in range(n))))
        for r in reqs:
            r.output = []

        cur = np.array([p[0] for p in prompts], np.int32)
        for t in range(horizon):
            batch = {"tokens": jnp.asarray(cur[:, None]),
                     "pos": jnp.full((n,), t, jnp.int32)}
            if self.cfg.m_rope:
                batch["positions_3d"] = jnp.full((n, 1, 3), t, jnp.int32)
            nxt, cache = self._step(self.params, cache, batch)
            nxt = np.asarray(nxt)
            for i, r in enumerate(reqs):
                if r.done:
                    continue
                if t + 1 < plens[i]:
                    cur[i] = prompts[i][t + 1]      # still in prompt
                else:
                    r.output.append(int(nxt[i]))
                    cur[i] = nxt[i]
                    if len(r.output) >= r.max_new_tokens:
                        r.done = True
            for i in range(len(reqs), n):
                cur[i] = 0
            if all(r.done for r in reqs):
                break
        # A request the max_seq horizon cut off before it exhausted
        # max_new_tokens is NOT complete — report the truncation instead of
        # silently claiming done.
        for r in reqs:
            r.truncated = not r.done

    def run(self, requests: List[Request]) -> List[Request]:
        for lo in range(0, len(requests), self.slots):
            self._run_batch(requests[lo: lo + self.slots])
        return requests


# ---------------------------------------------------------------------- #
# KGE link-prediction serving (the paper's model family)
# ---------------------------------------------------------------------- #
class KGEServer:
    """Answers (head, relation, ?) queries with top-k tails using the
    Pallas ranking kernel, for any registered decoder
    (``repro.models.decoders``).

    ``decoder_params`` is the decoder's own parameter tree (the trained
    model's ``params["decoder"]``); the candidate side of the query form is
    prepared ONCE at construction and cached, so each request only prepares
    its (B, d) queries before the kernel call.
    """

    def __init__(self, entity_emb: np.ndarray, decoder_params,
                 decoder="distmult"):
        from repro.models.decoders import get_decoder
        self.decoder = get_decoder(decoder)
        self.emb = jnp.asarray(entity_emb)
        self.params = jax.tree_util.tree_map(jnp.asarray, decoder_params)
        self._prepared = self.decoder.prepare_candidates(self.params,
                                                         self.emb)

    def topk_tails(self, heads: np.ndarray, rels: np.ndarray,
                   k: int = 10) -> np.ndarray:
        """Top-k tail entity ids, ``(B, min(k, num_entities))`` — ``k`` is
        clamped to the vocabulary and ties break deterministically toward
        the lowest entity id on every backend."""
        if k < 1:
            raise ValueError(f"k={k} must be >= 1")
        k = min(int(k), int(self.emb.shape[0]))
        scores = self.decoder.rank_scores(
            self.params, self.emb[jnp.asarray(heads)], jnp.asarray(rels),
            self.emb, prepared=self._prepared)
        return np.asarray(topk_padded(scores, k)[1])
