"""Full GNN-based KGE model: RGCN encoder + decoder (paper Fig. 1).

Two execution shapes:

* ``minibatch_loss`` — edge mini-batch (Algorithm 1): comp-graph arrays from
  ``repro.core.minibatch``, gather vertex inputs from the global table, run
  RGCN, score the batch triplets, BCE loss.
* ``fullgraph_loss`` — full-edge-batch training on a padded partition (the
  paper's FB15k-237 setting) with device-side constraint-based negatives.

Both are jit/shard_map friendly (fixed shapes, no host callbacks).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.negative import (
    constraint_based_negatives, global_closed_world_negatives, mix_pos_neg,
)
from repro.models import decoders
from repro.models.rgcn import RGCNConfig, init_rgcn_params, rgcn_encode
from repro.sharding.embedding import (
    plan_local_gather_device, sharded_gather,
)


@dataclasses.dataclass(frozen=True)
class KGEConfig:
    rgcn: RGCNConfig
    # registry name or Decoder instance (paper Eq. 4 default); resolved
    # ONLY through repro.models.decoders.get_decoder
    decoder: Union[str, decoders.Decoder] = "distmult"
    num_negatives: int = 1      # paper: 1 on ogbl-citation2
    negative_sampler: str = "constraint"   # "constraint" | "global"

    @property
    def decoder_impl(self) -> decoders.Decoder:
        return decoders.get_decoder(self.decoder)

    @property
    def num_entities(self) -> int:
        return self.rgcn.num_entities

    @property
    def num_table_shards(self) -> int:
        return self.rgcn.num_table_shards


def init_kge_params(key: jax.Array, cfg: KGEConfig) -> Dict[str, Any]:
    k_enc, k_dec = jax.random.split(key)
    params = init_rgcn_params(k_enc, cfg.rgcn)
    params["decoder"] = decoders.init_decoder_params(
        k_dec, cfg.decoder, cfg.rgcn.num_relations, cfg.rgcn.hidden_dim)
    return params


def vertex_input(params: Dict[str, Any], cfg: KGEConfig,
                 gather_global: jax.Array,
                 features: Optional[jax.Array],
                 shard_local_ids: Optional[jax.Array] = None,
                 shard_owned: Optional[jax.Array] = None,
                 shard_inverse: Optional[jax.Array] = None,
                 *, model_axis: Optional[str] = None) -> jax.Array:
    """Gather the per-vertex model input: learned embedding rows
    (transductive) or precomputed features (ogbl-citation2 style).

    With a row-sharded entity table (``(S, rows, d)``, see
    ``repro.sharding.embedding``) the dense gather becomes a shard-local
    gather + exchange, driven by a host-precomputed ``ShardedGatherPlan``
    (``shard_local_ids`` / ``shard_owned``, emitted by the input pipeline)
    or, when none is provided (full-graph / evaluation paths), by the
    identical in-jit plan.  A deduped plan additionally carries
    ``shard_inverse`` — the plan covers each id once and the inverse map
    expands the exchanged rows back to batch slots on device.
    ``model_axis`` names the mesh axis when running inside ``shard_map``;
    ``None`` selects the single-device simulation; ``cfg.rgcn.
    gather_exchange`` picks the exchange layout — every combination is
    bitwise equal to the replicated dense gather.
    """
    if cfg.rgcn.feature_dim is None:
        table = params["entity_embedding"]
        table_dtype = cfg.rgcn.table_dtype
        if table.ndim == 2 and table_dtype == "int8":
            # dense (unsharded) master: run the same quantized gather over
            # a single-shard stack so the int8 semantics don't depend on
            # num_table_shards
            table = table[None]
        if table.ndim == 3:
            if shard_local_ids is None:
                num_shards = (table.shape[0] if model_axis is None
                              else jax.lax.psum(1, model_axis))
                shard_local_ids, shard_owned = plan_local_gather_device(
                    num_shards, table.shape[1], gather_global)
            return sharded_gather(table, shard_local_ids, shard_owned,
                                  axis_name=model_axis,
                                  exchange=cfg.rgcn.gather_exchange,
                                  inverse=shard_inverse,
                                  table_dtype=table_dtype)
        return table[gather_global]
    assert features is not None, "feature-mode model needs features"
    return features[gather_global]


# ====================================================================== #
# Edge mini-batch loss (Algorithm 1 inner loop)
# ====================================================================== #
def minibatch_loss(
    params: Dict[str, Any],
    cfg: KGEConfig,
    batch: Dict[str, jax.Array],
    features: Optional[jax.Array] = None,
    dropout_key: Optional[jax.Array] = None,
    model_axis: Optional[str] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Loss on one padded EdgeMiniBatch (fields as device arrays; batches
    from a sharded-table pipeline also carry the precomputed gather plan
    under ``shard_local_ids`` / ``shard_owned``)."""
    x = vertex_input(params, cfg, batch["gather_global"], features,
                     batch.get("shard_local_ids"),
                     batch.get("shard_owned"),
                     batch.get("shard_inverse"), model_axis=model_axis)
    x = jnp.where(batch["vertex_mask"][:, None], x, 0.0)
    h = rgcn_encode(
        params, cfg.rgcn, x,
        batch["comp_src"], batch["comp_rel"], batch["comp_dst"],
        batch["comp_mask"], dropout_key=dropout_key,
        train=dropout_key is not None)
    scores = decoders.score_triplets(
        params["decoder"], cfg.decoder, h, batch["triplets"])
    mask = batch["triplet_mask"].astype(jnp.float32)
    loss = decoders.bce_loss(scores, batch["labels"], mask)
    pos = batch["labels"] > 0.5
    aux = {
        "loss": loss,
        "pos_score_mean": jnp.sum(scores * mask * pos) /
        jnp.maximum(jnp.sum(mask * pos), 1.0),
        "neg_score_mean": jnp.sum(scores * mask * (1 - pos)) /
        jnp.maximum(jnp.sum(mask * (1 - pos)), 1.0),
    }
    return loss, aux


# ====================================================================== #
# Full-graph loss on a padded self-sufficient partition
# ====================================================================== #
def fullgraph_loss(
    params: Dict[str, Any],
    cfg: KGEConfig,
    part: Dict[str, jax.Array],   # one slice of PaddedPartitionBatch
    rng: jax.Array,
    features: Optional[jax.Array] = None,
    train: bool = True,
    model_axis: Optional[str] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-edge-batch training step on one padded partition (paper's
    FB15k-237 configuration).  Negatives are sampled ON DEVICE from the
    partition's core vertices — legal because the full partition graph is the
    computational graph, so every core vertex already has an embedding."""
    k_neg, k_drop = jax.random.split(rng)
    x = vertex_input(params, cfg, part["local_to_global"], features,
                     part.get("shard_local_ids"),
                     part.get("shard_owned"),
                     part.get("shard_inverse"), model_axis=model_axis)
    x = jnp.where(part["vertex_mask"][:, None], x, 0.0)
    h = rgcn_encode(
        params, cfg.rgcn, x,
        part["src"], part["rel"], part["dst"], part["edge_mask"],
        dropout_key=k_drop if train else None, train=train)

    pos = jnp.stack([part["src"], part["rel"], part["dst"]], axis=1)
    if cfg.negative_sampler == "global":
        # baseline ablation: corrupt with ANY local vertex (the closest
        # analogue of the closed-world sampler inside one partition's
        # address space — a true global draw would need remote fetches)
        neg, _ = global_closed_world_negatives(
            k_neg, pos, cfg.num_negatives,
            int(part["local_to_global"].shape[0]))
    else:
        neg, _ = constraint_based_negatives(
            k_neg, pos, cfg.num_negatives, part["num_core_vertices"])
    trip, labels = mix_pos_neg(pos, neg)
    core = part["core_edge_mask"].astype(jnp.float32)
    mask = jnp.concatenate(
        [core] + [core] * cfg.num_negatives, axis=0)

    scores = decoders.score_triplets(params["decoder"], cfg.decoder, h, trip)
    loss = decoders.bce_loss(scores, labels, mask)
    return loss, {"loss": loss}


# ====================================================================== #
# Encoding for evaluation (embeds every local vertex of a partition)
# ====================================================================== #
def encode_partition(
    params: Dict[str, Any], cfg: KGEConfig, part: Dict[str, jax.Array],
    features: Optional[jax.Array] = None,
) -> jax.Array:
    x = vertex_input(params, cfg, part["local_to_global"], features,
                     part.get("shard_local_ids"), part.get("shard_owned"),
                     part.get("shard_inverse"))
    x = jnp.where(part["vertex_mask"][:, None], x, 0.0)
    return rgcn_encode(
        params, cfg.rgcn, x,
        part["src"], part["rel"], part["dst"], part["edge_mask"])
