"""Relation-aware graph attention encoder (paper ref. [26, 30] — the
authors' companion models).  The paper's distributed approach is "agnostic
to the used knowledge graph embedding model" (§6); this second encoder
proves it in code: RGAT slots into the same partition/expansion/mini-batch
pipeline by sharing the RGCN layer interface.

Per edge (s, r, t):  e_srt = LeakyReLU(a · [W h_s ‖ W h_t ‖ w_r])
attention = masked segment-softmax over the in-edges of s;
h'_s = σ( Σ α_srt · W h_t ).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.rgcn import RGCNConfig, _glorot


@dataclasses.dataclass(frozen=True)
class RGATConfig:
    base: RGCNConfig
    num_rel_dims: int = 16     # relation feature size in the attention


def init_rgat_params(key: jax.Array, cfg: RGATConfig) -> Dict[str, Any]:
    params: Dict[str, Any] = {}
    b = cfg.base
    keys = jax.random.split(key, b.num_layers * 4 + 1)
    ki = iter(keys)
    if b.feature_dim is None:
        params["entity_embedding"] = _glorot(
            next(ki), (b.num_entities, b.hidden_dim))
    layers = []
    for layer in range(b.num_layers):
        d_in = b.layer_in_dim(layer)
        d_out = b.hidden_dim
        layers.append({
            "w": _glorot(next(ki), (d_in, d_out)),
            "rel_feat": _glorot(next(ki),
                                (b.num_relations, cfg.num_rel_dims)),
            "attn": _glorot(next(ki), (2 * d_out + cfg.num_rel_dims, 1)),
            "self_weight": _glorot(next(ki), (d_in, d_out)),
        })
    params["layers"] = layers
    return params


def _segment_softmax(logits: jax.Array, seg: jax.Array, mask: jax.Array,
                     num_segments: int) -> jax.Array:
    """Numerically-stable softmax over edges grouped by head vertex."""
    logits = jnp.where(mask, logits, -1e30)
    seg_max = jax.ops.segment_max(logits, seg, num_segments=num_segments)
    z = jnp.exp(logits - seg_max[seg])
    z = jnp.where(mask, z, 0.0)
    denom = jax.ops.segment_sum(z, seg, num_segments=num_segments)
    return z / jnp.maximum(denom[seg], 1e-20)


def rgat_layer(h: jax.Array, src: jax.Array, rel: jax.Array,
               dst: jax.Array, edge_mask: jax.Array, lp: Dict[str, Any],
               *, activation=jax.nn.relu) -> jax.Array:
    wh = h @ lp["w"]                                   # (V, d_out)
    wh_s = wh[src]
    wh_t = wh[dst]
    rf = lp["rel_feat"][rel]                           # (E, r)
    feat = jnp.concatenate([wh_s, wh_t, rf], axis=-1)
    logits = jax.nn.leaky_relu(
        (feat @ lp["attn"])[:, 0], negative_slope=0.2)  # (E,)
    alpha = _segment_softmax(logits, src, edge_mask, h.shape[0])
    msg = alpha[:, None] * wh_t
    msg = jnp.where(edge_mask[:, None], msg, 0.0)
    agg = jax.ops.segment_sum(msg, src, num_segments=h.shape[0])
    return activation(agg + h @ lp["self_weight"])


def rgat_encode(params: Dict[str, Any], cfg: RGATConfig,
                vertex_input: jax.Array, src, rel, dst, edge_mask,
                **_ignored) -> jax.Array:
    """Same signature shape as ``rgcn_encode`` — drop-in encoder."""
    h = vertex_input
    n = len(params["layers"])
    for i, lp in enumerate(params["layers"]):
        act = jax.nn.relu if i < n - 1 else (lambda x: x)
        h = rgat_layer(h, src, rel, dst, edge_mask, lp, activation=act)
    return h
