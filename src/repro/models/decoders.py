"""KG-embedding decoders (scoring functions) — paper §2.1 Eq. 4.

The paper trains DistMult (``g(s,r,t) = h_s^T M_r h_t`` with diagonal M_r)
and states its scaling approach is "agnostic to the used knowledge graph
embedding model" (§6).  This module makes that agnosticism structural: every
decoder is a registered :class:`Decoder` whose load-bearing contract is the
**canonical query form**

    ``prepare_query(params, h_s, rel)      -> (q, q_bias)``      (B, d), (B,)
    ``prepare_candidates(params, C)        -> (C', c_bias)``   (..., d), (...)
    ``scores = epilogue(q @ C'^T + q_bias[:, None] + c_bias)``

with two epilogue families (``repro.kernels.kge_score.EPILOGUES``):

* ``bilinear`` — identity; DistMult and ComplEx reduce to a plain matmul
  (``q_bias = c_bias = 0``).
* ``neg_l2``   — ``-sqrt(max(x, 0) + NORM_EPS)`` (safe norm, eps under the
  sqrt).  TransE and RotatE use the norm-expansion trick
  ``‖u − c‖² = ‖u‖² + ‖c‖² − 2 u·c``: ``prepare_query`` folds the ``−2``
  into the query (``q = −2u``, ``q_bias = ‖u‖²``) and ``prepare_candidates``
  carries ``c_bias = ‖c‖²`` — the candidate matrix itself is untouched, so
  row-sharded entity tables need no per-decoder transform.

Because both families reduce to one matmul plus rank-1 biases, a single
Pallas kernel (``repro.kernels.kge_score``), the candidate-axis-sharded
ranking path (``repro.eval.sharded``) and the serving engine
(``repro.serving.KGEServer``) carry EVERY registered decoder.  Both
epilogues are elementwise and deterministic, so per-shard greater/equal tie
counts match the dense reference exactly — sharded == dense stays ``==``
for every decoder (``tests/test_decoders.py``).

``Decoder.score`` (the training/direct form) is DEFINED through the same
prepare functions and epilogue, so direct and candidate-form scores use the
identical stabilization — there is no second formula to drift (the old
``transe_score`` added ``1e-9`` inside the difference vector, shifting every
score; the safe-norm epilogue replaces it).  Precision note: the expansion
cancels catastrophically once ``‖u − c‖²`` falls within float32 rounding of
``‖u‖² + ‖c‖²`` (distances ≲1e-3 at typical norms), where scores clamp to
the ``-sqrt(NORM_EPS)`` floor with zero gradient — the accepted cost of
keeping ranking one matmul and direct == candidate scores bit-consistent
(a direct-subtraction ``score()`` would be more accurate there but a
DIFFERENT function from what ranking computes).

String names (CLI / config back-compat) resolve through :func:`get_decoder`;
no ``if name == "distmult"`` dispatch exists outside this registry.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels.kge_score import EPILOGUES, apply_epilogue


# ====================================================================== #
# The Decoder protocol + registry
# ====================================================================== #
@dataclasses.dataclass(frozen=True)
class Decoder:
    """Base class: a registered scoring function in canonical query form.

    Subclasses define ``init_params`` / ``prepare_query`` /
    ``prepare_candidates`` and declare their ``epilogue`` family; ``score``
    and ``score_candidates`` are derived, so every execution path (training
    triplet scoring, dense ranking, sharded ranking, serving top-k) computes
    the same function.  Instances are stateless frozen singletons — safe as
    jit-static closure constants.
    """

    name: str = ""
    epilogue: str = "bilinear"

    def __post_init__(self):
        if self.epilogue not in EPILOGUES:
            raise ValueError(f"unknown epilogue {self.epilogue!r}")

    # ---- per-decoder surface -------------------------------------------
    def init_params(self, key: jax.Array, num_relations: int,
                    dim: int) -> Dict[str, jax.Array]:
        raise NotImplementedError

    def prepare_query(self, params, h_s: jax.Array,
                      rel: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """(B, d) heads + (B,) relation ids → query rows ``q`` (B, d) and
        pre-epilogue bias ``q_bias`` (B,)."""
        raise NotImplementedError

    def prepare_candidates(self, params,
                           candidates: jax.Array
                           ) -> Tuple[jax.Array, jax.Array]:
        """(..., d) candidate tails → ``(C', c_bias)`` with matching leading
        dims.  Must be row-local (each output row a function of its input
        row only) so per-shard candidate blocks prepare independently and
        bitwise-match the dense preparation."""
        raise NotImplementedError

    # ---- derived: every path is the query form -------------------------
    def score(self, params, h_s: jax.Array, rel: jax.Array,
              h_t: jax.Array) -> jax.Array:
        """(B,) triplet scores — the row-wise query form (training path)."""
        q, q_bias = self.prepare_query(params, h_s, rel)
        c, c_bias = self.prepare_candidates(params, h_t)
        return apply_epilogue(jnp.sum(q * c, axis=-1) + q_bias + c_bias,
                              self.epilogue)

    def score_candidates(self, params, h_s: jax.Array, rel: jax.Array,
                         candidates: jax.Array,
                         bias: Optional[jax.Array] = None) -> jax.Array:
        """(B, C) rank-evaluation scores, pure-XLA path (the oracle the
        Pallas kernel is checked against; ``bias`` is the post-epilogue
        filter mask)."""
        q, q_bias = self.prepare_query(params, h_s, rel)
        c, c_bias = self.prepare_candidates(params, candidates)
        scores = apply_epilogue(
            q @ c.T + q_bias[:, None] + c_bias[None, :], self.epilogue)
        return scores if bias is None else scores + bias

    def rank_scores(self, params, h_s: jax.Array, rel: jax.Array,
                    candidates: jax.Array,
                    bias: Optional[jax.Array] = None, *,
                    prepared: Optional[Tuple[jax.Array, jax.Array]] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
        """(B, C) rank-evaluation scores through the Pallas kernel
        (``kernels.ops.kge_score_padded``).  ``prepared`` short-circuits
        ``prepare_candidates`` with a cached ``(C', c_bias)`` — callers that
        rank many query batches against one candidate set (dense ranking,
        serving) prepare once."""
        from repro.kernels.ops import kge_score_padded
        q, q_bias = self.prepare_query(params, h_s, rel)
        if prepared is None:
            prepared = self.prepare_candidates(params, candidates)
        c, c_bias = prepared
        return kge_score_padded(q, c, bias, q_bias, c_bias,
                                epilogue=self.epilogue, interpret=interpret)


_REGISTRY: Dict[str, Decoder] = {}


def register_decoder(decoder: Decoder) -> Decoder:
    """Add a Decoder singleton to the registry (idempotent per name)."""
    if not decoder.name:
        raise ValueError("decoder needs a name")
    _REGISTRY[decoder.name] = decoder
    return decoder


def get_decoder(decoder: Union[str, Decoder]) -> Decoder:
    """Resolve a decoder name (CLI/config strings) or pass through an
    instance — the ONLY string-to-decoder dispatch point in the system."""
    if isinstance(decoder, Decoder):
        return decoder
    try:
        return _REGISTRY[decoder]
    except KeyError:
        raise ValueError(
            f"unknown decoder {decoder!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def registered_decoders() -> Tuple[str, ...]:
    """Registered decoder names, sorted — drives parametrized tests and the
    per-decoder benchmark sweeps."""
    return tuple(sorted(_REGISTRY))


# ====================================================================== #
# The paper's decoders + RotatE (extensibility proof)
# ====================================================================== #
def _split_complex(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """First-half/second-half re/im convention shared by ComplEx and
    RotatE."""
    d = x.shape[-1] // 2
    return x[..., :d], x[..., d:]


def _neg_l2_query(u: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Norm-expansion query: ``q = −2u``, ``q_bias = ‖u‖²`` so that
    ``q·c + q_bias + c_bias = ‖u − c‖²`` (pre-epilogue)."""
    return -2.0 * u, jnp.sum(u * u, axis=-1)


def _zeros_bias(x: jax.Array) -> jax.Array:
    return jnp.zeros(x.shape[:-1], x.dtype)


@dataclasses.dataclass(frozen=True)
class DistMult(Decoder):
    """``g = h_s^T diag(m_r) h_t`` — Eq. 4 with diagonal M_r."""

    name: str = "distmult"
    epilogue: str = "bilinear"

    def init_params(self, key, num_relations, dim):
        return {"rel_diag": jax.random.normal(key, (num_relations, dim))
                * (1.0 / jnp.sqrt(dim))}

    def prepare_query(self, params, h_s, rel):
        q = h_s * params["rel_diag"][rel]
        return q, _zeros_bias(q)

    def prepare_candidates(self, params, candidates):
        return candidates, _zeros_bias(candidates)


@dataclasses.dataclass(frozen=True)
class TransE(Decoder):
    """``g = −‖h_s + r − h_t‖₂`` via the norm expansion (safe-norm: eps
    under the sqrt, NOT inside the difference vector)."""

    name: str = "transe"
    epilogue: str = "neg_l2"

    def init_params(self, key, num_relations, dim):
        return {"rel_vec": jax.random.normal(key, (num_relations, dim))
                * (1.0 / jnp.sqrt(dim))}

    def prepare_query(self, params, h_s, rel):
        return _neg_l2_query(h_s + params["rel_vec"][rel])

    def prepare_candidates(self, params, candidates):
        return candidates, jnp.sum(candidates * candidates, axis=-1)


@dataclasses.dataclass(frozen=True)
class ComplEx(Decoder):
    """``g = Re(<h_s, r, conj(h_t)>)`` with first/second-half re/im: the
    relation-rotated query ``q = (s_r r_r − s_i r_i, s_r r_i + s_i r_r)``
    makes it a plain real matmul against untouched candidates."""

    name: str = "complex"
    epilogue: str = "bilinear"

    def init_params(self, key, num_relations, dim):
        if dim % 2:
            raise ValueError("ComplEx needs even dim")
        return {"rel_complex": jax.random.normal(key, (num_relations, dim))
                * (1.0 / jnp.sqrt(dim))}

    def prepare_query(self, params, h_s, rel):
        sr, si = _split_complex(h_s)
        rr, ri = _split_complex(params["rel_complex"][rel])
        q = jnp.concatenate([sr * rr - si * ri, sr * ri + si * rr], axis=-1)
        return q, _zeros_bias(q)

    def prepare_candidates(self, params, candidates):
        return candidates, _zeros_bias(candidates)


@dataclasses.dataclass(frozen=True)
class RotatE(Decoder):
    """``g = −‖h_s ∘ r − h_t‖₂`` with unit-modulus relations
    ``r = e^{iθ_r}`` (sun et al. 2019), L2 form: the phase rotation of the
    head is the query, candidates ride the same neg_l2 norm expansion as
    TransE.  Registered to prove the query-form protocol extends past the
    paper's decoder set without touching kernel/eval/serving code."""

    name: str = "rotate"
    epilogue: str = "neg_l2"

    def init_params(self, key, num_relations, dim):
        if dim % 2:
            raise ValueError("RotatE needs even dim")
        return {"rel_phase": jax.random.uniform(
            key, (num_relations, dim // 2),
            minval=-jnp.pi, maxval=jnp.pi)}

    def prepare_query(self, params, h_s, rel):
        hr, hi = _split_complex(h_s)
        theta = params["rel_phase"][rel]
        cos, sin = jnp.cos(theta), jnp.sin(theta)
        u = jnp.concatenate([hr * cos - hi * sin, hr * sin + hi * cos],
                            axis=-1)
        return _neg_l2_query(u)

    def prepare_candidates(self, params, candidates):
        return candidates, jnp.sum(candidates * candidates, axis=-1)


DISTMULT = register_decoder(DistMult())
TRANSE = register_decoder(TransE())
COMPLEX = register_decoder(ComplEx())
ROTATE = register_decoder(RotatE())


# ====================================================================== #
# Functional conveniences (all registry-resolved)
# ====================================================================== #
def init_decoder_params(key: jax.Array, decoder: Union[str, Decoder],
                        num_relations: int, dim: int) -> Dict[str, jax.Array]:
    return get_decoder(decoder).init_params(key, num_relations, dim)


def score_triplets(params, decoder: Union[str, Decoder], h: jax.Array,
                   triplets: jax.Array) -> jax.Array:
    """Score (T, 3) batch-local triplets against vertex states h (V, d)."""
    dec = get_decoder(decoder)
    return dec.score(params, h[triplets[:, 0]], triplets[:, 1],
                     h[triplets[:, 2]])


def score_against_candidates(
    params, decoder: Union[str, Decoder], h_s: jax.Array, rel: jax.Array,
    candidates: jax.Array, bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Rank-evaluation form: (B, d) heads × (C, d) candidate tails →
    (B, C), pure-XLA.  The Pallas twin is ``Decoder.rank_scores``."""
    return get_decoder(decoder).score_candidates(params, h_s, rel,
                                                 candidates, bias)


def bce_loss(scores: jax.Array, labels: jax.Array,
             mask: jax.Array) -> jax.Array:
    """Paper Eq. 3: mean binary cross-entropy over positives+negatives,
    numerically stable logits form, padding masked out."""
    per = jnp.maximum(scores, 0) - scores * labels + \
        jnp.log1p(jnp.exp(-jnp.abs(scores)))
    denom = jnp.maximum(mask.sum(), 1.0)
    return jnp.sum(per * mask) / denom
