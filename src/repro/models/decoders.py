"""KG-embedding decoders (scoring functions) — paper §2.1 Eq. 4.

The paper trains DistMult (``g(s,r,t) = h_s^T M_r h_t`` with diagonal M_r);
TransE and ComplEx are included because the paper's approach is "agnostic to
the used knowledge graph embedding model" (§6) and the related frameworks it
compares against (DGL-KE, PBG) ship exactly these.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp


def init_decoder_params(key: jax.Array, name: str, num_relations: int,
                        dim: int) -> Dict[str, jax.Array]:
    if name == "distmult":
        return {"rel_diag": jax.random.normal(key, (num_relations, dim))
                * (1.0 / jnp.sqrt(dim))}
    if name == "transe":
        return {"rel_vec": jax.random.normal(key, (num_relations, dim))
                * (1.0 / jnp.sqrt(dim))}
    if name == "complex":
        if dim % 2:
            raise ValueError("ComplEx needs even dim")
        return {"rel_complex": jax.random.normal(key, (num_relations, dim))
                * (1.0 / jnp.sqrt(dim))}
    raise ValueError(f"unknown decoder {name!r}")


def distmult_score(params, h_s: jax.Array, rel: jax.Array,
                   h_t: jax.Array) -> jax.Array:
    """(B,) scores: sum(h_s * m_r * h_t) — Eq. 4 with diagonal M_r."""
    m = params["rel_diag"][rel]
    return jnp.sum(h_s * m * h_t, axis=-1)


def transe_score(params, h_s, rel, h_t) -> jax.Array:
    """Negative L2 distance: -||h_s + r - h_t||."""
    r = params["rel_vec"][rel]
    return -jnp.linalg.norm(h_s + r - h_t + 1e-9, axis=-1)


def complex_score(params, h_s, rel, h_t) -> jax.Array:
    """Re(<h_s, r, conj(h_t)>) with interleaved re/im halves."""
    d = h_s.shape[-1] // 2
    sr, si = h_s[..., :d], h_s[..., d:]
    tr, ti = h_t[..., :d], h_t[..., d:]
    r = params["rel_complex"][rel]
    rr, ri = r[..., :d], r[..., d:]
    return jnp.sum(sr * rr * tr + si * rr * ti +
                   sr * ri * ti - si * ri * tr, axis=-1)


SCORERS: Dict[str, Callable] = {
    "distmult": distmult_score,
    "transe": transe_score,
    "complex": complex_score,
}


def score_triplets(params, name: str, h: jax.Array,
                   triplets: jax.Array) -> jax.Array:
    """Score (T, 3) batch-local triplets against vertex states h (V, d)."""
    h_s = h[triplets[:, 0]]
    h_t = h[triplets[:, 2]]
    return SCORERS[name](params, h_s, triplets[:, 1], h_t)


def score_against_candidates(
    params, name: str, h_s: jax.Array, rel: jax.Array,
    candidates: jax.Array,
) -> jax.Array:
    """Rank-evaluation form: score (B, d) heads × (C, d) candidate tails →
    (B, C).  For DistMult this is the memory-bound q @ C^T that
    ``repro.kernels.kge_score`` tiles on TPU."""
    if name == "distmult":
        q = h_s * params["rel_diag"][rel]           # (B, d)
        return q @ candidates.T
    if name == "transe":
        r = params["rel_vec"][rel]
        diff = (h_s + r)[:, None, :] - candidates[None, :, :]
        return -jnp.linalg.norm(diff + 1e-9, axis=-1)
    if name == "complex":
        d = h_s.shape[-1] // 2
        r = params["rel_complex"][rel]
        sr, si = h_s[..., :d], h_s[..., d:]
        rr, ri = r[..., :d], r[..., d:]
        # Re(<s, r, conj(t)>) = (sr·rr - si·ri)·tr + (sr·ri + si·rr)·ti
        qr = sr * rr - si * ri
        qi = sr * ri + si * rr
        q = jnp.concatenate([qr, qi], axis=-1)      # (B, 2d)
        return q @ candidates.T
    raise ValueError(name)


def bce_loss(scores: jax.Array, labels: jax.Array,
             mask: jax.Array) -> jax.Array:
    """Paper Eq. 3: mean binary cross-entropy over positives+negatives,
    numerically stable logits form, padding masked out."""
    per = jnp.maximum(scores, 0) - scores * labels + \
        jnp.log1p(jnp.exp(-jnp.abs(scores)))
    denom = jnp.maximum(mask.sum(), 1.0)
    return jnp.sum(per * mask) / denom
