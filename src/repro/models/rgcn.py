"""RGCN encoder (Schlichtkrull et al., 2018) in pure JAX — paper §2.1.

Message passing (paper Eq. 1)::

    h'_s = sigma( W_0 h_s  +  sum_{(r,t) in N_s} (1/c_s) W_r h_t )

with two regularizations from the RGCN paper, both implemented:

* basis decomposition (Eq. 2): ``W_r = sum_b a_rb V_b`` — the configuration
  the paper trains (2 bases on ogbl-citation2);
* block-diagonal decomposition: ``W_r = diag(Q_r1 .. Q_rB)``.

The edge-level compute ``m_e = W_{rel_e} h_{dst_e}`` followed by a segment
sum into ``src_e`` is the hot spot; ``repro.kernels.rgcn_message`` provides
the Pallas TPU kernel, and this module's ``message_passing_ref`` is the pure
jnp implementation used as its oracle and as the CPU path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RGCNConfig:
    num_entities: int
    num_relations: int        # AFTER adding inverse relations
    hidden_dim: int = 75      # paper: 75 on FB15k-237, 32 on ogbl-citation2
    num_layers: int = 2       # paper: 2-layer RGCN
    num_bases: int = 2        # paper: 2 basis functions
    feature_dim: Optional[int] = None  # None => learned entity embeddings
    decomposition: str = "basis"       # "basis" | "block" | "none"
    num_blocks: int = 4                # for block-diagonal decomposition
    dropout: float = 0.2
    self_loop: bool = True
    use_kernel: bool = False  # route edge compute through the Pallas kernel
    num_table_shards: int = 1  # >1: entity table stored (S, rows, d), row-
    #   sharded over the model axis (repro.sharding.embedding); the gather
    #   becomes shard-local + exchange, bitwise equal to the dense gather
    gather_exchange: Optional[str] = None  # exchange layout for the sharded
    #   gather (None = per-path default: "fused" sim, "psum_scatter" under
    #   shard_map; see sharding.embedding.SIM_EXCHANGES/SPMD_EXCHANGES) —
    #   all layouts are bitwise equal, this picks the comm pattern only
    table_dtype: str = "fp32"  # "fp32" | "int8": int8 keeps the optimizer's
    #   fp32 MASTER table but runs every gather as quantize → fused-dequant
    #   (repro.sharding.embedding.quantize_rows; int8 codes cross the wire
    #   under shard_map, fp32 per-row scales ride along) — forward values
    #   round to ≤ scale/2 per element, gradients accumulate into the
    #   master bitwise equal to the fp32 path on the dequantized table

    def layer_in_dim(self, layer: int) -> int:
        if layer == 0:
            return self.feature_dim or self.hidden_dim
        return self.hidden_dim


# ====================================================================== #
# Parameters
# ====================================================================== #
def init_rgcn_params(key: jax.Array, cfg: RGCNConfig) -> Dict[str, Any]:
    """Glorot-initialized parameter pytree."""
    params: Dict[str, Any] = {}
    keys = jax.random.split(key, cfg.num_layers * 3 + 1)
    ki = iter(keys)

    if cfg.feature_dim is None:
        table = _glorot(next(ki), (cfg.num_entities, cfg.hidden_dim))
        if cfg.num_table_shards > 1:
            # same values as the dense init (same key), stored row-sharded;
            # padding rows are zero and never gathered, so sharded and
            # replicated models are initialized bitwise identically
            from repro.sharding.embedding import (
                ShardedTableLayout, shard_table,
            )
            table = shard_table(table, ShardedTableLayout(
                cfg.num_entities, cfg.num_table_shards))
        params["entity_embedding"] = table

    layers = []
    for layer in range(cfg.num_layers):
        d_in = cfg.layer_in_dim(layer)
        d_out = cfg.hidden_dim
        lp: Dict[str, Any] = {}
        if cfg.decomposition == "basis":
            lp["bases"] = _glorot(next(ki), (cfg.num_bases, d_in, d_out))
            lp["coeffs"] = _glorot(next(ki), (cfg.num_relations,
                                              cfg.num_bases))
        elif cfg.decomposition == "block":
            if d_in % cfg.num_blocks or d_out % cfg.num_blocks:
                raise ValueError("dims must divide num_blocks")
            lp["blocks"] = _glorot(
                next(ki),
                (cfg.num_relations, cfg.num_blocks,
                 d_in // cfg.num_blocks, d_out // cfg.num_blocks))
        elif cfg.decomposition == "none":
            lp["rel_weight"] = _glorot(
                next(ki), (cfg.num_relations, d_in, d_out))
        else:
            raise ValueError(cfg.decomposition)
        if cfg.self_loop:
            lp["self_weight"] = _glorot(next(ki), (d_in, d_out))
        layers.append(lp)
    params["layers"] = layers
    return params


def _glorot(key: jax.Array, shape) -> jax.Array:
    fan_in, fan_out = shape[-2] if len(shape) > 1 else 1, shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return (jax.random.normal(key, shape) * scale).astype(jnp.float32)


# ====================================================================== #
# Message passing
# ====================================================================== #
def relation_matrices(lp: Dict[str, Any], cfg: RGCNConfig) -> jax.Array:
    """Materialize (R, d_in, d_out) from the decomposition (reference path;
    fine for the R used here, the kernel path never materializes these for
    basis decomposition)."""
    if "bases" in lp:
        return jnp.einsum("rb,bio->rio", lp["coeffs"], lp["bases"])
    if "blocks" in lp:
        r, nb, bi, bo = lp["blocks"].shape
        w = jnp.zeros((r, nb * bi, nb * bo), lp["blocks"].dtype)
        for b in range(nb):
            w = w.at[:, b * bi:(b + 1) * bi, b * bo:(b + 1) * bo].set(
                lp["blocks"][:, b])
        return w
    return lp["rel_weight"]


def message_passing_ref(
    h: jax.Array,            # (V, d_in) vertex states
    src: jax.Array,          # (E,) int32 — edge (s, r, t): message INTO s
    rel: jax.Array,          # (E,) int32
    dst: jax.Array,          # (E,) int32 — message source vertex t
    edge_mask: jax.Array,    # (E,) bool
    lp: Dict[str, Any],
    cfg: RGCNConfig,
) -> jax.Array:
    """Pure-jnp edge compute + mean aggregation: the Pallas oracle.

    Returns (V, d_out) aggregated neighbor messages (NOT including self loop
    / activation — the layer wrapper adds those).
    """
    h_t = h[dst]  # (E, d_in) gather tail features
    if "bases" in lp:
        # m_e = sum_b a_[rel_e]b (V_b h_t_e): compute B projections once,
        # then per-edge coefficient mix — O(B·E·d²) -> O(B·V·d² + B·E·d).
        proj = jnp.einsum("ed,bdo->ebo", h_t, lp["bases"])   # (E, B, d_out)
        coef = lp["coeffs"][rel]                              # (E, B)
        msg = jnp.einsum("ebo,eb->eo", proj, coef)
    elif "blocks" in lp:
        r, nb, bi, bo = lp["blocks"].shape
        e = h_t.shape[0]
        h_blk = h_t.reshape(e, nb, bi)
        w_e = lp["blocks"][rel]                               # (E, nb, bi, bo)
        msg = jnp.einsum("enb,enbo->eno", h_blk, w_e).reshape(e, nb * bo)
    else:
        w_e = lp["rel_weight"][rel]                           # (E, d_in, d_out)
        msg = jnp.einsum("ed,edo->eo", h_t, w_e)

    msg = jnp.where(edge_mask[:, None], msg, 0.0)
    num_v = h.shape[0]
    agg = jax.ops.segment_sum(msg, src, num_segments=num_v)
    deg = jax.ops.segment_sum(edge_mask.astype(h.dtype), src,
                              num_segments=num_v)
    return agg / jnp.maximum(deg, 1.0)[:, None]


def rgcn_layer(
    h: jax.Array, src: jax.Array, rel: jax.Array, dst: jax.Array,
    edge_mask: jax.Array, lp: Dict[str, Any], cfg: RGCNConfig,
    *, activation=jax.nn.relu, dropout_key: Optional[jax.Array] = None,
) -> jax.Array:
    if cfg.use_kernel and "bases" in lp:
        from repro.kernels.ops import rgcn_message_basis
        agg = rgcn_message_basis(
            h, src, rel, dst, edge_mask, lp["bases"], lp["coeffs"])
    else:
        agg = message_passing_ref(h, src, rel, dst, edge_mask, lp, cfg)
    if cfg.self_loop:
        agg = agg + h @ lp["self_weight"]
    out = activation(agg)
    if dropout_key is not None and cfg.dropout > 0:
        keep = jax.random.bernoulli(dropout_key, 1 - cfg.dropout, out.shape)
        out = jnp.where(keep, out / (1 - cfg.dropout), 0.0)
    return out


def rgcn_encode(
    params: Dict[str, Any],
    cfg: RGCNConfig,
    vertex_input: jax.Array,   # (V, F) features OR (V, d) gathered embeddings
    src: jax.Array, rel: jax.Array, dst: jax.Array, edge_mask: jax.Array,
    *, dropout_key: Optional[jax.Array] = None, train: bool = False,
) -> jax.Array:
    """Run all RGCN layers on a (padded) computational graph.

    The final layer keeps a linear output (standard for link prediction —
    scores need signed values).
    """
    h = vertex_input
    n_layers = len(params["layers"])
    keys = (jax.random.split(dropout_key, n_layers)
            if (train and dropout_key is not None) else [None] * n_layers)
    for i, lp in enumerate(params["layers"]):
        act = jax.nn.relu if i < n_layers - 1 else (lambda x: x)
        h = rgcn_layer(h, src, rel, dst, edge_mask, lp, cfg,
                       activation=act, dropout_key=keys[i])
    return h


def count_params(params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(params)))
