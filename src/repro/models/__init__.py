"""GNN-based KG embedding models (encoder-decoder, paper Fig. 1)."""
from repro.models.rgcn import (
    RGCNConfig, init_rgcn_params, rgcn_encode, rgcn_layer,
    message_passing_ref, relation_matrices, count_params,
)
from repro.models.decoders import (
    Decoder, get_decoder, register_decoder, registered_decoders,
    init_decoder_params, score_triplets, score_against_candidates, bce_loss,
)
from repro.models.rgat import (
    RGATConfig, init_rgat_params, rgat_encode, rgat_layer,
)
from repro.models.kge import (
    KGEConfig, init_kge_params, minibatch_loss, fullgraph_loss,
    encode_partition, vertex_input,
)

__all__ = [n for n in dir() if not n.startswith("_")]
