"""Checkpointing: save/restore arbitrary pytrees as .npz + a JSON manifest.

No external deps (no orbax offline); flattening uses '/'-joined tree paths so
restores are structure-checked.  Device arrays are pulled to host; restore
returns numpy which JAX consumes (and re-shards under jit) transparently.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metadata: Optional[Dict] = None,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {_path_str(p): np.asarray(v) for p, v in flat}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "metadata": metadata or {},
    }
    with open(path.replace(".npz", ".json"), "w") as f:
        json.dump(manifest, f, indent=1)
    _garbage_collect(directory, keep)
    return path


def _garbage_collect(directory: str, keep: int) -> None:
    """Prune old checkpoints, newest ``keep`` retained.

    ``keep <= 0`` means KEEP EVERYTHING — an explicit contract, not the
    accident of ``ckpts[:-0]`` being empty (``ckpts[:-keep] if keep`` only
    worked for 0; a negative keep would have deleted the newest files).
    Orphaned ``.json`` manifests whose ``.npz`` payload is gone (partial
    copy, crashed save, out-of-band cleanup) are removed either way so
    ``latest_checkpoint`` and the GC window never count phantom steps.
    """
    names = os.listdir(directory)
    ckpts = sorted(f for f in names if re.fullmatch(r"ckpt_\d+\.npz", f))
    live = set(ckpts)
    for f in names:
        if re.fullmatch(r"ckpt_\d+\.json", f) and \
                f.replace(".json", ".npz") not in live:
            os.remove(os.path.join(directory, f))
    if keep <= 0:
        return
    for old in ckpts[:-keep]:
        os.remove(os.path.join(directory, old))
        j = os.path.join(directory, old.replace(".npz", ".json"))
        if os.path.exists(j):
            os.remove(j)


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(
        f for f in os.listdir(directory)
        if re.fullmatch(r"ckpt_\d+\.npz", f))
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def read_metadata(path: str) -> Tuple[int, Dict]:
    """``(step, metadata)`` from a checkpoint's JSON manifest — the
    trainer-side state (epoch counter, PRNG key) that must survive a
    resume lives here, next to (not inside) the array tree."""
    with open(path.replace(".npz", ".json")) as f:
        manifest = json.load(f)
    return manifest["step"], manifest.get("metadata", {})


def restore_checkpoint(path: str, like: PyTree,
                       entity_rows: Optional[int] = None
                       ) -> Tuple[int, PyTree]:
    """Restore into the structure of ``like`` (shape/dtype verified).

    The entity embedding table round-trips across storage layouts: a
    checkpoint saved with a dense ``(V, d)`` table restores into a model
    holding a model-axis row-sharded ``(S, rows, d)`` table and vice versa
    (and across shard counts) — the row blocks are contiguous, so the
    conversion is a pad/trim + reshape (``repro.sharding.embedding``).
    Pass ``entity_rows`` (the model's true entity count) to verify the
    conversion exactly; without it, sharded layouts can only be checked up
    to their tail padding.  Every other leaf keeps the strict shape check.
    """
    from repro.sharding.embedding import convert_table_layout

    data = np.load(path)
    with open(path.replace(".npz", ".json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, v in flat:
        k = _path_str(p)
        if k not in data:
            raise KeyError(f"checkpoint missing leaf {k!r}")
        arr = data[k]
        if tuple(arr.shape) != tuple(np.shape(v)):
            if k.split("/")[-1] == "entity_embedding":
                arr = convert_table_layout(arr, np.shape(v),
                                           num_rows=entity_rows)
            else:
                raise ValueError(
                    f"shape mismatch at {k}: ckpt {arr.shape} vs model "
                    f"{np.shape(v)}")
        out.append(arr)
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, out)
