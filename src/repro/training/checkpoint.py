"""Checkpointing: save/restore arbitrary pytrees as .npz + a JSON manifest.

No external deps (no orbax offline); flattening uses '/'-joined tree paths so
restores are structure-checked.  Device arrays are pulled to host; restore
returns numpy which JAX consumes (and re-shards under jit) transparently.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metadata: Optional[Dict] = None,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {_path_str(p): np.asarray(v) for p, v in flat}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "metadata": metadata or {},
    }
    with open(path.replace(".npz", ".json"), "w") as f:
        json.dump(manifest, f, indent=1)
    _garbage_collect(directory, keep)
    return path


def _garbage_collect(directory: str, keep: int) -> None:
    ckpts = sorted(
        f for f in os.listdir(directory)
        if re.fullmatch(r"ckpt_\d+\.npz", f))
    for old in ckpts[:-keep] if keep else []:
        os.remove(os.path.join(directory, old))
        j = os.path.join(directory, old.replace(".npz", ".json"))
        if os.path.exists(j):
            os.remove(j)


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(
        f for f in os.listdir(directory)
        if re.fullmatch(r"ckpt_\d+\.npz", f))
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def restore_checkpoint(path: str, like: PyTree) -> Tuple[int, PyTree]:
    """Restore into the structure of ``like`` (shape/dtype verified)."""
    data = np.load(path)
    with open(path.replace(".npz", ".json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, v in flat:
        k = _path_str(p)
        if k not in data:
            raise KeyError(f"checkpoint missing leaf {k!r}")
        arr = data[k]
        if tuple(arr.shape) != tuple(np.shape(v)):
            raise ValueError(
                f"shape mismatch at {k}: ckpt {arr.shape} vs model "
                f"{np.shape(v)}")
        out.append(arr)
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, out)
