"""Checkpointing: save/restore arbitrary pytrees as .npz + a JSON manifest.

No external deps (no orbax offline); flattening uses '/'-joined tree paths so
restores are structure-checked.  Device arrays are pulled to host; restore
returns numpy which JAX consumes (and re-shards under jit) transparently.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metadata: Optional[Dict] = None,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {_path_str(p): np.asarray(v) for p, v in flat}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "metadata": metadata or {},
    }
    with open(path.replace(".npz", ".json"), "w") as f:
        json.dump(manifest, f, indent=1)
    _garbage_collect(directory, keep)
    return path


def _garbage_collect(directory: str, keep: int) -> None:
    """Prune old checkpoints, newest ``keep`` retained.

    ``keep <= 0`` means KEEP EVERYTHING — an explicit contract, not the
    accident of ``ckpts[:-0]`` being empty (``ckpts[:-keep] if keep`` only
    worked for 0; a negative keep would have deleted the newest files).
    Orphaned ``.json`` manifests whose ``.npz`` payload is gone (partial
    copy, crashed save, out-of-band cleanup) are removed either way so
    ``latest_checkpoint`` and the GC window never count phantom steps.
    """
    names = os.listdir(directory)
    ckpts = sorted(f for f in names if re.fullmatch(r"ckpt_\d+\.npz", f))
    live = set(ckpts)
    for f in names:
        if re.fullmatch(r"ckpt_\d+\.json", f) and \
                f.replace(".json", ".npz") not in live:
            os.remove(os.path.join(directory, f))
    if keep <= 0:
        return
    for old in ckpts[:-keep]:
        os.remove(os.path.join(directory, old))
        j = os.path.join(directory, old.replace(".npz", ".json"))
        if os.path.exists(j):
            os.remove(j)


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(
        f for f in os.listdir(directory)
        if re.fullmatch(r"ckpt_\d+\.npz", f))
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def read_metadata(path: str) -> Tuple[int, Dict]:
    """``(step, metadata)`` from a checkpoint's JSON manifest — the
    trainer-side state (epoch counter, PRNG key) that must survive a
    resume lives here, next to (not inside) the array tree."""
    with open(path.replace(".npz", ".json")) as f:
        manifest = json.load(f)
    return manifest["step"], manifest.get("metadata", {})


def restore_checkpoint(path: str, like: PyTree,
                       entity_rows: Optional[int] = None
                       ) -> Tuple[int, PyTree]:
    """Restore into the structure of ``like`` (shape/dtype verified).

    The entity embedding table round-trips across storage layouts: a
    checkpoint saved with a dense ``(V, d)`` table restores into a model
    holding a model-axis row-sharded ``(S, rows, d)`` table and vice versa
    (and across shard counts) — the row blocks are contiguous, so the
    conversion is a pad/trim + reshape (``repro.sharding.embedding``).
    Pass ``entity_rows`` (the model's true entity count) to verify the
    conversion exactly; without it, sharded layouts can only be checked up
    to their tail padding.  Every other leaf keeps the strict shape check.

    Quantized tables round-trip too.  A quantized tree stores the entity
    table as ``entity_embedding/{codes, scales}`` (``repro.sharding.
    embedding.quantize_table`` — the serving/export form; training keeps
    the fp32 master).  Four conversions compose with the layout
    conversion above:

    * quantized → quantized across shard counts: codes and scales are
      pad/trim-reshaped EXACTLY (padding rows are all-zero, which is also
      their quantized form — no requantization, bits preserved);
    * quantized checkpoint → fp32 model: dequantize (exact: code · pow2
      scale) then convert the layout;
    * fp32 checkpoint → quantized model: convert the layout then
      requantize — deterministic, ``quantize_rows`` has no randomness,
      so restoring the same checkpoint twice yields identical codes;
    * anything else (wrong dtype, wrong row count) fails with an explicit
      error, never a silent cast.
    """
    from repro.sharding.embedding import (
        convert_table_layout, dequantize_rows, quantize_rows,
    )

    data = np.load(path)
    with open(path.replace(".npz", ".json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    names = set(getattr(data, "files", ()))

    def convert_scales(arr, target_shape):
        # scales are (..., rows) — a table with d=1 as far as the
        # row-block pad/trim is concerned
        return convert_table_layout(
            arr[..., None], tuple(target_shape) + (1,),
            num_rows=entity_rows)[..., 0]

    requant_cache: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}

    def requantized(parent, codes_shape):
        # fp32 checkpoint table → the model's quantized layout: layout
        # conversion FIRST (per-row amax is layout-invariant; padding
        # rows quantize to zero codes + zero scale), then one
        # deterministic quantization shared by the codes and scales leaves
        if parent not in requant_cache:
            src = data[parent]
            if src.dtype != np.float32:
                raise ValueError(
                    f"cannot quantize checkpoint leaf {parent!r} of dtype "
                    f"{src.dtype} into an int8 table — expected float32")
            codes, scales = quantize_rows(
                convert_table_layout(src, codes_shape,
                                     num_rows=entity_rows))
            requant_cache[parent] = (np.asarray(codes), np.asarray(scales))
        return requant_cache[parent]

    out = []
    for p, v in flat:
        k = _path_str(p)
        parts = k.split("/")
        leaf = parts[-1]
        parent = "/".join(parts[:-1])
        quant_leaf = (leaf in ("codes", "scales") and len(parts) >= 2
                      and parts[-2] == "entity_embedding")
        if k in names:
            arr = data[k]
            if tuple(arr.shape) != tuple(np.shape(v)):
                if leaf == "entity_embedding":
                    arr = convert_table_layout(arr, np.shape(v),
                                               num_rows=entity_rows)
                elif quant_leaf and leaf == "codes":
                    if arr.dtype != np.int8:
                        raise ValueError(
                            f"dtype mismatch at {k}: ckpt {arr.dtype} vs "
                            f"int8 codes — not a quantized table")
                    arr = convert_table_layout(arr, np.shape(v),
                                               num_rows=entity_rows)
                elif quant_leaf:
                    arr = convert_scales(arr, np.shape(v))
                else:
                    raise ValueError(
                        f"shape mismatch at {k}: ckpt {arr.shape} vs model "
                        f"{np.shape(v)}")
        elif leaf == "entity_embedding" and f"{k}/codes" in names:
            # quantized checkpoint into an fp32 model: exact dequantize,
            # then the usual layout conversion
            codes = data[f"{k}/codes"]
            if codes.dtype != np.int8:
                raise ValueError(
                    f"dtype mismatch at {k}/codes: ckpt {codes.dtype} vs "
                    f"int8 — not a quantized table")
            arr = convert_table_layout(
                np.asarray(dequantize_rows(codes, data[f"{k}/scales"])),
                np.shape(v), num_rows=entity_rows)
        elif quant_leaf and parent in names:
            # fp32 checkpoint into a quantized model: deterministic
            # requantization in the model's layout
            codes_shape = (np.shape(v) if leaf == "codes"
                           else tuple(np.shape(v)) + (np.shape(v)[-1],))
            if leaf == "scales":
                # the codes leaf of the same table fixes the row layout;
                # scales only need the leading dims
                codes_like = [vv for pp, vv in flat
                              if _path_str(pp) == f"{parent}/codes"]
                codes_shape = np.shape(codes_like[0]) if codes_like else \
                    codes_shape
            codes, scales = requantized(parent, tuple(codes_shape))
            arr = codes if leaf == "codes" else scales
        else:
            raise KeyError(f"checkpoint missing leaf {k!r}")
        if tuple(arr.shape) != tuple(np.shape(v)):
            raise ValueError(
                f"shape mismatch at {k}: converted {arr.shape} vs model "
                f"{np.shape(v)}")
        out.append(arr)
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, out)
