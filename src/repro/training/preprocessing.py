"""Offline preprocessing (paper §3.2): partition → expand → pad → budgets.

One function, one artifact: ``preprocess_graph`` turns a training KG into a
``PreprocessedGraph`` holding everything the input pipeline and the SPMD
step need — self-sufficient partitions, the padded full-graph batch, the
replication factor (paper Eq. 7), and (in mini-batch mode) the comp-graph
budgets plus per-partition CSR indices.  The trainer, the launch CLI, the
examples and the benchmarks all go through this seam, so preprocessing can
be cached/sharded later without touching any of them.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core import (
    BatchBudget, KnowledgeGraph, expand_all, pad_partitions, partition_graph,
    plan_budgets, replication_factor,
)
from repro.core.expansion import PaddedPartitionBatch, SelfSufficientPartition
from repro.core.minibatch import _PartitionCSR
from repro.sharding.embedding import ShardedTableLayout


@dataclasses.dataclass
class PreprocessedGraph:
    """Everything downstream of offline preprocessing."""

    train_kg: KnowledgeGraph
    partitions: List[SelfSufficientPartition]
    padded: PaddedPartitionBatch
    replication_factor: float
    # mini-batch mode only:
    budget: Optional[BatchBudget] = None
    csrs: Optional[List[_PartitionCSR]] = None
    # entity-table layout when the embedding table is row-sharded over the
    # model axis (repro.sharding.embedding); None = replicated table
    table_layout: Optional[ShardedTableLayout] = None

    @property
    def num_partitions(self) -> int:
        return len(self.partitions)


def preprocess_graph(
    train_kg: KnowledgeGraph,
    *,
    num_trainers: int,
    strategy: str = "vertex_cut",
    num_hops: int = 2,
    seed: int = 0,
    batch_size: Optional[int] = None,
    num_negatives: int = 1,
    sampler: str = "constraint",
    num_table_shards: int = 1,
) -> PreprocessedGraph:
    """Partition ``train_kg`` and make every partition self-sufficient.

    With ``batch_size`` set, also probes the comp-graph budgets (sized
    against the same positive↔negative pairing the mini-batch iterator uses)
    and builds the per-partition in-edge CSRs the hot path gathers from.
    With ``num_table_shards > 1``, derives the entity-table
    ``ShardedTableLayout`` the pipeline's gather plans and the model's
    row-sharded table both follow.
    """
    parts = partition_graph(train_kg, num_trainers, strategy, seed=seed)
    partitions = expand_all(train_kg, parts, num_hops)
    pre = PreprocessedGraph(
        train_kg=train_kg,
        partitions=partitions,
        padded=pad_partitions(partitions),
        replication_factor=replication_factor(train_kg, parts),
        table_layout=(
            ShardedTableLayout(train_kg.num_entities, num_table_shards)
            if num_table_shards > 1 else None),
    )
    if batch_size is not None:
        pre.budget = plan_budgets(
            partitions, batch_size, num_negatives, num_hops, seed=seed,
            sampler=sampler)
        pre.csrs = [_PartitionCSR(p) for p in partitions]
    return pre
