"""Training substrate: optimizers, distributed step, checkpointing, driver."""
from repro.training.optimizer import (
    adam, sgd, apply_updates, global_norm, constant_schedule,
    warmup_cosine_schedule, Optimizer, OptState,
)
from repro.training.distributed import (
    make_simulated_train_step, make_spmd_train_step, split_trainer_keys,
)
from repro.training.checkpoint import (
    save_checkpoint, restore_checkpoint, latest_checkpoint,
)
from repro.training.preprocessing import PreprocessedGraph, preprocess_graph
from repro.training.evaluation import encode_all_entities, evaluate_split
from repro.training.trainer import KGETrainer, TrainConfig
__all__ = [n for n in dir() if not n.startswith("_")]
