"""Evaluation-time encoding + filtered ranking (paper §4.3).

Standalone functions so the CLI, examples and benchmarks can evaluate saved
parameters without constructing a trainer.

Scaling shape (PR 3): the encoder pass STREAMS over self-sufficient
partitions — each partition is encoded with ``encode_partition`` (reusing
the training partitions and, with a row-sharded table, the same
host-precomputed ``ShardedGatherPlan`` path the training collator uses) and
its CORE vertices are scattered into the global embedding matrix.  Core
vertices carry their full ``num_hops`` receptive field inside the partition
(the paper's self-sufficiency invariant), so the streamed embeddings are
mathematically identical to a full-graph encode; a single partition
reproduces the old mega-partition pass exactly.  Ranking then goes through
``repro.eval`` — candidate-axis-sharded when the model's entity table is
row-sharded (``num_table_shards > 1``), in which case the host builds each
shard's filter-bias column block straight from the CSR index (the dense
``(B, N)`` bias never exists on this path) and both candidate protocols
(all-entities and ogbl candidate lists) ride the sharded count exchange.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import numpy as np

from repro.core import KnowledgeGraph, expand_all, pad_partitions, \
    partition_graph
from repro.core.expansion import PaddedPartitionBatch, SelfSufficientPartition
from repro.data.pipeline import eval_partition_batches
from repro.eval.ranking import evaluate_both_directions
from repro.models import KGEConfig, encode_partition
from repro.sharding.embedding import ShardedTableLayout


def encode_all_entities(
    params: Dict[str, Any],
    kge_cfg: KGEConfig,
    train_kg: KnowledgeGraph,
    num_hops: int,
    features: Optional[Any] = None,
    partitions: Optional[Sequence[SelfSufficientPartition]] = None,
    padded: Optional[PaddedPartitionBatch] = None,
) -> np.ndarray:
    """Embed every entity for evaluation by streaming ``encode_partition``
    over self-sufficient partitions and scattering core vertices into the
    global ``(N, d)`` matrix.

    ``partitions``/``padded`` reuse the trainer's preprocessing artifacts
    (no re-partitioning on the eval path); with neither given, the graph is
    wrapped in a single partition — the full-graph mega-partition pass.
    Every non-isolated entity is a core vertex of at least one partition
    (edge partitions cover all edges), so the scatter covers the same rows
    the mega-partition pass does; isolated entities keep zero rows in both.
    """
    if padded is None:
        if partitions is None:
            partitions = expand_all(
                train_kg, partition_graph(train_kg, 1, "random", seed=0),
                num_hops)
        padded = pad_partitions(partitions)

    layout = None
    if kge_cfg.rgcn.feature_dim is None and kge_cfg.num_table_shards > 1:
        layout = ShardedTableLayout(train_kg.num_entities,
                                    kge_cfg.num_table_shards)

    out: Optional[np.ndarray] = None
    v_idx = np.arange(padded.padded_vertices)
    for i, part in enumerate(eval_partition_batches(padded, layout)):
        h = np.asarray(encode_partition(params, kge_cfg, part,
                                        features=features))
        if out is None:
            out = np.zeros((train_kg.num_entities, h.shape[1]), np.float32)
        # scatter CORE rows only: support vertices at the receptive-field
        # boundary are not self-sufficient in this partition and another
        # partition owns them as core
        core = np.asarray(padded.vertex_mask[i]) & \
            (v_idx < int(padded.num_core_vertices[i]))
        out[np.asarray(padded.local_to_global[i])[core]] = h[core]
    assert out is not None, "no partitions to encode"
    return out


def evaluate_split(
    params: Dict[str, Any],
    kge_cfg: KGEConfig,
    splits: Dict[str, KnowledgeGraph],
    split: str,
    num_hops: int,
    decoder: str,
    features: Optional[Any] = None,
    partitions: Optional[Sequence[SelfSufficientPartition]] = None,
    padded: Optional[PaddedPartitionBatch] = None,
) -> Dict[str, float]:
    """Filtered MRR / Hits@k on ``split`` (both directions, paper protocol).

    ``partitions``/``padded`` stream the encoder over existing training
    partitions; ``decoder`` resolves through the registry
    (``repro.models.decoders``) and its whole parameter tree rides along, so
    with ``num_table_shards > 1`` ranking is candidate-axis-sharded over the
    model's row blocks for EVERY registered decoder — per-shard filter-bias
    blocks built straight from CSR (peak host bias memory ∝ 1/shards), no
    dense ``(B, N)`` bias anywhere on the sharded path."""
    emb = encode_all_entities(
        params, kge_cfg, splits["train"].with_inverse_relations(),
        num_hops, features=features, partitions=partitions, padded=padded)
    metrics = evaluate_both_directions(
        emb, params["decoder"], splits[split],
        [splits["train"], splits["valid"], splits["test"]],
        num_relations_base=splits["train"].num_relations,
        decoder=decoder,
        num_shards=(kge_cfg.num_table_shards
                    if kge_cfg.rgcn.feature_dim is None else 1),
        table_dtype=(kge_cfg.rgcn.table_dtype
                     if kge_cfg.rgcn.feature_dim is None else "fp32"),
    )
    return {f"{split}_{k}": v for k, v in metrics.items()}
