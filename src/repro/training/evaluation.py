"""Evaluation-time encoding + filtered ranking (paper §4.3).

Standalone functions so the CLI, examples and benchmarks can evaluate saved
parameters without constructing a trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import KnowledgeGraph, expand_all, pad_partitions, \
    partition_graph
from repro.eval.ranking import evaluate_both_directions
from repro.models import KGEConfig, encode_partition

# decoder -> relation-table key in params["decoder"]
DECODER_TABLE_KEY = {"distmult": "rel_diag", "transe": "rel_vec",
                     "complex": "rel_complex"}


def encode_all_entities(
    params: Dict[str, Any],
    kge_cfg: KGEConfig,
    train_kg: KnowledgeGraph,
    num_hops: int,
    features: Optional[jnp.ndarray] = None,
) -> np.ndarray:
    """Embed every entity with the full (unpartitioned) train graph — the
    evaluation-time encoder pass."""
    full = partition_graph(train_kg, 1, "random", seed=0)
    full_part = expand_all(train_kg, full, num_hops)
    pb = pad_partitions(full_part)
    part0 = {f.name: jnp.asarray(getattr(pb, f.name)[0])
             for f in dataclasses.fields(pb)}
    h = encode_partition(params, kge_cfg, part0, features=features)
    # scatter local -> global order
    out = np.zeros((train_kg.num_entities, h.shape[1]), np.float32)
    l2g = np.asarray(part0["local_to_global"])
    mask = np.asarray(part0["vertex_mask"])
    out[l2g[mask]] = np.asarray(h)[mask]
    return out


def evaluate_split(
    params: Dict[str, Any],
    kge_cfg: KGEConfig,
    splits: Dict[str, KnowledgeGraph],
    split: str,
    num_hops: int,
    decoder: str,
    features: Optional[jnp.ndarray] = None,
) -> Dict[str, float]:
    """Filtered MRR / Hits@k on ``split`` (both directions, paper protocol)."""
    emb = encode_all_entities(
        params, kge_cfg, splits["train"].with_inverse_relations(),
        num_hops, features=features)
    table = np.asarray(params["decoder"][DECODER_TABLE_KEY[decoder]])
    metrics = evaluate_both_directions(
        emb, table, splits[split],
        [splits["train"], splits["valid"], splits["test"]],
        num_relations_base=splits["train"].num_relations,
        decoder=decoder,
    )
    return {f"{split}_{k}": v for k, v in metrics.items()}
