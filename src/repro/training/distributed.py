"""Distributed data-parallel training step (paper §2.2, §3.1, Algorithm 1).

The paper's paradigm, mapped to JAX/TPU:

* one trainer per compute unit  →  one partition per slice of the ``data``
  (×``pod``) mesh axis, stacked on the batch leading axis;
* PyTorch DDP + Gloo AllReduce   →  ``shard_map`` + ``jax.lax.pmean`` on
  gradients (lowers to all-reduce over ICI — hardware-native);
* gradient sharing BEFORE the optimizer step (the paper argues this, not
  parameter averaging, preserves mathematical equivalence)  →  grads are
  pmean'd, then one replicated optimizer update.

Two step builders with identical math:

* ``make_spmd_train_step``      — shard_map over a real mesh (pods).
* ``make_simulated_train_step`` — vmap over the trainer axis + mean; runs on
  a single device and is bit-wise the same averaging, used by CPU tests to
  prove distributed == simulated == (for 1 trainer) non-distributed.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# plan-carrying batch keys, defined next to the plan layout: the spmd step
# shards them P(data, model) in its in_specs to match the
# BatchShardings.plan transfer placement (per-device plan blocks arrive
# pre-sliced — no resharding on dispatch)
from repro.sharding.embedding import PLAN_BATCH_KEYS
from repro.training.optimizer import Optimizer, apply_updates

PyTree = Any
# loss_fn(params, batch_slice, key) -> (loss, aux)
LossFn = Callable[[PyTree, Dict[str, jax.Array], jax.Array],
                  Tuple[jax.Array, Dict[str, jax.Array]]]


def make_simulated_train_step(
    loss_fn: LossFn, optimizer: Optimizer, *, donate_batch: bool = False,
) -> Callable:
    """Single-device simulation of P trainers: vmap the per-trainer grad,
    average (== AllReduce), one optimizer step.  Batch pytree has a leading
    trainer axis; keys is (P, 2) PRNG keys.

    ``donate_batch`` donates the batch pytree's buffers to the step (the
    exchange arrays — gather plans, inverse maps — are dead after the step,
    so XLA can reuse their memory for the exchange outputs).  Only enable
    it for streamed batches that are never reused (the trainer keeps it off
    for ``FullGraphPipeline``'s resident batch, and on CPU where donation
    is a no-op that warns)."""

    def grad_one(params, batch, key):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, key)
        return loss, aux, grads

    @functools.partial(
        jax.jit, donate_argnums=(2,) if donate_batch else ())
    def step(params, opt_state, batch, keys):
        loss, aux, grads = jax.vmap(
            grad_one, in_axes=(None, 0, 0))(params, batch, keys)
        grads = jax.tree_util.tree_map(
            lambda g: jnp.mean(g, axis=0), grads)      # AllReduce-average
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": jnp.mean(loss),
                   **{k: jnp.mean(v) for k, v in aux.items()}}
        return params, opt_state, metrics

    return step


def derive_opt_state_specs(opt_state: Any, params: Any,
                           param_specs: Any) -> Any:
    """PartitionSpec tree for an optimizer state, derived from its ACTUAL
    structure (``optimizer.init(params)``): any subtree mirroring the
    params structure (adam's mu/nu moments, SGD's momentum buffer) gets
    ``param_specs`` — moments shard exactly like their parameters — and
    every other leaf (the step counter) stays replicated.  ``None``
    subtrees (plain SGD's missing moments) are empty pytrees and stay
    ``None``, so the spec tree always matches the state the optimizer
    really built — no more hardcoded adam-shaped
    ``OptState(step, mu, nu)`` default that trace-errored for SGD.
    """
    p_struct = jax.tree_util.tree_structure(params)

    def params_like(sub) -> bool:
        return jax.tree_util.tree_structure(sub) == p_struct

    return jax.tree_util.tree_map(
        lambda sub: param_specs if params_like(sub) else P(),
        opt_state, is_leaf=params_like)



def make_spmd_train_step(
    loss_fn: LossFn,
    optimizer: Optimizer,
    mesh: Mesh,
    data_axes: Sequence[str] = ("data",),
    replicate_params_axes: Optional[Sequence[str]] = None,
    param_specs: Optional[Any] = None,
    opt_state_specs: Optional[Any] = None,
    model_axis: Optional[str] = None,
    donate_batch: bool = False,
):
    """shard_map train step over a real mesh.

    Batch arrays are sharded on their leading (trainer) axis over
    ``data_axes`` (e.g. ``("pod", "data")`` on the multi-pod mesh); params
    and optimizer state are replicated across those axes.  Inside the shard
    each trainer computes its gradient on its own partition (self-sufficient:
    no neighbor traffic), then ``pmean`` — the AllReduce of Algorithm 1
    line 8 — averages gradients before the shared optimizer step.

    ``param_specs`` (a PartitionSpec pytree mirroring ``params``, e.g.
    ``repro.sharding.kge_param_specs``) opts individual parameters out of
    replication: a model-axis row-sharded entity table
    (``repro.sharding.embedding``) stays sharded through the step — its
    gradients are shard-local by construction (the exchange's backward
    passes each device's replicated cotangent through once, each shard
    scatter-adds only its own rows), so they are pmean'd over
    ``data_axes`` only, like every other leaf, and the optimizer updates
    each row block in place.  The ``loss_fn`` must perform the shard-local
    gather + exchange itself (pass ``model_axis="model"`` into the model's
    ``vertex_input`` path) and the same ``model_axis`` here.

    Optimizer-state specs are derived from the REAL state structure at the
    first call (``derive_opt_state_specs``): moment trees mirroring the
    params shard like the params, scalars stay replicated, absent moments
    (plain/momentum SGD) stay ``None``.  An explicit ``opt_state_specs``
    tree still overrides.

    With ``model_axis`` set, the gather-plan batch keys
    (``PLAN_BATCH_KEYS``) are sharded ``P(data_axes, model_axis)`` — the
    same placement ``BatchShardings`` transfers them with — so each device
    receives its own pre-sliced ``(1, V_b)`` plan block; every other batch
    leaf (and the keys) shards on the leading trainer axis only.

    ``donate_batch`` donates the streamed batch's buffers (gather plans,
    inverse maps, id arrays are dead after the step — XLA reuses them for
    the exchange outputs); keep it off for resident batches that are
    reused across steps (``FullGraphPipeline``).
    """
    data_axes = tuple(data_axes)
    batch_spec = P(data_axes)      # leading trainer axis sharded
    rep_spec = P()                 # params replicated
    p_spec = rep_spec if param_specs is None else param_specs
    model_size = int(mesh.shape.get(model_axis, 1)) if model_axis else 1
    plan_spec = (P(data_axes, model_axis)
                 if model_axis and model_size > 1 else batch_spec)

    def shard_body(params, opt_state, batch, keys):
        # strip the per-shard leading axis of size trainers/shard (==1 when
        # one partition per data slice; >1 when partitions are grouped)
        def one(params, b, k):
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, b, k)
            return loss, aux, grads

        loss, aux, grads = jax.vmap(one, in_axes=(None, 0, 0))(
            params, batch, keys)
        grads = jax.tree_util.tree_map(lambda g: jnp.mean(g, 0), grads)
        loss = jnp.mean(loss)
        # AllReduce over the trainer axes (and leave other axes alone —
        # model-parallel replicas hold identical grads by construction).
        grads = jax.lax.pmean(grads, axis_name=data_axes)
        loss = jax.lax.pmean(loss, axis_name=data_axes)
        aux = jax.tree_util.tree_map(
            lambda v: jax.lax.pmean(jnp.mean(v), axis_name=data_axes), aux)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, **aux}

    from jax.experimental.shard_map import shard_map

    # the shard_map is built lazily at the first call: the opt-state spec
    # tree needs the REAL state structure and the batch spec tree the REAL
    # key set (plan keys present or not), neither known at build time.
    # Cached per (opt-state structure, batch keys) — stable across steps.
    cache: Dict[Any, Callable] = {}

    def build(params, opt_state, batch):
        if opt_state_specs is not None:
            o_spec = opt_state_specs
        else:
            o_spec = derive_opt_state_specs(opt_state, params, p_spec)
        b_spec = {k: plan_spec if k in PLAN_BATCH_KEYS else batch_spec
                  for k in batch}
        sharded = shard_map(
            shard_body, mesh=mesh,
            in_specs=(p_spec, o_spec, b_spec, batch_spec),
            out_specs=(p_spec, o_spec, rep_spec),
            check_rep=False,
        )
        return jax.jit(sharded,
                       donate_argnums=(2,) if donate_batch else ())

    def _lookup(params, opt_state, batch):
        key = (jax.tree_util.tree_structure(opt_state),
               tuple(sorted(batch)))
        fn = cache.get(key)
        if fn is None:
            cache[key] = fn = build(params, opt_state, batch)
        return fn

    def step(params, opt_state, batch, keys):
        fn = _lookup(params, opt_state, batch)
        return fn(params, opt_state, batch, keys)

    def lower(params, opt_state, batch, keys):
        """``jax.stages.Lowered`` for the same jit the step would run —
        the hook ``repro.analysis.programs`` audits the post-SPMD HLO
        through (compile it and read ``.as_text()`` for the per-device
        module)."""
        return _lookup(params, opt_state, batch).lower(
            params, opt_state, batch, keys)

    step.lower = lower
    return step


def replicate_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh,
                   data_axes: Sequence[str] = ("data",)) -> NamedSharding:
    return NamedSharding(mesh, P(tuple(data_axes)))


def split_trainer_keys(key: jax.Array, num_trainers: int,
                       step: int) -> jax.Array:
    """Per-trainer, per-step PRNG keys (negative sampling & dropout must
    differ across trainers — each samples its own partition)."""
    base = jax.random.fold_in(key, step)
    return jax.random.split(base, num_trainers)
