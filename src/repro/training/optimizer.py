"""Optimizers and LR schedules — pure-pytree, no external deps.

Implements what the paper's stack uses (Adam for RGCN link prediction) plus
AdamW/SGD-momentum for the transformer substrate.  Interface mirrors optax
(init/update returning update pytrees) so components stay composable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree            # first moment (Adam) / momentum (SGD)
    nu: Optional[PyTree]  # second moment (Adam) or None


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree],
                     Tuple[PyTree, OptState]]  # (grads, state, params)


def _zeros_like_tree(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def adam(
    learning_rate: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip_norm: Optional[float] = None,
    state_dtype: Optional[jnp.dtype] = None,
) -> Optimizer:
    """Adam / AdamW.  ``state_dtype`` lets large models keep moments in
    bf16 (halves optimizer HBM — see EXPERIMENTS.md memory analysis)."""

    def lr_at(step):
        return learning_rate(step) if callable(learning_rate) \
            else jnp.asarray(learning_rate)

    def init(params: PyTree) -> OptState:
        cast = (lambda x: jnp.zeros_like(
            x, dtype=state_dtype or x.dtype))
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(cast, params),
            nu=jax.tree_util.tree_map(cast, params),
        )

    def update(grads, state, params):
        if grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr = lr_at(step)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = m2 / bc1
            vhat = v2 / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (-lr * delta).astype(p.dtype), \
                m2.astype(m.dtype), v2.astype(v.dtype)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1] for o in out])
        nu = treedef.unflatten([o[2] for o in out])
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd(learning_rate: float | Callable, momentum: float = 0.0) -> Optimizer:
    def lr_at(step):
        return learning_rate(step) if callable(learning_rate) \
            else jnp.asarray(learning_rate)

    def init(params):
        mu = _zeros_like_tree(params) if momentum else None
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=None)

    def update(grads, state, params):
        step = state.step + 1
        lr = lr_at(step)
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state.mu, grads)
            updates = jax.tree_util.tree_map(lambda m: -lr * m, mu)
        else:
            mu = None
            updates = jax.tree_util.tree_map(lambda g: -lr * g, grads)
        return updates, OptState(step=step, mu=mu, nu=None)

    return Optimizer(init=init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# ---------------------------------------------------------------------- #
# Schedules
# ---------------------------------------------------------------------- #
def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(peak_lr: float, warmup_steps: int,
                           total_steps: int,
                           end_lr: float = 0.0) -> Callable:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = end_lr + 0.5 * (peak_lr - end_lr) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule
