"""End-to-end distributed KGE training driver — paper Algorithm 1 + §4.

The trainer is a thin composition of four seams, each usable on its own:

* ``repro.training.preprocessing``  — partition → expand → pad → budgets;
* ``repro.data.pipeline``           — serial/async host input pipelines
  (``getComputeGraph`` off the device critical path, double-buffered
  host→device transfer);
* ``repro.training.distributed``    — the SPMD step (vmap simulation on CPU,
  shard_map on real meshes; mathematically identical averaging);
* ``repro.training.evaluation``     — full-graph encoding + filtered ranking.

Timing instrumentation mirrors the paper's Fig. 6 component breakdown:
``t_get_compute_graph`` is the host batch-construction time left on the
critical path (== all of it for the serial pipeline; the exposed remainder
for the async pipeline), ``t_host_build`` the total host construction time,
``overlap_fraction`` how much of it the pipeline hid behind the device step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KnowledgeGraph
from repro.data.pipeline import (
    FullGraphPipeline, InputPipeline, make_input_pipeline,
)
from repro.models import (
    KGEConfig, RGCNConfig, fullgraph_loss, init_kge_params, minibatch_loss,
)
from repro.training import optimizer as opt_lib
from repro.training.distributed import (
    make_simulated_train_step, split_trainer_keys,
)
from repro.training.evaluation import encode_all_entities, evaluate_split
from repro.training.preprocessing import PreprocessedGraph, preprocess_graph


@dataclasses.dataclass
class TrainConfig:
    num_trainers: int = 4
    strategy: str = "vertex_cut"        # paper's choice; Table 5 ablations
    num_hops: int = 2                   # == RGCN layers
    hidden_dim: int = 32
    num_bases: int = 2
    num_negatives: int = 1
    batch_size: Optional[int] = None    # None => full edge batch (FB15k-237)
    learning_rate: float = 0.01
    dropout: float = 0.2
    epochs: int = 30
    negative_sampler: str = "constraint"  # "constraint" | "global"
    decoder: str = "distmult"
    seed: int = 0
    use_kernel: bool = False
    eval_every: int = 0                 # 0 => only at end
    pipeline: str = "async"             # "async" | "serial" input pipeline
    prefetch: int = 2                   # per-partition prefetch queue depth
    num_table_shards: int = 1           # >1: row-shard the entity embedding
    #   table over the model axis (repro.sharding.embedding); the pipeline
    #   then emits per-shard gather plans with every batch
    sharded_transfer: bool = False      # transfer batches with per-axis
    #   NamedShardings over a host mesh (data×model): each partition slice
    #   lands on its own data-axis device, each gather-plan block on its
    #   model-axis device.  Values are bitwise identical to the
    #   single-device transfer; on a 1-device mesh the paths coincide.
    gather_dedup: bool = False          # dedupe gather plans per trainer
    #   row in the collator: the embedding exchange then moves each unique
    #   id once and expands on device (bitwise-identical output; wins grow
    #   with id skew).  Mini-batch pipelines only — the full-graph resident
    #   batch is transferred once, so there is nothing to save.
    gather_exchange: Optional[str] = None  # sharded-gather exchange layout
    #   (None = per-path default; see sharding.embedding.sharded_gather)
    table_dtype: str = "fp32"           # "fp32" | "int8" entity-table
    #   storage: int8 keeps an fp32 master for the optimizer but every
    #   gather runs quantize → fused-dequant (int8 codes + fp32 per-row
    #   scales cross the wire under shard_map) — values round to ≤ scale/2,
    #   master grads stay bitwise equal to the fp32 path on the
    #   dequantized table (repro.sharding.embedding)
    spmd: Optional[bool] = None         # run the REAL shard_map step over a
    #   data×model mesh (repro.training.distributed.make_spmd_train_step):
    #   params + adam moments placed with kge_param_specs (the row-sharded
    #   entity table stays sharded through the step), batches routed to
    #   per-device placements via BatchShardings on the same mesh.  None =
    #   auto: on when more than one device exists and the mesh fits
    #   (launch.mesh.fit_spmd_mesh — model axis == num_table_shards, data
    #   axis divides num_trainers); True forces it (1×1 mesh allowed);
    #   False keeps the vmap-simulated step.  Both steps are bitwise
    #   identical (tests/test_distributed.py gates losses == and final
    #   params bitwise on a forced 2-device mesh).


class KGETrainer:
    """Owns the preprocessed data, model params, input pipeline and the
    SPMD step."""

    def __init__(self, splits: Dict[str, KnowledgeGraph], cfg: TrainConfig):
        self.cfg = cfg
        self.splits = splits
        train_kg = splits["train"].with_inverse_relations()
        self.train_kg = train_kg

        feat = train_kg.features
        if cfg.num_table_shards > 1 and feat is not None:
            raise ValueError(
                "num_table_shards > 1 requires learned entity embeddings "
                "(feature-mode models have no table to shard)")
        from repro.sharding.embedding import TABLE_DTYPES
        if cfg.table_dtype not in TABLE_DTYPES:
            raise ValueError(
                f"table_dtype={cfg.table_dtype!r} not in {TABLE_DTYPES}")
        if cfg.table_dtype == "int8" and feat is not None:
            raise ValueError(
                "table_dtype='int8' requires learned entity embeddings "
                "(feature-mode models have no table to quantize)")

        # ---- offline preprocessing (paper §3.2) ----
        self.pre: PreprocessedGraph = preprocess_graph(
            train_kg,
            num_trainers=cfg.num_trainers, strategy=cfg.strategy,
            num_hops=cfg.num_hops, seed=cfg.seed,
            batch_size=cfg.batch_size, num_negatives=cfg.num_negatives,
            sampler=cfg.negative_sampler,
            num_table_shards=cfg.num_table_shards,
        )

        # ---- model ----
        self.kge_cfg = KGEConfig(
            rgcn=RGCNConfig(
                num_entities=train_kg.num_entities,
                num_relations=train_kg.num_relations,
                hidden_dim=cfg.hidden_dim,
                num_layers=cfg.num_hops,
                num_bases=cfg.num_bases,
                feature_dim=None if feat is None else feat.shape[1],
                dropout=cfg.dropout,
                use_kernel=cfg.use_kernel,
                num_table_shards=cfg.num_table_shards,
                gather_exchange=cfg.gather_exchange,
                table_dtype=cfg.table_dtype,
            ),
            decoder=cfg.decoder,
            num_negatives=cfg.num_negatives,
            negative_sampler=cfg.negative_sampler,
        )
        key = jax.random.PRNGKey(cfg.seed)
        self.params = init_kge_params(key, self.kge_cfg)
        self.features = None if feat is None else jnp.asarray(feat)

        optimizer = opt_lib.adam(cfg.learning_rate)
        self.optimizer = optimizer
        self.opt_state = optimizer.init(self.params)
        self._key = jax.random.PRNGKey(cfg.seed + 1)
        self._epoch = 0
        self.timings: List[Dict[str, float]] = []

        # ---- mesh + step selection (simulated vmap vs real shard_map) ----
        self._fullgraph = cfg.batch_size is None
        self.mesh = None
        self._spmd = self._resolve_spmd()
        self._model_axis = "model" if self._spmd else None
        self._validate_exchange()

        if self._spmd:
            from repro.launch.mesh import fit_spmd_mesh, make_host_mesh
            self.mesh = make_host_mesh(*fit_spmd_mesh(
                cfg.num_trainers, cfg.num_table_shards))
            self._place_state()

        # ---- input pipeline ----
        if self._spmd:
            # spmd batches always transfer with mesh-aware placements:
            # partition slices over the data axis, gather-plan blocks over
            # the model axis — the step's in_specs, so no resharding
            from repro.data.pipeline import BatchShardings
            shardings = BatchShardings(self.mesh)
        elif cfg.sharded_transfer:
            shardings = self._make_batch_shardings()
        else:
            shardings = None
        # full-graph: the resident batch is reused every epoch, so its
        # buffers must NOT be donated (and there is nothing to dedup).
        # mini-batch: streamed batches die after their step — donate their
        # buffers to the exchange (no-op with a warning on CPU, so gate it)
        donate = not self._fullgraph and jax.default_backend() != "cpu"
        loss = self._fullgraph_loss if self._fullgraph \
            else self._minibatch_loss
        if self._spmd:
            from repro.training.distributed import make_spmd_train_step
            self._step = make_spmd_train_step(
                loss, optimizer, self.mesh,
                param_specs=self._param_specs,
                model_axis="model", donate_batch=donate)
        else:
            self._step = make_simulated_train_step(
                loss, optimizer, donate_batch=donate)
        if self._fullgraph:
            self.pipeline: InputPipeline = FullGraphPipeline(
                self.pre.padded, table_layout=self.pre.table_layout,
                shardings=shardings)
        else:
            self.pipeline = make_input_pipeline(
                cfg.pipeline, self.pre.partitions,
                batch_size=cfg.batch_size,
                num_negatives=cfg.num_negatives,
                num_hops=cfg.num_hops,
                budget=self.pre.budget,
                seed=cfg.seed,
                sampler=cfg.negative_sampler,
                csrs=self.pre.csrs,
                prefetch=cfg.prefetch,
                table_layout=self.pre.table_layout,
                shardings=shardings,
                dedup_gather=cfg.gather_dedup,
            )

    def _resolve_spmd(self) -> bool:
        """``cfg.spmd`` tri-state: explicit True/False wins (True validates
        the mesh fits and raises otherwise); None auto-enables the real
        shard_map step exactly when it buys parallelism — more than one
        local device AND the mesh fits (``fit_spmd_mesh``)."""
        from repro.launch.mesh import fit_spmd_mesh
        cfg = self.cfg
        fit = fit_spmd_mesh(cfg.num_trainers, cfg.num_table_shards)
        if cfg.spmd is None:
            return fit is not None and fit[0] * fit[1] > 1
        if cfg.spmd and fit is None:
            raise ValueError(
                f"spmd=True needs {cfg.num_table_shards} model-axis "
                f"devices for {cfg.num_table_shards} table shards but "
                f"only {jax.device_count()} devices exist")
        return bool(cfg.spmd)

    def _validate_exchange(self) -> None:
        """Fail fast on an exchange layout the selected step can't run:
        the vmap simulation implements ``SIM_EXCHANGES``, the shard_map
        step the collective ``SPMD_EXCHANGES`` — ``None`` always resolves
        to the right per-path default."""
        from repro.sharding.embedding import SIM_EXCHANGES, SPMD_EXCHANGES
        ex = self.cfg.gather_exchange
        allowed = SPMD_EXCHANGES if self._spmd else SIM_EXCHANGES
        if ex is not None and ex not in allowed:
            kind = "spmd" if self._spmd else "simulated"
            raise ValueError(
                f"gather_exchange={ex!r} is not available on the {kind} "
                f"step (one of {allowed}); leave it None for the default")

    def _place_state(self) -> None:
        """Place params and optimizer state on the mesh BEFORE the first
        step: the row-sharded entity table (and its adam moments) start —
        and stay — distributed with ``kge_param_specs`` instead of being
        resharded out of a replicated copy on the first dispatch."""
        from repro.sharding import kge_param_specs, tree_named_shardings
        from repro.training.distributed import derive_opt_state_specs
        self._param_specs = kge_param_specs(self.params, self.mesh)
        self._opt_specs = derive_opt_state_specs(
            self.opt_state, self.params, self._param_specs)
        self.params = jax.device_put(
            self.params, tree_named_shardings(self._param_specs, self.mesh))
        self.opt_state = jax.device_put(
            self.opt_state, tree_named_shardings(self._opt_specs, self.mesh))

    def _make_batch_shardings(self):
        """Mesh-aware transfer placements for ``cfg.sharded_transfer``: the
        data×model host mesh using the MOST local devices such that the
        ``data`` axis divides the trainer count and the ``model`` axis the
        table shard count (ties prefer the data axis — trainer slices
        dominate transfer bytes; 1×1, the bitwise-identical degenerate
        case, when only one device exists)."""
        from repro.data.pipeline import BatchShardings
        from repro.launch.mesh import make_host_mesh
        cfg = self.cfg
        ndev = jax.device_count()
        data, model = max(
            ((d, m) for d in range(1, ndev + 1)
             if cfg.num_trainers % d == 0
             for m in range(1, ndev // d + 1)
             if cfg.num_table_shards % m == 0),
            key=lambda dm: (dm[0] * dm[1], dm[0]))
        return BatchShardings(make_host_mesh(data, model))

    # ------------------------------------------------------------------ #
    # preprocessing artifacts (stable public surface)
    # ------------------------------------------------------------------ #
    @property
    def partitions(self):
        return self.pre.partitions

    @property
    def padded(self):
        return self.pre.padded

    @property
    def replication_factor(self) -> float:
        return self.pre.replication_factor

    @property
    def budget(self):
        return self.pre.budget

    # ------------------------------------------------------------------ #
    def _fullgraph_loss(self, params, batch, key):
        return fullgraph_loss(params, self.kge_cfg, batch, key,
                              features=self.features, train=True,
                              model_axis=self._model_axis)

    def _minibatch_loss(self, params, batch, key):
        return minibatch_loss(params, self.kge_cfg, batch,
                              features=self.features, dropout_key=key,
                              model_axis=self._model_axis)

    # ------------------------------------------------------------------ #
    def lower_step(self, batch=None):
        """``jax.stages.Lowered`` of the trainer's jitted train step for
        one real pipeline batch — the entry point the SPMD contract
        auditor (``repro.analysis.programs``) lowers each production
        configuration through.  ``batch`` defaults to the pipeline's
        first batch of the next epoch; compile the result and read
        ``.as_text()`` for the post-optimization per-device module."""
        if batch is None:
            it = self.pipeline.device_batches(self._epoch + 1)
            batch = next(iter(it))
            close = getattr(it, "close", None)
            if close is not None:
                close()
        keys = split_trainer_keys(self._key, self.cfg.num_trainers,
                                  self._epoch + 1)
        if not self._fullgraph:
            keys = jax.vmap(jax.random.fold_in, (0, None))(keys, 0)
        return self._step.lower(self.params, self.opt_state, batch, keys)

    # ------------------------------------------------------------------ #
    def train_epoch(self) -> Dict[str, float]:
        cfg = self.cfg
        self._epoch += 1
        t_device = 0.0
        losses = []
        keys = split_trainer_keys(self._key, cfg.num_trainers, self._epoch)

        nbatches = 0
        for batch in self.pipeline.device_batches(self._epoch):
            if self._fullgraph:
                skeys = keys     # one update per epoch; keys already fresh
            else:
                skeys = jax.vmap(jax.random.fold_in, (0, None))(
                    keys, nbatches)
            t0 = time.perf_counter()
            self.params, self.opt_state, m = self._step(
                self.params, self.opt_state, batch, skeys)
            jax.block_until_ready(m["loss"])
            t_device += time.perf_counter() - t0
            losses.append(float(m["loss"]))
            nbatches += 1

        stats = self.pipeline.last_stats
        rec = {
            "epoch": self._epoch,
            "loss": float(np.mean(losses)) if losses else float("nan"),
            "t_get_compute_graph": stats.exposed_wait_s,
            "t_host_build": stats.host_build_s,
            "t_warmup": stats.warmup_s,
            "overlap_fraction": stats.overlap_fraction(),
            "t_device_step": t_device,
            "t_epoch": stats.warmup_s + stats.exposed_wait_s + t_device,
            "num_batches": nbatches,
        }
        self.timings.append(rec)
        return rec

    def fit(self, epochs: Optional[int] = None,
            log_fn=None) -> List[Dict[str, float]]:
        history = []
        for _ in range(epochs or self.cfg.epochs):
            rec = self.train_epoch()
            if self.cfg.eval_every and \
                    self._epoch % self.cfg.eval_every == 0:
                rec.update(self.evaluate("valid"))
            history.append(rec)
            if log_fn:
                log_fn(rec)
        return history

    def close(self) -> None:
        self.pipeline.close()

    # ------------------------------------------------------------------ #
    # checkpointing: params + optimizer state + the TRAINER-side state
    # (epoch counter, PRNG key) that the per-epoch key schedule
    # (``split_trainer_keys(key, P, epoch)``) depends on — without both, a
    # resumed run silently restarts the negative-sampling / dropout RNG
    # stream at epoch 1 and diverges from the uninterrupted run.
    # ------------------------------------------------------------------ #
    def save_checkpoint(self, directory: str, keep: int = 3) -> str:
        """One checkpoint per call, stamped with the current epoch; the
        manifest ``metadata`` carries ``epoch`` and the raw PRNG ``key``
        so ``restore`` continues the exact RNG stream."""
        from repro.training.checkpoint import save_checkpoint
        tree = {"params": self.params, "opt": self.opt_state}
        meta = {
            "epoch": int(self._epoch),
            "key": np.asarray(self._key, dtype=np.uint32).tolist(),
        }
        return save_checkpoint(directory, self._epoch, tree,
                               metadata=meta, keep=keep)

    def restore(self, path: str) -> int:
        """Resume from ``save_checkpoint`` output: restores params +
        optimizer state (entity tables convert across storage layouts and
        shard counts — checkpoints are layout-portable), then the epoch
        counter and PRNG key from the manifest metadata, so the next
        ``train_epoch`` draws the SAME keys the uninterrupted run would
        have.  Under spmd the restored (host) arrays are re-placed on the
        mesh.  Returns the restored epoch."""
        from repro.training.checkpoint import read_metadata, \
            restore_checkpoint
        like = {"params": self.params, "opt": self.opt_state}
        step, tree = restore_checkpoint(
            path, like, entity_rows=self.train_kg.num_entities)
        self.params, self.opt_state = tree["params"], tree["opt"]
        _, meta = read_metadata(path)
        self._epoch = int(meta.get("epoch", step))
        if "key" in meta:
            self._key = jnp.asarray(np.asarray(meta["key"],
                                               dtype=np.uint32))
        if self._spmd:
            self._place_state()
        return self._epoch

    # ------------------------------------------------------------------ #
    def encode_all_entities(self) -> np.ndarray:
        """Evaluation-time encoder pass: stream ``encode_partition`` over
        the TRAINING partitions (reusing ``self.pre`` — no re-partitioning)
        and scatter each partition's core vertices into the global matrix."""
        return encode_all_entities(
            self.params, self.kge_cfg, self.train_kg, self.cfg.num_hops,
            features=self.features, partitions=self.pre.partitions,
            padded=self.pre.padded)

    def evaluate(self, split: str = "test") -> Dict[str, float]:
        """Filtered MRR / Hits@k through the scaled eval subsystem: streamed
        partition encoding + (with ``num_table_shards > 1``) candidate-axis-
        sharded ranking over the row-sharded entity table."""
        return evaluate_split(
            self.params, self.kge_cfg, self.splits, split,
            self.cfg.num_hops, self.cfg.decoder, features=self.features,
            partitions=self.pre.partitions, padded=self.pre.padded)
