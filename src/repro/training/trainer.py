"""End-to-end distributed KGE training driver — paper Algorithm 1 + §4.

Pipeline: partition → neighborhood-expand → pad → per-epoch (negative
sampling → edge mini-batches → grad → AllReduce-average → update) → filtered
evaluation.  Runs the simulated-trainer step on CPU (mathematically identical
averaging to the shard_map step used on real meshes — see
``repro.training.distributed``).

Timing instrumentation mirrors the paper's Fig. 6 component breakdown:
``getComputeGraph`` (host mini-batch construction), ``GNNmodel+loss+backward+
step`` (the fused device step — XLA fuses what PyTorch runs as three separate
phases), reported per epoch by the benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BatchBudget, KnowledgeGraph, expand_all, iterate_edge_minibatches,
    pad_partitions, partition_graph, plan_budgets, stack_minibatches,
    replication_factor,
)
from repro.core.minibatch import _PartitionCSR
from repro.eval.ranking import evaluate_both_directions
from repro.models import (
    KGEConfig, RGCNConfig, encode_partition, fullgraph_loss, init_kge_params,
    minibatch_loss,
)
from repro.training import optimizer as opt_lib
from repro.training.distributed import (
    make_simulated_train_step, split_trainer_keys,
)


@dataclasses.dataclass
class TrainConfig:
    num_trainers: int = 4
    strategy: str = "vertex_cut"        # paper's choice; Table 5 ablations
    num_hops: int = 2                   # == RGCN layers
    hidden_dim: int = 32
    num_bases: int = 2
    num_negatives: int = 1
    batch_size: Optional[int] = None    # None => full edge batch (FB15k-237)
    learning_rate: float = 0.01
    dropout: float = 0.2
    epochs: int = 30
    negative_sampler: str = "constraint"  # "constraint" | "global"
    decoder: str = "distmult"
    seed: int = 0
    use_kernel: bool = False
    eval_every: int = 0                 # 0 => only at end


class KGETrainer:
    """Owns the partitioned data, model params and the SPMD step."""

    def __init__(self, splits: Dict[str, KnowledgeGraph], cfg: TrainConfig):
        self.cfg = cfg
        self.splits = splits
        train_kg = splits["train"].with_inverse_relations()
        self.train_kg = train_kg

        # ---- offline preprocessing (paper §3.2) ----
        parts = partition_graph(
            train_kg, cfg.num_trainers, cfg.strategy, seed=cfg.seed)
        self.partitions = expand_all(train_kg, parts, cfg.num_hops)
        self.padded = pad_partitions(self.partitions)
        self.replication_factor = replication_factor(train_kg, parts)

        # ---- model ----
        feat = train_kg.features
        self.kge_cfg = KGEConfig(
            rgcn=RGCNConfig(
                num_entities=train_kg.num_entities,
                num_relations=train_kg.num_relations,
                hidden_dim=cfg.hidden_dim,
                num_layers=cfg.num_hops,
                num_bases=cfg.num_bases,
                feature_dim=None if feat is None else feat.shape[1],
                dropout=cfg.dropout,
                use_kernel=cfg.use_kernel,
            ),
            decoder=cfg.decoder,
            num_negatives=cfg.num_negatives,
            negative_sampler=cfg.negative_sampler,
        )
        key = jax.random.PRNGKey(cfg.seed)
        self.params = init_kge_params(key, self.kge_cfg)
        self.features = None if feat is None else jnp.asarray(feat)

        optimizer = opt_lib.adam(cfg.learning_rate)
        self.optimizer = optimizer
        self.opt_state = optimizer.init(self.params)
        self._key = jax.random.PRNGKey(cfg.seed + 1)
        self._epoch = 0
        self.timings: List[Dict[str, float]] = []

        if cfg.batch_size is None:
            self._step = make_simulated_train_step(
                self._fullgraph_loss, optimizer)
            self._device_parts = {
                f.name: jnp.asarray(getattr(self.padded, f.name))
                for f in dataclasses.fields(self.padded)
            }
        else:
            self._step = make_simulated_train_step(
                self._minibatch_loss, optimizer)
            self.budget: BatchBudget = plan_budgets(
                self.partitions, cfg.batch_size, cfg.num_negatives,
                cfg.num_hops, seed=cfg.seed)
            self._csrs = [_PartitionCSR(p) for p in self.partitions]

    # ------------------------------------------------------------------ #
    def _fullgraph_loss(self, params, batch, key):
        return fullgraph_loss(params, self.kge_cfg, batch, key,
                              features=self.features, train=True)

    def _minibatch_loss(self, params, batch, key):
        return minibatch_loss(params, self.kge_cfg, batch,
                              features=self.features, dropout_key=key)

    # ------------------------------------------------------------------ #
    def train_epoch(self) -> Dict[str, float]:
        cfg = self.cfg
        self._epoch += 1
        t_host = 0.0
        t_device = 0.0
        losses = []
        keys = split_trainer_keys(self._key, cfg.num_trainers, self._epoch)

        if cfg.batch_size is None:
            # full edge batch: one model update per epoch (paper FB15k-237)
            t0 = time.perf_counter()
            self.params, self.opt_state, m = self._step(
                self.params, self.opt_state, self._device_parts, keys)
            jax.block_until_ready(m["loss"])
            t_device += time.perf_counter() - t0
            losses.append(float(m["loss"]))
            nbatches = 1
        else:
            rngs = [np.random.default_rng(
                hash((cfg.seed, self._epoch, i)) % (2 ** 31))
                for i in range(cfg.num_trainers)]
            iters = [
                iterate_edge_minibatches(
                    rngs[i], self.partitions[i], cfg.batch_size,
                    cfg.num_negatives, cfg.num_hops, self.budget,
                    self._csrs[i])
                for i in range(cfg.num_trainers)
            ]
            nbatches = 0
            while True:
                t0 = time.perf_counter()
                try:
                    mbs = [next(it) for it in iters]   # getComputeGraph
                except StopIteration:
                    break
                t_host += time.perf_counter() - t0
                stacked = stack_minibatches(mbs)
                batch = {k: jnp.asarray(v) for k, v in
                         dataclasses.asdict(stacked).items()}
                skeys = jax.vmap(jax.random.fold_in, (0, None))(
                    keys, nbatches)
                t0 = time.perf_counter()
                self.params, self.opt_state, m = self._step(
                    self.params, self.opt_state, batch, skeys)
                jax.block_until_ready(m["loss"])
                t_device += time.perf_counter() - t0
                losses.append(float(m["loss"]))
                nbatches += 1

        rec = {
            "epoch": self._epoch,
            "loss": float(np.mean(losses)) if losses else float("nan"),
            "t_get_compute_graph": t_host,
            "t_device_step": t_device,
            "t_epoch": t_host + t_device,
            "num_batches": nbatches,
        }
        self.timings.append(rec)
        return rec

    def fit(self, epochs: Optional[int] = None,
            log_fn=None) -> List[Dict[str, float]]:
        history = []
        for _ in range(epochs or self.cfg.epochs):
            rec = self.train_epoch()
            if self.cfg.eval_every and \
                    self._epoch % self.cfg.eval_every == 0:
                rec.update(self.evaluate("valid"))
            history.append(rec)
            if log_fn:
                log_fn(rec)
        return history

    # ------------------------------------------------------------------ #
    def encode_all_entities(self) -> np.ndarray:
        """Embed every entity with the full (unpartitioned) train graph —
        the evaluation-time encoder pass."""
        full = partition_graph(self.train_kg, 1, "random", seed=0)
        full_part = expand_all(self.train_kg, full, self.cfg.num_hops)
        pb = pad_partitions(full_part)
        part0 = {f.name: jnp.asarray(getattr(pb, f.name)[0])
                 for f in dataclasses.fields(pb)}
        h = encode_partition(self.params, self.kge_cfg, part0,
                             features=self.features)
        # scatter local -> global order
        out = np.zeros((self.train_kg.num_entities, h.shape[1]), np.float32)
        l2g = np.asarray(part0["local_to_global"])
        mask = np.asarray(part0["vertex_mask"])
        out[l2g[mask]] = np.asarray(h)[mask]
        return out

    def evaluate(self, split: str = "test") -> Dict[str, float]:
        emb = self.encode_all_entities()
        table_key = {"distmult": "rel_diag", "transe": "rel_vec",
                     "complex": "rel_complex"}[self.cfg.decoder]
        table = np.asarray(self.params["decoder"][table_key])
        metrics = evaluate_both_directions(
            emb, table, self.splits[split],
            [self.splits["train"], self.splits["valid"],
             self.splits["test"]],
            num_relations_base=self.splits["train"].num_relations,
            decoder=self.cfg.decoder,
        )
        return {f"{split}_{k}": v for k, v in metrics.items()}
