"""Training driver.

Two families behind one CLI (the framework's two model families share the
distributed runtime — DESIGN.md §4):

* ``--arch rgcn-fb15k237`` / ``rgcn-citation2`` — the paper's distributed
  KGE training (partition → expand → edge mini-batch → AllReduce), at a
  ``--scale`` that fits the local machine; real FB15k-237 files are used
  when ``--data-root`` points at them.
* ``--arch <assigned-arch>`` — reduced-config LM training on the synthetic
  token stream (exercises the same train_step the dry-run lowers at
  production scale).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch rgcn-fb15k237 \
      --trainers 4 --epochs 20
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 50
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_kge(args) -> None:
    from repro.data import load_or_synthesize
    from repro.training import KGETrainer
    from repro.configs import RGCN_FB15K237, RGCN_CITATION2

    name = "fb15k-237" if args.arch == "rgcn-fb15k237" else "ogbl-citation2"
    base = RGCN_FB15K237 if name == "fb15k-237" else RGCN_CITATION2
    splits = load_or_synthesize(name, data_root=args.data_root,
                                scale=args.scale)
    cfg = dataclasses.replace(
        base, num_trainers=args.trainers, epochs=args.epochs,
        batch_size=args.batch_size if args.batch_size > 0 else
        (None if name == "fb15k-237" else 4096),
        strategy=args.strategy, use_kernel=args.use_kernel,
        pipeline=args.pipeline, prefetch=args.prefetch,
        num_table_shards=args.table_shards,
        sharded_transfer=args.sharded_transfer,
        gather_dedup=args.gather_dedup,
        gather_exchange=args.gather_exchange,
        table_dtype=args.table_dtype,
        spmd=args.spmd,
        decoder=args.decoder, num_negatives=args.num_negatives,
        **({"hidden_dim": args.hidden_dim} if args.hidden_dim > 0 else {}))
    pipe = ("full-graph (resident batch)" if cfg.batch_size is None
            else f"{cfg.pipeline} pipeline")   # --pipeline/--prefetch only
    #                                            drive the mini-batch path
    xfer = ", sharded transfer" if cfg.sharded_transfer else ""
    xfer += ", deduped gather" if cfg.gather_dedup else ""
    if cfg.gather_exchange:
        xfer += f", {cfg.gather_exchange} exchange"
    if cfg.table_dtype != "fp32":
        xfer += f", {cfg.table_dtype} table"
    print(f"[train] {name}: {splits['train'].num_edges} train edges, "
          f"{splits['train'].num_entities} entities; "
          f"{cfg.decoder} decoder, {cfg.num_negatives} negatives/edge; "
          f"{cfg.num_trainers} trainers ({cfg.strategy}, {pipe}{xfer}, "
          f"{cfg.num_table_shards}-shard entity table)")
    trainer = KGETrainer(splits, cfg)
    if trainer.mesh is not None:
        print(f"[train] spmd shard_map step on a "
              f"{dict(trainer.mesh.shape)} mesh "
              f"({jax.device_count()} local devices)")
    else:
        print(f"[train] simulated (vmap) step"
              + (" — --spmd forced off" if cfg.spmd is False else
                 f" — mesh does not fit {jax.device_count()} device(s)"))
    print(f"[train] RF={trainer.replication_factor:.2f}")
    trainer.fit(log_fn=lambda r: print(
        f"  epoch {r['epoch']:3d} loss={r['loss']:.4f} "
        f"t={r['t_epoch']:.2f}s (host exposed "
        f"{r['t_get_compute_graph']:.2f}s of {r['t_host_build']:.2f}s, "
        f"overlap {r['overlap_fraction']:.0%})"))
    # eval reuses the training partitions (streamed encoder) and, with
    # --table-shards > 1, ranks candidate-axis-sharded over the row blocks
    t0 = time.perf_counter()
    metrics = trainer.evaluate("test")
    rank_mode = (f"{cfg.num_table_shards}-shard ranking"
                 if cfg.num_table_shards > 1 else "dense ranking")
    print(f"[eval] {cfg.decoder} decoder, {rank_mode}, "
          f"{len(trainer.partitions)}-partition "
          f"streamed encode, {time.perf_counter() - t0:.2f}s")
    print("[eval]", metrics)


def train_lm(args) -> None:
    from repro.configs import get_arch
    from repro.data import TokenStream
    from repro.launch.steps import make_train_step
    from repro.nn import init_params
    from repro.training.optimizer import adam

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    optimizer = adam(args.lr)
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(cfg, optimizer), donate_argnums=(0, 1))
    stream = TokenStream(cfg.vocab_size, args.batch, args.seq)
    print(f"[train] {cfg.name}: "
          f"{sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(params)):,.0f} params")
    it = iter(stream)
    for i in range(args.steps):
        raw = next(it)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.arch_type == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, args.seq, cfg.vision_dim), jnp.float32)
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, :, None],
                (args.batch, args.seq, 3)).astype(jnp.int32)
        if cfg.arch_type == "encdec":
            batch["audio_frames"] = jnp.zeros(
                (args.batch, cfg.encoder_frames, cfg.d_model), jnp.float32)
        t0 = time.perf_counter()
        params, opt_state, metrics = step(params, opt_state, batch)
        loss = float(metrics["loss"])
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"  step {i:4d} loss={loss:.4f} "
                  f"({time.perf_counter() - t0:.2f}s)")
    assert np.isfinite(loss), "training diverged"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--trainers", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=-1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--strategy", default="vertex_cut")
    ap.add_argument("--pipeline", default="async",
                    choices=("async", "serial"),
                    help="host input pipeline for mini-batch training")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="per-partition prefetch queue depth")
    ap.add_argument("--table-shards", type=int, default=1,
                    help="row-shard the entity embedding table over this "
                         "many model-axis shards (1 = replicated)")
    ap.add_argument("--sharded-transfer", action="store_true",
                    help="transfer batches with per-axis NamedShardings "
                         "over a data x model host mesh (each partition "
                         "slice to its own data-axis device, gather-plan "
                         "blocks to model-axis devices); bitwise identical "
                         "to the single-device transfer")
    ap.add_argument("--gather-dedup", action="store_true",
                    help="dedupe sharded-gather plans per trainer row in "
                         "the collator (exchange each unique id once, "
                         "expand on device; bitwise-identical output)")
    ap.add_argument("--spmd", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="run the REAL shard_map train step over a "
                         "data x model device mesh (params + adam moments "
                         "placed with kge_param_specs; the row-sharded "
                         "entity table stays distributed through the "
                         "step).  Default: auto — on when >1 device "
                         "exists and the mesh fits (model axis == "
                         "--table-shards, data axis divides --trainers); "
                         "--no-spmd keeps the vmap-simulated step.  Both "
                         "are bitwise identical")
    ap.add_argument("--gather-exchange", default=None,
                    choices=("fused", "masked_sum", "psum", "psum_scatter",
                             "alltoall"),
                    help="sharded-gather exchange layout (default: fused "
                         "on the sim path, psum_scatter under shard_map; "
                         "all layouts are bitwise equal)")
    ap.add_argument("--table-dtype", default="fp32",
                    choices=("fp32", "int8"),
                    help="entity-table storage: int8 stores row-wise "
                         "symmetric codes + fp32 per-row scales "
                         "(~0.27x the fp32 bytes at d=64) with dequant "
                         "fused into the gather; the optimizer keeps an "
                         "fp32 master, so training dynamics match the "
                         "fp32 path on the dequantized table")
    from repro.models.decoders import registered_decoders
    ap.add_argument("--decoder", default="distmult",
                    choices=registered_decoders(),
                    help="KGE scoring function (registry-resolved; the "
                         "paper trains distmult)")
    ap.add_argument("--num-negatives", type=int, default=1,
                    help="negative samples per positive edge (paper: 1)")
    ap.add_argument("--hidden-dim", type=int, default=-1,
                    help="override the arch config's hidden dim (complex/"
                         "rotate need an even dim; fb15k-237's paper dim "
                         "is 75)")
    ap.add_argument("--data-root", default=None)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()
    if args.arch.startswith("rgcn-"):
        train_kge(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
