"""Production mesh construction.

Functions, not module-level constants, so importing never touches jax device
state.  Single pod: 16×16 = 256 chips (``data`` × ``model``); multi-pod:
2×16×16 = 512 chips with a leading ``pod`` axis (data-parallel across pods —
the slowest links carry only gradient AllReduce, exactly the paper's
cross-machine traffic profile).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 explicit-sharding API; older jax has no AxisType
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes, devices) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(
        shape, axes,
        axis_types=(AxisType.Auto,) * len(axes),
        devices=devices)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = math.prod(shape)
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices, have {len(devices)} — "
            "the dry-run entry point must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return _make_mesh(shape, axes, devices[:ndev])


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever local devices exist (tests / examples)."""
    devices = jax.devices()[: data * model]
    return _make_mesh((data, model), ("data", "model"), devices)


def fit_spmd_mesh(num_trainers: int, num_table_shards: int,
                  ndev: "int | None" = None) -> "tuple[int, int] | None":
    """``(data, model)`` shape for the trainer's spmd step, or ``None``
    when the local devices cannot host it.

    The ``model`` axis must be EXACTLY ``num_table_shards`` — the
    row-sharded entity table places one ``(rows, d)`` block per model-axis
    device (``kge_param_specs`` enforces ``S == mesh.shape['model']``); a
    dense table (``num_table_shards == 1``) means a 1-wide model axis.
    The ``data`` axis is the largest divisor of ``num_trainers`` that fits
    the remaining devices (partitions must split evenly over it —
    ``BatchShardings.check``).  The same rule drives ``--spmd`` auto-on in
    the CLI and ``TrainConfig.spmd=None`` auto-detection.
    """
    ndev = jax.device_count() if ndev is None else ndev
    model = max(num_table_shards, 1)
    if model > ndev:
        return None
    data = max(d for d in range(1, ndev // model + 1)
               if num_trainers % d == 0)
    return data, model


# TPU v5e hardware constants used by the roofline (EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
