"""KGE serving driver: the sharded top-k engine under a request stream.

Stands up a :class:`repro.serving.ShardedKGEServer` (synthetic entity table
+ decoder params, or a table trained in-process with ``--train-epochs``),
wraps it in the dynamic-batching :class:`repro.serving.KGEServeEngine`, and
drives a Zipf-skewed query stream through it — printing p50/p99 request
latency and QPS, plus the sharded == dense top-k equality check the
subsystem is contracted on (``docs/serving.md``).

Examples:
  PYTHONPATH=src python -m repro.launch.serve --table-shards 4 --topk 10
  PYTHONPATH=src python -m repro.launch.serve --decoder rotate \
      --filtered --cache-size 256 --requests 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_server(args):
    from repro.core.graph import KnowledgeGraph
    from repro.eval.ranking import CSRFilterIndex
    from repro.models.decoders import init_decoder_params
    from repro.serving import ShardedKGEServer

    rng = np.random.default_rng(args.seed)
    emb = rng.normal(scale=0.1, size=(args.entities, args.dim)
                     ).astype(np.float32)
    params = init_decoder_params(jax.random.PRNGKey(args.seed),
                                 args.decoder, args.relations, args.dim)
    filter_index = None
    if args.filtered:
        e = max(args.entities * 4, 64)   # synthetic known-triplet store
        g = KnowledgeGraph(src=rng.integers(0, args.entities, e),
                           rel=rng.integers(0, args.relations, e),
                           dst=rng.integers(0, args.entities, e),
                           num_entities=args.entities,
                           num_relations=args.relations)
        filter_index = CSRFilterIndex.build([g])
    server = ShardedKGEServer(
        emb, params, args.decoder, num_shards=args.table_shards,
        filter_index=filter_index, cache_size=args.cache_size,
        table_dtype=args.table_dtype)
    return server, emb, params


def check_equal_dense(server, emb, params, args) -> bool:
    """The serving contract: sharded top-k == dense ``jax.lax.top_k``
    (over the dequantized table for ``--table-dtype int8`` — dequant is
    an exact pow2 multiply, so equality stays exact)."""
    from repro.models.decoders import score_against_candidates

    if args.table_dtype == "int8":
        from repro.sharding.embedding import dequantize_rows, quantize_rows
        emb = np.asarray(dequantize_rows(*quantize_rows(emb)))
    rng = np.random.default_rng(args.seed + 1)
    heads = rng.integers(0, args.entities, args.slots)
    rels = rng.integers(0, args.relations, args.slots)
    k = min(args.topk, args.entities)
    dense = score_against_candidates(
        params, args.decoder, jnp.asarray(emb[heads]),
        jnp.asarray(rels.astype(np.int32)), jnp.asarray(emb))
    _, want = jax.lax.top_k(dense, k)
    _, got = server.topk_tails(heads, rels, k)
    return bool((got == np.asarray(want)).all())


def main() -> None:
    from repro.models.decoders import registered_decoders

    ap = argparse.ArgumentParser()
    ap.add_argument("--entities", type=int, default=5000)
    ap.add_argument("--relations", type=int, default=16)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--decoder", default="distmult",
                    choices=registered_decoders())
    ap.add_argument("--table-shards", type=int, default=1,
                    help="row-shard the entity table over this many "
                         "candidate-axis shards (the (B, N) score matrix "
                         "is never materialized for any value)")
    ap.add_argument("--topk", type=int, default=10,
                    help="engine-wide max k (per-request k is clamped to "
                         "it; one jitted step shape)")
    ap.add_argument("--slots", type=int, default=8,
                    help="dynamic-batching width — requests per step")
    ap.add_argument("--policy", default="fifo",
                    choices=("fifo", "smallest-k-first"),
                    help="admission policy (smallest-k-first decouples "
                         "completion from submission order)")
    ap.add_argument("--filtered", action="store_true",
                    help="filter known tails via the column-range "
                         "CSRFilterIndex bias (serving sentinel t=-1)")
    ap.add_argument("--table-dtype", default="fp32",
                    choices=("fp32", "int8"),
                    help="entity-table storage: int8 keeps only row-wise "
                         "symmetric codes + fp32 pow2 scales on device "
                         "(~0.27x bytes at d=64) and fuses the dequant "
                         "into the top-k program")
    ap.add_argument("--cache-size", type=int, default=0,
                    help="hot-entity head-embedding LRU entries "
                         "(0 disables; bits never change)")
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--zipf", type=float, default=1.3,
                    help="head-entity skew of the query stream (serving "
                         "traffic is hot-entity heavy; drives the cache)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.serving import KGEServeEngine

    server, emb, params = build_server(args)
    engine = KGEServeEngine(server, slots=args.slots, max_k=args.topk,
                            filtered=args.filtered, policy=args.policy)
    print(f"[serve] {args.decoder} over {args.entities} entities, "
          f"{args.table_shards}-shard table "
          f"(rows/shard={server.layout.rows_per_shard}), "
          f"slots={args.slots}, max_k={engine.max_k}"
          + (", int8 table" if args.table_dtype == "int8" else "")
          + (", filtered" if args.filtered else "")
          + (f", cache={args.cache_size}" if args.cache_size else ""))

    rng = np.random.default_rng(args.seed + 2)
    heads = np.minimum(rng.zipf(args.zipf, args.requests) - 1,
                       args.entities - 1)
    rels = rng.integers(0, args.relations, args.requests)

    # warmup: compile the fixed-shape step once
    engine.submit(int(heads[0]), int(rels[0]), k=engine.max_k)
    engine.run()

    lat = []
    t_start = time.perf_counter()
    for lo in range(0, args.requests, args.slots):
        for i in range(lo, min(lo + args.slots, args.requests)):
            engine.submit(int(heads[i]), int(rels[i]), k=engine.max_k)
        t0 = time.perf_counter()
        done = engine.run()
        dt = time.perf_counter() - t0
        lat.extend([dt] * len(done))     # batch-synchronous latency
    wall = time.perf_counter() - t_start
    lat_ms = np.sort(np.array(lat) * 1e3)
    p50 = float(np.percentile(lat_ms, 50))
    p99 = float(np.percentile(lat_ms, 99))
    print(f"[serve] {args.requests} requests in {wall:.2f}s — "
          f"{args.requests / wall:.1f} QPS, "
          f"p50={p50:.2f}ms p99={p99:.2f}ms")
    if args.cache_size:
        tot = server.cache_hits + server.cache_misses
        print(f"[serve] head cache: {server.cache_hits}/{tot} hits "
              f"({server.cache_hits / max(tot, 1):.0%})")
    ok = check_equal_dense(server, emb, params, args)
    print(f"[serve] sharded top-k == dense jax.lax.top_k: {ok}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
