"""Abstract input specs for every (architecture × input shape) pair.

Everything is ``jax.ShapeDtypeStruct`` — weak-type-correct, shardable, zero
allocation.  Modality frontends are stubs by assignment: whisper gets frame
embeddings (B, 1500, d); qwen2-vl gets patch embeddings (B, S, 1280) and
3-D M-RoPE positions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.nn.transformer import (
    ArchConfig, init_decode_cache, init_params, stack_plan,
)
from repro.training.optimizer import adam

PyTree = Any


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str        # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run for SSM / hybrid / the
# sliding-window dense variant; skip pure full-attention archs (DESIGN.md §4)
LONG_CONTEXT_OK = {"rwkv6-3b", "recurrentgemma-9b", "gemma-2b-sw"}


def resolve_arch_for_shape(arch_name: str, shape_name: str
                           ) -> Tuple[Optional[ArchConfig], str]:
    """Returns (config-or-None, note).  gemma-2b substitutes its
    sliding-window variant for long_500k."""
    if shape_name == "long_500k":
        if arch_name == "gemma-2b":
            return get_arch("gemma-2b-sw"), \
                "substituted sliding-window variant (sub-quadratic)"
        if arch_name not in LONG_CONTEXT_OK:
            return None, "skipped: full-attention arch at 500k decode"
    return get_arch(arch_name), ""


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16) -> PyTree:
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, dtype=dtype))


def abstract_opt_state(params: PyTree, optimizer=None) -> PyTree:
    opt = optimizer or adam(1e-4)
    return jax.eval_shape(opt.init, params)


def abstract_batch(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    sds = jax.ShapeDtypeStruct

    if shape.mode in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": sds((b, s), i32)}
        if shape.mode == "train":
            batch["labels"] = sds((b, s), i32)
        if cfg.arch_type == "vlm":
            batch["vision_embeds"] = sds((b, s, cfg.vision_dim), bf16)
            batch["positions"] = sds((b, s, 3), i32)
        if cfg.arch_type == "encdec":
            batch["audio_frames"] = sds(
                (b, cfg.encoder_frames, cfg.d_model), bf16)
        return batch

    # decode: one token against a seq_len cache
    batch = {"tokens": sds((b, 1), i32), "pos": sds((b,), i32)}
    if cfg.m_rope:
        batch["positions_3d"] = sds((b, 1, 3), i32)
    return batch


def abstract_cache(cfg: ArchConfig, shape: InputShape,
                   dtype=jnp.bfloat16) -> PyTree:
    return jax.eval_shape(
        lambda: init_decode_cache(cfg, shape.global_batch, shape.seq_len,
                                  dtype=dtype))


# ---------------------------------------------------------------------- #
# Analytic FLOPs for the roofline (MODEL_FLOPS)
# ---------------------------------------------------------------------- #
def _param_counts(cfg: ArchConfig) -> Tuple[float, float]:
    """(total, active) parameter counts, from abstract shapes.  Active
    discounts routed experts to their top_k/num_experts utilization and
    excludes embeddings (standard 6ND convention)."""
    import numpy as np
    params = abstract_params(cfg)
    total = active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        n = float(np.prod(leaf.shape))
        total += n
        if any(str(x) in ("embed", "lm_head") for x in names):
            continue
        if "moe" in [str(x) for x in names] and str(names[-1]) in (
                "w_in", "w_gate", "w_out") and len(leaf.shape) >= 3:
            n = n * (cfg.top_k / max(cfg.num_experts, 1))
        active += n
    return total, active


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """Analytic useful FLOPs per step: 6·N_active·tokens (train),
    2·N_active·tokens (prefill), and for decode 2·N_active·B plus the
    KV-cache attention term."""
    total, active = _param_counts(cfg)
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    if shape.mode == "train":
        flops = 6.0 * active * b * s
        # quadratic attention term (scores+values), per layer with attention
        attn_layers = _attention_layer_count(cfg)
        flops += 6.0 * b * s * s * cfg.num_heads * hd * attn_layers * 0.5
        return flops
    if shape.mode == "prefill":
        attn_layers = _attention_layer_count(cfg)
        return (2.0 * active * b * s +
                2.0 * b * s * s * cfg.num_heads * hd * attn_layers * 0.5)
    # decode
    attn_layers = _attention_layer_count(cfg)
    window = cfg.sliding_window or s
    kv_len = min(s, window)
    return (2.0 * active * b +
            4.0 * b * kv_len * cfg.num_heads * hd * attn_layers)


def _attention_layer_count(cfg: ArchConfig) -> int:
    n = 0
    for kind, cnt, _ in stack_plan(cfg):
        if kind == "pattern":
            per = sum(1 for k in cfg.hybrid_pattern if k == "attn")
            n += per * cnt
        elif kind in ("dense", "moe", "dec", "enc"):
            n += cnt
    if cfg.arch_type == "encdec":
        n += cfg.encoder_layers
    return n


def scan_trip_count(cfg: ArchConfig) -> int:
    """Largest scanned-group length — the collective-bytes loop multiplier."""
    return max((n for _, n, scanned in stack_plan(cfg) if scanned),
               default=1)
