import os
import sys


def _early_devices(argv) -> int:
    """Read ``--devices N`` from raw argv BEFORE jax is imported — jax
    locks the platform device count at first init, so the forced host
    device count must be in XLA_FLAGS before anything touches jax."""
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return 4


if __name__ == "__main__" or os.environ.get("REPRO_AUDIT_FORCE_DEVICES"):
    _n = int(os.environ.get("REPRO_AUDIT_FORCE_DEVICES", 0)) \
        or _early_devices(sys.argv)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n}")

"""SPMD contract auditor CLI — statically prove the communication
contract of every production jitted program (see ``docs/analysis.md``).

Run:  PYTHONPATH=src python -m repro.launch.audit \
          [--devices 4] [--programs train,rank,serve] \
          [--exchanges psum_scatter,psum,alltoall] [--dedup both|on|off] \
          [--json PATH] [--quiet]

Each program — the spmd train step per gather-exchange layout × dedup,
the sharded rank step per protocol, the sharded top-k serve step — is
lowered to post-optimization per-device HLO and checked against its
declarative ``CommContract`` (collective whitelist per mesh axis,
replication audit, donation audit, closed-form collective-byte budget).
Prints the per-program contract table; exits non-zero on any violation.

``--devices`` forces the CPU host platform device count (default 4: a
2×2 data×model mesh, so BOTH axes carry real collectives; 2 still works
— the data axis degenerates and its rules relax to optional).
"""
import argparse
import json


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="statically audit the SPMD communication contracts "
                    "of every production jitted program")
    ap.add_argument("--devices", type=int, default=4,
                    help="forced host platform device count (default 4)")
    ap.add_argument("--programs", default="train,rank,serve",
                    help="comma list of train,rank,serve")
    ap.add_argument("--exchanges", default="",
                    help="comma list of gather-exchange layouts "
                         "(default: every SPMD layout)")
    ap.add_argument("--dedup", default="both",
                    choices=("both", "on", "off"),
                    help="gather-dedup settings to audit (train only)")
    ap.add_argument("--json", default="",
                    help="also write comm_audit rows to this path")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress progress lines (table still prints)")
    args = ap.parse_args(argv)

    import jax
    if jax.device_count() < args.devices:
        print(f"audit: expected {args.devices} forced host devices, "
              f"found {jax.device_count()} — was jax imported before "
              f"this module set XLA_FLAGS?", file=sys.stderr)
        return 2

    from repro.analysis.contracts import format_report_table
    from repro.analysis.programs import comm_audit_rows, run_audit

    dedups = {"both": (False, True), "on": (True,),
              "off": (False,)}[args.dedup]
    log = None if args.quiet else \
        (lambda msg: print(f"# {msg}", file=sys.stderr, flush=True))
    reports = run_audit(
        programs=tuple(p for p in args.programs.split(",") if p),
        exchanges=tuple(e for e in args.exchanges.split(",") if e) or None,
        dedups=dedups, log=log)

    print(format_report_table(reports))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"devices": args.devices,
                       "comm_audit": comm_audit_rows(reports)}, f,
                      indent=2)
    bad = [r.program for r in reports if not r.ok]
    if bad:
        print(f"audit FAILED: contract violations in {bad}",
              file=sys.stderr)
        return 1
    print(f"# audit ok: {len(reports)} programs within contract",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
