"""Step functions lowered by the dry-run and used by the drivers.

``train_step``   — loss + grad + AllReduce (implicit in pjit) + Adam update.
``prefill_step`` — full-sequence forward (inference prefill).
``serve_step``   — ONE new token against a ``seq_len`` KV cache / recurrent
state, greedy-sampled.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn.transformer import (
    ArchConfig, decode_step, loss_fn, prefill,
)
from repro.training.optimizer import Optimizer, apply_updates

PyTree = Any


def make_train_step(cfg: ArchConfig, optimizer: Optimizer) -> Callable:
    def train_step(params, opt_state, batch
                   ) -> Tuple[PyTree, PyTree, Dict[str, jax.Array]]:
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, **aux}
    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch) -> jax.Array:
        last_logits, _ = prefill(
            params, cfg, batch["tokens"],
            positions=batch.get("positions"),
            vision_embeds=batch.get("vision_embeds"),
            audio_frames=batch.get("audio_frames"))
        return last_logits
    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    def serve_step(params, cache, batch
                   ) -> Tuple[jax.Array, PyTree]:
        logits, cache = decode_step(
            params, cfg, batch["tokens"], cache, batch["pos"],
            positions_3d=batch.get("positions_3d"))
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, cache
    return serve_step
