import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): for every (architecture × input shape ×
mesh), ``jit(step).lower(**abstract_inputs).compile()`` must succeed on the
production meshes — 16×16 single-pod and 2×16×16 multi-pod — and emit the
memory / cost / collective numbers the roofline (§Roofline) reads.
(No ``from __future__`` import here: the XLA_FLAGS lines above must stay the
first statements in the file.)

Run:  PYTHONPATH=src python -m repro.launch.dryrun \
          [--arch all] [--shape all] [--mesh single,multi] \
          [--out experiments/dryrun.jsonl] [--force]

Results are appended incrementally (one JSON per line); existing (arch,
shape, mesh) keys are skipped unless --force.

NOTE the XLA_FLAGS assignment above MUST precede every jax import — jax
locks the device count at first init.  Only this entry point sets it; tests
and benchmarks see the real single CPU device.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED
from repro.launch import specs as S
from repro.launch.mesh import (
    HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh,
)
from repro.launch.steps import (
    make_prefill_step, make_serve_step, make_train_step,
)
from repro.sharding.context import mesh_context
from repro.sharding.hlo_analysis import analyze_hlo, total_collective_bytes
from repro.sharding.rules import (
    batch_shardings, cache_shardings, opt_state_shardings, param_shardings,
)
from repro.training.optimizer import adam


def lower_rgcn(mesh_kind: str, overrides: str = "") -> Dict:
    """The paper's own configuration at pod scale: one self-sufficient
    partition per chip (data-parallel over ALL mesh axes — the paper's
    trainer axis), RGCN + DistMult + constraint-based negatives, gradient
    AllReduce via pmean inside shard_map.  Partition shapes follow the
    ogbl-citation2 statistics (Table 2) extrapolated to 256/512 partitions;
    features ship WITH the partition (self-sufficiency: no remote gathers,
    exactly §3.2)."""
    import jax.numpy as jnp
    from repro.models import KGEConfig, RGCNConfig, init_kge_params
    from repro.models.rgcn import rgcn_encode
    from repro.models import decoders
    from repro.core.negative import constraint_based_negatives, mix_pos_neg
    from repro.training.distributed import make_spmd_train_step

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    axes = tuple(mesh.axis_names)          # trainers = ALL axes
    n_chips = int(np.prod(list(mesh.shape.values())))
    V_MAX, E_MAX, FEAT, HID = 262_144, 1_048_576, 128, 32
    # §Perf variant: "dtype=bf16" ships features + activations in bf16
    feat_dtype = jnp.bfloat16 if "dtype=bf16" in overrides else jnp.float32
    kge_cfg = KGEConfig(rgcn=RGCNConfig(
        num_entities=2_927_963, num_relations=2, hidden_dim=HID,
        num_layers=2, num_bases=2, feature_dim=FEAT, dropout=0.0))

    params = jax.eval_shape(
        lambda: init_kge_params(jax.random.PRNGKey(0), kge_cfg))
    if "dtype=bf16" in overrides:
        # full bf16: params + features (+ therefore messages/activations)
        params = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16), params)
    sds = jax.ShapeDtypeStruct
    batch = {
        "src": sds((n_chips, E_MAX), jnp.int32),
        "rel": sds((n_chips, E_MAX), jnp.int32),
        "dst": sds((n_chips, E_MAX), jnp.int32),
        "edge_mask": sds((n_chips, E_MAX), jnp.bool_),
        "core_edge_mask": sds((n_chips, E_MAX), jnp.bool_),
        "features": sds((n_chips, V_MAX, FEAT), feat_dtype),
        "num_core_vertices": sds((n_chips,), jnp.int32),
    }

    def loss_fn(p, b, key):
        h = rgcn_encode(p, kge_cfg.rgcn, b["features"], b["src"], b["rel"],
                        b["dst"], b["edge_mask"])
        pos = jnp.stack([b["src"], b["rel"], b["dst"]], axis=1)
        neg, _ = constraint_based_negatives(
            key, pos, 1, b["num_core_vertices"])
        trip, labels = mix_pos_neg(pos, neg)
        core = b["core_edge_mask"].astype(jnp.float32)
        mask = jnp.concatenate([core, core], axis=0)
        scores = decoders.score_triplets(p["decoder"], "distmult", h, trip)
        loss = decoders.bce_loss(scores, labels, mask)
        return loss, {}

    optimizer = adam(1e-2)
    opt_state = jax.eval_shape(optimizer.init, params)
    keys = jax.eval_shape(
        lambda: jax.random.split(jax.random.PRNGKey(0), n_chips))
    step = make_spmd_train_step(loss_fn, optimizer, mesh, data_axes=axes)

    t0 = time.time()
    lowered = jax.jit(step).lower(params, opt_state, batch, keys)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # jax<=0.4 returns [dict]
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    coll_bytes, coll_stats = total_collective_bytes(hlo_text)
    parsed = analyze_hlo(hlo_text)
    terms = {
        "compute_s": parsed["flops"] / PEAK_FLOPS_BF16,
        "memory_s": parsed["bytes"] / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    return {
        "arch": "rgcn-citation2", "shape": "kg_train", "mesh": mesh_kind,
        "mode": "train", "status": "ok", "chips": n_chips,
        "overrides": overrides,
        "note": f"paper's own config: {n_chips} self-sufficient partitions, "
                "V_max=262144 E_max=1048576 per partition",
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "hlo_flops_per_device": parsed["flops"],
        "hlo_bytes_per_device": parsed["bytes"],
        "hlo_flops_raw": float(cost.get("flops", 0.0)),
        "collective_bytes_per_device": coll_bytes,
        "collective_detail": coll_stats,
        "memory": {"argument_bytes": mem.argument_size_in_bytes,
                   "temp_bytes": mem.temp_size_in_bytes,
                   "output_bytes": mem.output_size_in_bytes},
        "model_flops_global": 0.0, "model_flops_per_device": 0.0,
        "useful_flops_ratio": None,
        "roofline": {**terms, "dominant": dominant.replace("_s", "")},
    }


def _apply_overrides(cfg, overrides: str):
    """--override "k=v,k=v" → dataclasses.replace on the ArchConfig."""
    import dataclasses as _dc
    if not overrides:
        return cfg
    kw = {}
    for item in overrides.split(","):
        k, v = item.split("=", 1)
        field = {f.name: f for f in _dc.fields(cfg)}[k]
        if field.type in ("int",):
            v = int(v)
        elif field.type in ("float",):
            v = float(v)
        elif field.type in ("bool",):
            v = v.lower() in ("1", "true")
        kw[k] = v
    return _dc.replace(cfg, **kw)


def lower_one(arch_name: str, shape_name: str, mesh_kind: str,
              sharding_mode: str = "2d", overrides: str = "") -> Dict:
    """Lower+compile one combination; returns the result record."""
    if arch_name == "rgcn-citation2":
        if shape_name != "kg_train":
            return {"arch": arch_name, "shape": shape_name,
                    "mesh": mesh_kind, "status": "skipped",
                    "note": "rgcn uses its own kg_train shape", "mode": "-"}
        return lower_rgcn(mesh_kind, overrides)
    shape = S.INPUT_SHAPES[shape_name]
    cfg, note = S.resolve_arch_for_shape(arch_name, shape_name)
    rec: Dict = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "mode": shape.mode, "sharding": sharding_mode, "note": note,
        "overrides": overrides, "status": "skipped",
    }
    if cfg is None:
        return rec
    cfg = _apply_overrides(cfg, overrides)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    params = S.abstract_params(cfg)
    p_sh = param_shardings(params, mesh, mode=sharding_mode)
    optimizer = adam(1e-4)

    t0 = time.time()
    with mesh_context(mesh):
        lowered = _lower(cfg, shape, mesh, params, p_sh, optimizer)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # jax<=0.4 returns [dict]
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        }
    except Exception:
        mem_rec = {}

    trip = S.scan_trip_count(cfg)
    hlo_text = compiled.as_text()
    coll_bytes, coll_stats = total_collective_bytes(
        hlo_text, loop_trip_count=trip)
    parsed = analyze_hlo(hlo_text, loop_trip_count=trip)

    # raw XLA numbers (count while bodies ONCE — kept as cross-check);
    # loop-aware parsed numbers drive the roofline
    hlo_flops_raw = float(cost.get("flops", 0.0))
    hlo_bytes_raw = float(cost.get("bytes accessed", 0.0))
    hlo_flops = parsed["flops"]
    hlo_bytes = parsed["bytes"]
    mf = S.model_flops(cfg, shape)

    # roofline terms (seconds), per-device program numbers
    t_compute = hlo_flops / PEAK_FLOPS_BF16
    t_memory = hlo_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    rec.update({
        "status": "ok",
        "chips": n_chips,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "hlo_flops_per_device": hlo_flops,
        "hlo_bytes_per_device": hlo_bytes,
        "hlo_flops_raw": hlo_flops_raw,
        "hlo_bytes_raw": hlo_bytes_raw,
        "collective_bytes_per_device": coll_bytes,
        "collective_detail": coll_stats,
        "scan_trip_count": trip,
        "memory": mem_rec,
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / hlo_flops
        if hlo_flops else None,
        "roofline": {**terms, "dominant": dominant.replace("_s", "")},
    })
    return rec


def _lower(cfg, shape, mesh, params, p_sh, optimizer):
    """Build the jit and lower with abstract inputs (mesh installed)."""
    if shape.mode == "train":
        opt_state = S.abstract_opt_state(params, optimizer)
        o_sh = opt_state_shardings(opt_state, p_sh, mesh)
        batch = S.abstract_batch(cfg, shape)
        b_sh = batch_shardings(batch, mesh)
        step = make_train_step(cfg, optimizer)
        return jax.jit(
            step, in_shardings=(p_sh, o_sh, b_sh),
            donate_argnums=(0, 1)).lower(params, opt_state, batch)
    if shape.mode == "prefill":
        batch = S.abstract_batch(cfg, shape)
        b_sh = batch_shardings(batch, mesh)
        step = make_prefill_step(cfg)
        return jax.jit(
            step, in_shardings=(p_sh, b_sh)).lower(params, batch)
    batch = S.abstract_batch(cfg, shape)
    b_sh = batch_shardings(batch, mesh)
    cache = S.abstract_cache(cfg, shape)
    c_sh = cache_shardings(cache, mesh)
    step = make_serve_step(cfg)
    return jax.jit(
        step, in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,)).lower(params, cache, batch)


def load_done(path: str) -> Dict:
    done = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done[(r["arch"], r["shape"], r["mesh"])] = r
                except Exception:
                    pass
    return done


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--sharding", default="2d", choices=["2d", "1d"])
    ap.add_argument("--override", default="",
                    help="ArchConfig overrides, e.g. rwkv_mode=chunked")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = (list(S.INPUT_SHAPES) if args.shape == "all"
              else args.shape.split(","))
    meshes = args.mesh.split(",")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = {} if args.force else load_done(args.out)
    failures = 0
    with open(args.out, "a") as out:
        for arch in archs:
            for shape in shapes:
                for mesh_kind in meshes:
                    key = (arch, shape, mesh_kind)
                    prev = done.get(key)
                    if prev and prev.get("status") in ("ok", "skipped"):
                        continue
                    t0 = time.time()
                    try:
                        rec = lower_one(arch, shape, mesh_kind,
                                        sharding_mode=args.sharding,
                                        overrides=args.override)
                    except Exception as e:
                        rec = {"arch": arch, "shape": shape,
                               "mesh": mesh_kind, "status": "error",
                               "error": f"{type(e).__name__}: {e}",
                               "traceback": traceback.format_exc()[-2000:]}
                        failures += 1
                    out.write(json.dumps(rec) + "\n")
                    out.flush()
                    dom = rec.get("roofline", {}).get("dominant", "-")
                    print(f"[{time.strftime('%H:%M:%S')}] {arch:>22s} "
                          f"{shape:>12s} {mesh_kind:>6s} "
                          f"{rec['status']:>7s} dom={dom} "
                          f"({time.time() - t0:.0f}s)", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
