"""Static analysis of the lowered SPMD programs.

``repro.analysis.hlo`` is the shared HLO-text parsing core (also behind
``repro.sharding.hlo_analysis``'s roofline counters);
``repro.analysis.contracts`` the declarative CommContract auditor;
``repro.analysis.programs`` lowers every production jitted program and
audits it.  CLI front-end: ``python -m repro.launch.audit``.
"""
from repro.analysis.contracts import (
    AuditReport, CollectiveRule, CommContract, audit_hlo,
    format_report_table,
)
from repro.analysis.hlo import (
    COLLECTIVE_KINDS, COLLECTIVE_WIRE_FACTOR, DTYPE_BYTES, Collective,
    HloModule, buffer_donors, entry_parameters, group_axes,
    input_output_aliases, iter_collectives, parse_instruction,
    parse_replica_groups, shape_bytes, shape_dims,
    used_parameter_numbers,
)

__all__ = [
    "AuditReport", "CollectiveRule", "CommContract", "audit_hlo",
    "format_report_table",
    "COLLECTIVE_KINDS", "COLLECTIVE_WIRE_FACTOR", "DTYPE_BYTES",
    "Collective", "HloModule", "buffer_donors", "entry_parameters",
    "group_axes", "input_output_aliases", "iter_collectives",
    "parse_instruction", "parse_replica_groups", "shape_bytes",
    "shape_dims", "used_parameter_numbers",
]
