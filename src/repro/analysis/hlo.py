"""Shared post-optimization HLO text-parsing core.

One module owns the dtype/collective tables and the instruction/shape
grammar that both consumers build on:

* ``repro.sharding.hlo_analysis`` — loop-aware FLOPs / HBM bytes /
  collective bytes for the roofline (the original consumer; its public
  ``analyze_hlo`` / ``collective_stats`` API is unchanged and now thin
  wrappers over this core);
* ``repro.analysis.contracts`` — the SPMD contract auditor, which needs
  strictly more: replica groups classified onto mesh axes, donation
  metadata (``input_output_alias`` / ``buffer_donor``), entry-parameter
  usage, and nested-tuple result shapes.

Parsing conventions (all verified against live ``compiled.as_text()``
per-device modules from the CPU backend, jax 0.4.x):

* a rank-0 shape ``f32[]`` is ONE element (4 bytes) — not zero;
* tuple-shaped results ``(f32[2], s32[2])`` sum their members; tuples
  nest (``((f32[2,4], f32[]), s32[])``) and members may carry
  ``/*index=N*/`` comments and ``{...}`` layouts;
* ``-start``/``-done`` async collective pairs are counted once (at the
  ``-start``; ``-done`` lines carry no shape of their own);
* ``replica_groups`` come either explicit (``{{0,1},{2,3}}``) or in iota
  form (``[2,2]<=[4]`` with an optional ``T(perm)`` transpose);
* wire bytes follow the roofline convention: ring all-reduce moves ~2x
  the buffer, every other collective is counted at result size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

DTYPE_BYTES: Dict[str, int] = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_KINDS: Tuple[str, ...] = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute")

# wire-byte convention per kind (multiplier on the result size): a ring
# all-reduce moves ~2x the buffer over the wire; everything else is
# counted at result size.
COLLECTIVE_WIRE_FACTOR: Dict[str, float] = {"all-reduce": 2.0}

SHAPE_RE = re.compile(r"\b(" + "|".join(DTYPE_BYTES) + r")\[([0-9,]*)\]")
HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*((?:\([^)]*\)|[\w\[\],{} ]+))")
OPERAND_RE = re.compile(r"%([\w.\-]+)")

_SIMPLE_TYPE_RE = re.compile(r"[\w.\-]+\[[0-9,]*\](?:\{[^{}]*\})?")
_NAME_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|comparator)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_RG_EXPLICIT_INNER_RE = re.compile(r"\{([0-9, ]*)\}")
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
    r"(?:T\(([0-9,]+)\))?")
_ALIAS_ENTRY_RE = re.compile(
    r"\{([0-9, ]*)\}:\s*\((\d+),\s*\{([0-9, ]*)\}"
    r"(?:,\s*([\w\-]+))?\)")
_DONOR_ENTRY_RE = re.compile(r"\((\d+),\s*\{([0-9, ]*)\}\)")


# ---------------------------------------------------------------------- #
# shapes
# ---------------------------------------------------------------------- #
def shape_bytes(text: str) -> int:
    """Total bytes of every dtype[dims] shape token in ``text``.

    ``f32[]`` (rank 0) is one element; tuple types sum their members —
    pass a full (possibly nested) tuple type string and each member
    token is counted once.
    """
    total = 0
    for dtype, dims in SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def shape_dims(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """Every ``(dtype, dims)`` shape token in ``text``, in order."""
    return [(dtype, tuple(int(d) for d in dims.split(",") if d))
            for dtype, dims in SHAPE_RE.findall(text)]


def first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def scan_type(line: str, pos: int) -> Optional[Tuple[str, int]]:
    """Scan one HLO type starting at ``pos``: a simple ``dtype[dims]``
    (with optional ``{layout}``) or a balanced — possibly nested — tuple
    ``(...)``.  Returns ``(type_text, end_pos)`` or ``None``."""
    if pos < len(line) and line[pos] == "(":
        depth = 0
        for i in range(pos, len(line)):
            c = line[i]
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    return line[pos:i + 1], i + 1
        return None
    m = _SIMPLE_TYPE_RE.match(line, pos)
    if m is None:
        return None
    return m.group(0), m.end()


# ---------------------------------------------------------------------- #
# instructions
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Instruction:
    """One parsed HLO instruction line."""

    name: str
    type_str: str      # full result type, including nested tuples
    op: str
    rest: str          # text after the op's opening parenthesis
    is_root: bool
    line: str


def parse_instruction(line: str) -> Optional[Instruction]:
    """Parse ``[ROOT] %name = <type> op(...)`` with balanced tuple types
    (the legacy single-regex parser rejected nested tuples)."""
    nm = _NAME_RE.match(line)
    if nm is None:
        return None
    scanned = scan_type(line, nm.end())
    if scanned is None:
        return None
    type_str, end = scanned
    om = _OP_RE.match(line, end)
    if om is None:
        return None
    return Instruction(
        name=nm.group(2), type_str=type_str, op=om.group(1),
        rest=line[om.end():], is_root=bool(nm.group(1)), line=line)


# ---------------------------------------------------------------------- #
# module: computations, loop graph, trip multipliers
# ---------------------------------------------------------------------- #
class HloModule:
    """Parsed HLO module text: computations, the loop graph and its trip
    multipliers (loop trip counts recovered from ``i < N`` conditions),
    and which computations are top-level (entry / loop bodies) versus
    fusion/call internals."""

    def __init__(self, hlo_text: str, default_trip: int = 1):
        self.text = hlo_text
        self.comps: Dict[str, List[str]] = {}
        self.entry: str = ""
        cur: Optional[List[str]] = None
        for line in hlo_text.splitlines():
            h = HEADER_RE.match(line)
            if h and line.rstrip().endswith("{"):
                name = h.group(1)
                cur = []
                self.comps[name] = cur
                if line.lstrip().startswith("ENTRY"):
                    self.entry = name
                # keep the header line: parameters feed the shape table
                cur.append(line)
                continue
            if cur is not None:
                cur.append(line)
                if line.strip() == "}":
                    cur = None

        # loop graph: parent comp -> [(body, cond, trip)]
        self.loops: Dict[str, List[Tuple[str, str, int]]] = {}
        self.call_targets: Set[str] = set()
        for name, lines in self.comps.items():
            for line in lines:
                b = _BODY_RE.search(line)
                c = _COND_RE.search(line)
                if b and c:
                    trip = self._trip_from_cond(c.group(1), default_trip)
                    self.loops.setdefault(name, []).append(
                        (b.group(1), c.group(1), trip))
                for t in _CALLS_RE.findall(line):
                    self.call_targets.add(t)

        # multipliers by DFS from entry
        self.mult: Dict[str, float] = {}
        if self.entry:
            self._assign(self.entry, 1.0)
        # computations never reached (e.g. dead) default to 1 when visited

    def _trip_from_cond(self, cond: str, default: int) -> int:
        lines = self.comps.get(cond, [])
        consts = [int(m.group(1)) for line in lines
                  for m in [_CONST_RE.search(line)] if m]
        return max(consts) if consts else default

    def _assign(self, comp: str, mult: float, depth: int = 0) -> None:
        if depth > 32:
            return
        self.mult[comp] = max(self.mult.get(comp, 0.0), mult)
        for body, cond, _trip in self.loops.get(comp, []):
            self._assign(body, mult * _trip, depth + 1)
            self._assign(cond, mult * _trip, depth + 1)

    def multiplier(self, comp: str) -> float:
        return self.mult.get(comp, 1.0)

    def top_level(self, comp: str) -> bool:
        """entry / loop bodies / conds — not fusion internals."""
        return comp == self.entry or comp not in self.call_targets

    def instructions(self, comp: str) -> Iterator[Instruction]:
        """Parsed instructions of one computation (header line skipped)."""
        for line in self.comps.get(comp, [])[1:]:
            inst = parse_instruction(line)
            if inst is not None:
                yield inst


# ---------------------------------------------------------------------- #
# collectives
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Collective:
    """One collective instruction, loop-scaled."""

    kind: str                      # one of COLLECTIVE_KINDS
    type_str: str                  # full result type
    result_bytes: int              # per occurrence, unscaled
    wire_bytes: float              # scale * wire factor * result_bytes
    replica_groups: Optional[Tuple[Tuple[int, ...], ...]]
    comp: str
    scale: float                   # loop trip multiplier
    line: str


def _iota_replica_groups(g: int, s: int, dims: Sequence[int],
                         perm: Sequence[int]
                         ) -> Tuple[Tuple[int, ...], ...]:
    """Expand ``replica_groups=[g,s]<=[dims]T(perm)``: device ids are the
    row-major iota over ``dims``, transposed by ``perm``, flattened, then
    reshaped to ``(g, s)`` rows."""
    n = 1
    for d in dims:
        n *= d
    if perm:
        strides = [1] * len(dims)
        for i in range(len(dims) - 2, -1, -1):
            strides[i] = strides[i + 1] * dims[i + 1]
        tdims = [dims[p] for p in perm]
        flat: List[int] = []
        idx = [0] * len(tdims)
        for _ in range(n):
            flat.append(sum(idx[k] * strides[perm[k]]
                            for k in range(len(perm))))
            for k in range(len(tdims) - 1, -1, -1):
                idx[k] += 1
                if idx[k] < tdims[k]:
                    break
                idx[k] = 0
    else:
        flat = list(range(n))
    return tuple(tuple(flat[r * s: (r + 1) * s]) for r in range(g))


def parse_replica_groups(line: str
                         ) -> Optional[Tuple[Tuple[int, ...], ...]]:
    """The instruction's replica groups: ``None`` when the attribute is
    absent, ``()`` for the empty ``replica_groups={}`` (one flat group of
    every device), explicit groups otherwise.  Handles both the explicit
    ``{{0,1},{2,3}}`` and the iota ``[2,2]<=[4]T(1,0)`` forms."""
    m = _RG_IOTA_RE.search(line)
    if m:
        dims = [int(d) for d in m.group(3).split(",") if d]
        perm = ([int(p) for p in m.group(4).split(",") if p]
                if m.group(4) else [])
        return _iota_replica_groups(int(m.group(1)), int(m.group(2)),
                                    dims, perm)
    key = "replica_groups={"
    i = line.find(key)
    if i < 0:
        return None
    depth, j = 0, i + len(key) - 1
    for j in range(i + len(key) - 1, len(line)):
        if line[j] == "{":
            depth += 1
        elif line[j] == "}":
            depth -= 1
            if depth == 0:
                break
    region = line[i + len(key): j]
    return tuple(
        tuple(int(d) for d in g.split(",") if d.strip())
        for g in (mm.group(1) for mm in
                  _RG_EXPLICIT_INNER_RE.finditer(region)))


def collective_kind(op: str) -> Optional[str]:
    """Map an op name (including ``-start`` async forms) onto a
    collective kind, or ``None``."""
    kind = op[:-len("-start")] if op.endswith("-start") else op
    return kind if kind in COLLECTIVE_KINDS else None


def iter_collectives(mod: HloModule) -> List[Collective]:
    """Every collective in the module, loop-scaled, with parsed replica
    groups.  Async pairs are counted once at the ``-start``."""
    out: List[Collective] = []
    for comp, lines in mod.comps.items():
        scale = mod.multiplier(comp)
        for line in lines:
            if "-done(" in line:
                continue
            inst = parse_instruction(line)
            if inst is None:
                continue
            kind = collective_kind(inst.op)
            if kind is None:
                continue
            size = shape_bytes(inst.type_str)
            factor = COLLECTIVE_WIRE_FACTOR.get(kind, 1.0)
            out.append(Collective(
                kind=kind, type_str=inst.type_str, result_bytes=size,
                wire_bytes=scale * factor * size,
                replica_groups=parse_replica_groups(line),
                comp=comp, scale=scale, line=line.strip()))
    return out


def _unravel(device: int, sizes: Sequence[int]) -> Tuple[int, ...]:
    coords = []
    for size in reversed(sizes):
        coords.append(device % size)
        device //= size
    return tuple(reversed(coords))


def group_axes(groups: Optional[Tuple[Tuple[int, ...], ...]],
               mesh_axes: Sequence[Tuple[str, int]]) -> frozenset:
    """Classify replica groups onto mesh axes: which axes of the
    row-major ``(name, size)`` device mesh the groups span.

    ``{{0,1},{2,3}}`` on a 2x2 ``(data, model)`` mesh spans ``{model}``
    (members differ only in the minor coordinate); ``{{0,2},{1,3}}``
    spans ``{data}``.  ``None``/empty groups (a flat all-device
    collective) span every axis; all-singleton groups span none — the
    collective moves no bytes.
    """
    names = [n for n, _ in mesh_axes]
    sizes = [s for _, s in mesh_axes]
    if not groups:
        return frozenset(names)
    spanned = set()
    for g in groups:
        if len(g) <= 1:
            continue
        coords = [_unravel(d, sizes) for d in g]
        for i, name in enumerate(names):
            if len({c[i] for c in coords}) > 1:
                spanned.add(name)
    return frozenset(spanned)


# ---------------------------------------------------------------------- #
# donation metadata + entry parameters
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class IOAlias:
    """One ``input_output_alias`` entry: output index tuple aliases the
    given parameter (at ``param_index`` inside its tuple, if nested)."""

    output_index: Tuple[int, ...]
    param: int
    param_index: Tuple[int, ...]
    kind: str                     # "may-alias" | "must-alias"


def _balanced_attr(text: str, key: str) -> str:
    """The balanced ``{...}`` region (exclusive) of ``key={...}`` in the
    module header, or ``""`` when absent."""
    i = text.find(key + "={")
    if i < 0:
        return ""
    start = i + len(key) + 1
    depth = 0
    for j in range(start, len(text)):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                return text[start + 1: j]
    return ""


def _index_tuple(text: str) -> Tuple[int, ...]:
    return tuple(int(d) for d in text.split(",") if d.strip())


def input_output_aliases(hlo_text: str) -> List[IOAlias]:
    """Parsed ``input_output_alias`` header entries — the donations XLA
    actually ESTABLISHED as output aliases."""
    region = _balanced_attr(hlo_text, "input_output_alias")
    return [
        IOAlias(output_index=_index_tuple(m.group(1)),
                param=int(m.group(2)),
                param_index=_index_tuple(m.group(3)),
                kind=m.group(4) or "may-alias")
        for m in _ALIAS_ENTRY_RE.finditer(region)]


def buffer_donors(hlo_text: str) -> Set[Tuple[int, Tuple[int, ...]]]:
    """Parsed ``buffer_donor`` header entries — parameters XLA retains as
    donatable (donated by the caller, not yet bound to an output)."""
    region = _balanced_attr(hlo_text, "buffer_donor")
    return {(int(m.group(1)), _index_tuple(m.group(2)))
            for m in _DONOR_ENTRY_RE.finditer(region)}


def entry_parameters(mod: HloModule) -> Dict[int, Tuple[str, str]]:
    """Entry-computation parameters: number -> (name, type)."""
    out: Dict[int, Tuple[str, str]] = {}
    for inst in mod.instructions(mod.entry):
        if inst.op == "parameter":
            num = inst.rest.split(")")[0].strip()
            if num.isdigit():
                out[int(num)] = (inst.name, inst.type_str)
    return out


def used_parameter_numbers(mod: HloModule) -> Set[int]:
    """Entry parameters referenced by at least one non-parameter entry
    instruction (operand names match with or without the ``%`` sigil)."""
    params = entry_parameters(mod)
    by_name = {name: num for num, (name, _t) in params.items()}
    used: Set[int] = set()
    for inst in mod.instructions(mod.entry):
        if inst.op == "parameter":
            continue
        for name, num in by_name.items():
            if num in used:
                continue
            if re.search(r"(?<![\w.%-])%?" + re.escape(name)
                         + r"(?![\w.\-])", inst.rest):
                used.add(num)
    return used
