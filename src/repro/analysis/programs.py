"""Lower every production jitted program and audit its CommContract.

Program inventory (the complete set of jitted multi-device programs the
repo ships — anything new belongs here with a contract):

* ``train[<exchange>,<dedup>]`` — the shard_map SPMD train step
  (``repro.training.distributed.make_spmd_train_step`` as the trainer
  wires it), one per ``gather_exchange`` layout in ``SPMD_EXCHANGES``
  × gather dedup on/off.  Contract: the exchange's own collectives on
  the ``model`` axis (with closed-form wire bytes from the batch's plan
  width), the gradient/loss pmean all-reduces on the ``data`` axis, and
  NOTHING else; no buffer of full-table shape; donated batch buffers
  survive to the executable.
* ``rank[<protocol>]`` — the sharded rank-count step
  (``repro.eval.sharded.make_sharded_rank_step``), both protocols.
  Contract: only the integer-count/true-score psums on the ``model``
  axis, with exact closed-form bytes.
* ``serve[topk]`` — the sharded top-k serve program
  (``repro.serving.kge.ShardedKGEServer.topk_program``).  Contract: no
  collectives at all, and no buffer with a full-vocabulary dimension —
  the ``(B, N)`` dense score matrix provably never materializes.
* ``train[psum_scatter,int8]`` / ``serve[topk,int8]`` — the quantized
  table path (``table_dtype="int8"``), audited for the default exchange
  and the serve program.  Train contract: the exchange moves int8 codes
  plus the f32 per-row scale sidecar — reduce-scatter
  ``U'/S·(d·1 + 4)`` and all-gather ``U'·(d·1 + 4)`` bytes per stacked
  trainer (each rule allows up to two matches: XLA may keep the
  codes/scales collectives separate or merge them variadically; the
  auditor sums operand bytes so the budget holds either way); the
  data-axis gradient all-reduce is unchanged (the fp32 master is the
  parameter).  Serve contract additionally forbids any **f32** buffer
  shaped like the full code stack ``(S, rows, d)`` or the flat table
  ``(S·rows, d)`` — the static proof that the fp32 table is never
  materialized on device; the same-shaped int8 codes are exactly what
  should exist, and per-block ``(rows, d)`` dequants are legitimate.

Byte closed-forms (verified against live lowerings; ``U`` = plan width,
``U'`` = ``U`` padded to a shard multiple, ``d`` = embedding dim, ``S``
= model-axis size, f32):

=============  =====================================================
layout         expected exchange wire bytes
=============  =====================================================
psum           ``2·U·d·4``          (one dense all-reduce, ring 2x)
psum_scatter   ``U'·d·4·(1 + 1/S)`` (reduce-scatter + tiled gather)
alltoall       ``2·U'·d·4``         (all-to-all + tiled all-gather)
=============  =====================================================

Dedup shrinks ``U`` to the bucket-padded unique count — the formulas
read the REAL batch's plan width, so the budget tracks dedup for free.

The builders need the forced multi-device CPU platform
(``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
imports) — use the ``repro.launch.audit`` CLI or the tier-1 test's
subprocess; importing this module does not import jax at top level for
exactly that reason.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.contracts import (
    AuditReport, CollectiveRule, CommContract, audit_hlo,
)

RANK_PROTOCOLS = ("all-entities", "candidates")
_N_LOSS_SCALARS = 3     # loss + pos/neg score means (aux keys, CSE'd)


@dataclasses.dataclass(frozen=True)
class AuditConfig:
    """One audited configuration — small enough for CPU CI, shaped like
    production (multi-trainer data axis, multi-shard model axis)."""

    num_trainers: int = 2
    num_table_shards: int = 2
    hidden_dim: int = 8
    num_hops: int = 1
    batch_size: int = 64
    data_scale: float = 0.01     # synthetic_fb15k scale (V = 200)
    seed: int = 3
    eval_dim: int = 16
    eval_batch: int = 16
    eval_relations: int = 4
    num_candidates: int = 8
    serve_batch: int = 8
    serve_k: int = 5


def _mesh_axes(mesh) -> Tuple[Tuple[str, int], ...]:
    return tuple((name, int(size)) for name, size in mesh.shape.items())


def _guard_dims(name: str, legit: Sequence[int],
                forbidden: Sequence[int]) -> None:
    clash = sorted(set(legit) & set(forbidden))
    if clash:
        raise ValueError(
            f"degenerate audit config for {name}: legitimate buffer "
            f"dims {clash} collide with the forbidden full-table dims "
            f"{sorted(set(forbidden))} — the replication audit could "
            f"not tell them apart; pick different audit sizes")


# ---------------------------------------------------------------------- #
# train step
# ---------------------------------------------------------------------- #
def _build_trainer(cfg: AuditConfig, exchange: str, dedup: bool,
                   table_dtype: str = "fp32"):
    from repro.data.datasets import synthetic_fb15k
    from repro.training.trainer import KGETrainer, TrainConfig
    splits = synthetic_fb15k(scale=cfg.data_scale, seed=cfg.seed)
    return KGETrainer(splits, TrainConfig(
        num_trainers=cfg.num_trainers,
        num_hops=cfg.num_hops,
        hidden_dim=cfg.hidden_dim,
        batch_size=cfg.batch_size,
        num_table_shards=cfg.num_table_shards,
        gather_exchange=exchange,
        gather_dedup=dedup,
        table_dtype=table_dtype,
        pipeline="serial",
        spmd=True,
        epochs=1,
        seed=cfg.seed,
    ))


def train_contract(tr, batch: Dict, exchange: str,
                   name: str) -> CommContract:
    """The spmd train step's contract, computed from the trainer's REAL
    mesh, parameter placement and the batch's plan width."""
    import jax

    axes = _mesh_axes(tr.mesh)
    s = int(tr.mesh.shape["model"])
    data = int(tr.mesh.shape["data"])
    d = int(tr.cfg.hidden_dim)
    u = int(batch["shard_local_ids"].shape[-1])
    u_pad = -(-u // s) * s
    itm = 4
    # trainers stacked per data-axis device: the shard_body vmaps the
    # exchange over them, so every exchange buffer (and its wire bytes)
    # scales by t_dev while the collective COUNT stays 1
    t_dev = int(tr.cfg.num_trainers) // data
    quant = tr.cfg.table_dtype == "int8"
    # int8 exchange wire format: one byte per code element plus the f32
    # per-row scale sidecar; XLA may lower codes+scales as two separate
    # collectives or one variadic — each rule tolerates both (count <= 2,
    # bytes summed over matches)
    row_bytes = (d * 1 + 4) if quant else d * itm
    cap = 2 if quant else 1
    rules: List[CollectiveRule] = []
    if s > 1:
        if quant and exchange != "psum_scatter":
            raise ValueError(
                f"int8 train contract is only derived for the default "
                f"psum_scatter exchange, not {exchange!r}")
        if exchange == "psum":
            rules.append(CollectiveRule(
                "all-reduce", ("model",),
                expected_bytes=2.0 * t_dev * u * d * itm,
                note="dense table-exchange psum"))
        elif exchange == "psum_scatter":
            rules.append(CollectiveRule(
                "reduce-scatter", ("model",), min_count=1, max_count=cap,
                expected_bytes=float(t_dev * (u_pad // s) * row_bytes),
                note="scatter phase of the exchange"
                     + (" (int8 codes + f32 scales)" if quant else "")))
            rules.append(CollectiveRule(
                "all-gather", ("model",), min_count=1, max_count=cap,
                expected_bytes=float(t_dev * u_pad * row_bytes),
                note="tiled gather phase of the exchange"
                     + (" (int8 codes + f32 scales)" if quant else "")))
        elif exchange == "alltoall":
            rules.append(CollectiveRule(
                "all-to-all", ("model",),
                expected_bytes=float(t_dev * u_pad * d * itm),
                note="shard-major exchange"))
            rules.append(CollectiveRule(
                "all-gather", ("model",),
                expected_bytes=float(t_dev * u_pad * d * itm),
                note="tiled gather phase of the exchange"))
        else:
            raise ValueError(f"no contract for exchange {exchange!r}")
    leaves = jax.tree_util.tree_leaves(tr.params)
    grad_bytes = sum(
        math.prod(x.sharding.shard_shape(x.shape)) * x.dtype.itemsize
        for x in leaves)
    if data > 1:
        rules.append(CollectiveRule(
            "all-reduce", ("data",),
            min_count=1, max_count=len(leaves) + _N_LOSS_SCALARS + 1,
            expected_bytes=2.0 * (grad_bytes + _N_LOSS_SCALARS * itm),
            note="gradient/loss pmean (Algorithm 1 line 8)"))

    v = int(tr.train_kg.num_entities)
    layout = tr.pre.table_layout
    padded = (layout.num_shards * layout.rows_per_shard
              if layout is not None else v)
    _guard_dims(name, [u, u_pad, d], [v, padded])
    return CommContract(
        name=name, mesh_axes=axes, rules=tuple(rules),
        forbidden_suffixes=tuple({(v, d), (padded, d)}),
        min_donated=max(1, len(batch) - 3),
        notes=f"V={v} d={d} U={u} U'={u_pad} mesh={dict(tr.mesh.shape)}")


def audit_train_step(exchange: str, dedup: bool,
                     cfg: Optional[AuditConfig] = None,
                     table_dtype: str = "fp32") -> AuditReport:
    """Lower the production spmd train step for one exchange layout ×
    dedup setting (× table dtype) and audit its per-device HLO."""
    from repro.training.distributed import (
        make_spmd_train_step, split_trainer_keys,
    )
    import jax

    cfg = cfg or AuditConfig()
    tr = _build_trainer(cfg, exchange, dedup, table_dtype)
    try:
        it = tr.pipeline.device_batches(1)
        batch = next(iter(it))
        close = getattr(it, "close", None)
        if close is not None:
            close()
        # the trainer turns donation off on CPU (where it is a warning
        # no-op); the audit builds the SAME step with the real-backend
        # donation flag so the donation contract is checked as shipped
        step = make_spmd_train_step(
            tr._minibatch_loss, tr.optimizer, tr.mesh,
            param_specs=tr._param_specs, model_axis="model",
            donate_batch=True)
        keys = split_trainer_keys(
            jax.random.PRNGKey(cfg.seed), cfg.num_trainers, 1)
        keys = jax.vmap(jax.random.fold_in, (0, None))(keys, 0)
        lowered = step.lower(tr.params, tr.opt_state, batch, keys)
        hlo = lowered.compile().as_text()
        name = (f"train[{exchange}{',dedup' if dedup else ''}"
                f"{',int8' if table_dtype == 'int8' else ''}]")
        return audit_hlo(hlo, train_contract(tr, batch, exchange, name))
    finally:
        tr.close()


# ---------------------------------------------------------------------- #
# sharded rank step
# ---------------------------------------------------------------------- #
def audit_rank_step(protocol: str,
                    cfg: Optional[AuditConfig] = None) -> AuditReport:
    """Lower ``make_sharded_rank_step`` for one protocol and audit it."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.eval.sharded import _model_axis_put, make_sharded_rank_step
    from repro.launch.mesh import fit_spmd_mesh, make_host_mesh
    from repro.models.decoders import get_decoder, init_decoder_params
    from repro.sharding.embedding import (
        ShardedTableLayout, plan_local_gather, shard_table_block,
    )

    cfg = cfg or AuditConfig()
    fit = fit_spmd_mesh(cfg.num_trainers, cfg.num_table_shards)
    if fit is None:
        raise RuntimeError(
            f"rank-step audit needs {cfg.num_table_shards} model-axis "
            f"devices; {jax.device_count()} available")
    mesh = make_host_mesh(*fit)
    s = cfg.num_table_shards
    b, d, c = cfg.eval_batch, cfg.eval_dim, cfg.num_candidates
    v = 25 * s * d   # V: multiple of S (no layout padding), != any of b/d/c
    layout = ShardedTableLayout(v, s)
    rows = layout.rows_per_shard
    rng = np.random.RandomState(cfg.seed)
    emb = rng.standard_normal((v, d)).astype(np.float32)
    dec = get_decoder("distmult")
    dparams = jax.tree_util.tree_map(jnp.asarray, init_decoder_params(
        jax.random.PRNGKey(cfg.seed), dec, cfg.eval_relations, d))

    table = _model_axis_put(
        (s, rows, d), lambda i: shard_table_block(emb, layout, i),
        mesh, "model")
    heads = rng.randint(0, v, size=b)
    rel = jnp.asarray(rng.randint(0, cfg.eval_relations, size=b)
                      .astype(np.int32))
    q, q_bias = dec.prepare_query(dparams, jnp.asarray(emb[heads]), rel)

    step = make_sharded_rank_step(mesh, decoder=dec, protocol=protocol)
    itm = 4
    if protocol == "all-entities":
        bias = _model_axis_put(
            (s, b, rows), lambda i: np.zeros((b, rows), np.float32),
            mesh, "model")
        t_li, t_ow = plan_local_gather(layout, rng.randint(0, v, size=b))
        lowered = step.lower(dparams, table, q, q_bias, bias,
                             jnp.asarray(t_li), jnp.asarray(t_ow))
        # greater + equal (s32) + true_score (f32) psums, (B,) each
        n_psums, psum_bytes = 3, 3 * 2.0 * b * itm
    elif protocol == "candidates":
        cand = rng.randint(0, v, size=(b, c))
        c_li, c_ow = plan_local_gather(layout, cand)      # (S, B, C)
        c_li = _model_axis_put((s, b, c), lambda i: c_li[i], mesh, "model")
        c_ow = _model_axis_put((s, b, c), lambda i: c_ow[i], mesh, "model")
        true_score = jnp.zeros((b,), jnp.float32)
        lowered = step.lower(dparams, table, q, q_bias, c_li, c_ow,
                             true_score)
        n_psums, psum_bytes = 2, 2 * 2.0 * b * itm
    else:
        raise ValueError(f"unknown rank protocol {protocol!r}")

    _guard_dims(f"rank[{protocol}]", [b, d, c, rows], [v])
    contract = CommContract(
        name=f"rank[{protocol}]",
        mesh_axes=_mesh_axes(mesh),
        rules=(CollectiveRule(
            "all-reduce", ("model",), min_count=1, max_count=n_psums,
            expected_bytes=psum_bytes,
            note="integer rank-count / true-score psums"),),
        forbidden_dims=(v,),
        notes=f"V={v} B={b} d={d} rows={rows}")
    return audit_hlo(lowered.compile().as_text(), contract)


# ---------------------------------------------------------------------- #
# sharded top-k serve step
# ---------------------------------------------------------------------- #
def audit_serve_step(cfg: Optional[AuditConfig] = None,
                     table_dtype: str = "fp32") -> AuditReport:
    """Lower the sharded top-k serve program and audit it: no
    collectives, and no buffer with a full-vocabulary dimension.  With
    ``table_dtype="int8"`` additionally prove no **f32** buffer shaped
    like the full code stack ``(S, rows, d)`` or the flat table
    ``(S·rows, d)`` exists — dequantization stays per-block."""
    import jax
    import numpy as np

    from repro.models.decoders import init_decoder_params
    from repro.serving.kge import ShardedKGEServer

    cfg = cfg or AuditConfig()
    s, d, b, k = (cfg.num_table_shards, cfg.eval_dim, cfg.serve_batch,
                  cfg.serve_k)
    v = 25 * s * d
    rng = np.random.RandomState(cfg.seed)
    emb = rng.standard_normal((v, d)).astype(np.float32)
    dparams = init_decoder_params(
        jax.random.PRNGKey(cfg.seed), "distmult", cfg.eval_relations, d)
    server = ShardedKGEServer(emb, dparams, "distmult", num_shards=s,
                              table_dtype=table_dtype)
    lowered = server.lower_topk(b, k)
    quant = table_dtype == "int8"
    rows = server.layout.rows_per_shard
    name = "serve[topk,int8]" if quant else "serve[topk]"
    _guard_dims(name, [b, d, k, rows, s * min(k, rows)], [v])
    contract = CommContract(
        name=name,
        mesh_axes=(),
        rules=(),                      # any collective is a stray
        forbidden_dims=(v,),
        # the int8 contract: a same-shaped int8 code stack SHOULD exist,
        # but its fp32 image must only ever appear one (rows, d) block at
        # a time — never the whole stack or the flattened table
        forbidden_f32_suffixes=(
            ((s, rows, d), (s * rows, d)) if quant else ()),
        notes=f"V={v} B={b} k={k} S={s} — dense (B, N) scores must "
              f"never materialize"
              + (" and the fp32 table must stay per-block" if quant
                 else ""))
    return audit_hlo(lowered.compile().as_text(), contract)


# ---------------------------------------------------------------------- #
# runner
# ---------------------------------------------------------------------- #
def run_audit(cfg: Optional[AuditConfig] = None,
              programs: Sequence[str] = ("train", "rank", "serve"),
              exchanges: Optional[Sequence[str]] = None,
              dedups: Sequence[bool] = (False, True),
              log=None) -> List[AuditReport]:
    """Audit every requested production program; returns one report per
    lowered module (all ok ⇔ the repo's communication contracts hold)."""
    from repro.sharding.embedding import SPMD_EXCHANGES

    cfg = cfg or AuditConfig()
    exchanges = tuple(exchanges) if exchanges else SPMD_EXCHANGES
    reports: List[AuditReport] = []

    def note(msg):
        if log is not None:
            log(msg)

    if "train" in programs:
        for exchange in exchanges:
            for dedup in dedups:
                note(f"lowering train[{exchange}"
                     f"{',dedup' if dedup else ''}] ...")
                reports.append(audit_train_step(exchange, dedup, cfg))
        if "psum_scatter" in exchanges:
            # quantized-table variant of the default exchange: int8
            # codes + f32 scale sidecar on the wire, fp32 master grads
            note("lowering train[psum_scatter,int8] ...")
            reports.append(audit_train_step(
                "psum_scatter", False, cfg, table_dtype="int8"))
    if "rank" in programs:
        for protocol in RANK_PROTOCOLS:
            note(f"lowering rank[{protocol}] ...")
            reports.append(audit_rank_step(protocol, cfg))
    if "serve" in programs:
        note("lowering serve[topk] ...")
        reports.append(audit_serve_step(cfg))
        note("lowering serve[topk,int8] ...")
        reports.append(audit_serve_step(cfg, table_dtype="int8"))
    return reports


def comm_audit_rows(reports: List[AuditReport]) -> List[Dict]:
    """JSON rows for the ``comm_audit`` section of
    ``BENCH_pipeline.json`` (gated by ``benchmarks/run.py``)."""
    return [r.as_row() for r in reports]
