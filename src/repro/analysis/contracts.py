"""Declarative SPMD communication contracts over post-optimization HLO.

A :class:`CommContract` states, for ONE production jitted program, what
its per-device HLO is allowed to do on the wire and in memory; pass the
compiled module text to :func:`audit_hlo` and get back an
:class:`AuditReport` with every violation.  Four audits compose:

1. **Collective whitelist** — every collective in the module must match
   exactly one :class:`CollectiveRule` by (kind, spanned mesh axes); the
   replica groups are classified onto the row-major device mesh
   (``repro.analysis.hlo.group_axes``), so a gradient all-reduce over
   the ``data`` axis and a table exchange over the ``model`` axis are
   distinguished statically.  Anything unmatched is a stray collective
   — the "no cross-partition traffic" claim, proven on the lowering.
   All-singleton-group collectives move no bytes and are ignored.
2. **Count bounds** — each rule's matches must fall in
   ``[min_count, max_count]`` (a psum_scatter exchange is exactly one
   reduce-scatter plus one all-gather, not two of either).
3. **Byte budget** — a rule with ``expected_bytes`` compares the summed
   wire bytes of its matches against the closed-form expectation (from
   plan sizes / dedup counts), within ``tol`` relative tolerance.
4. **Replication audit** — no instruction in a top-level computation
   (entry, loop bodies — fusion internals never materialize) may
   produce or consume a buffer whose shape ends with a forbidden
   suffix (e.g. the full-table ``(V, d)``) or contains a forbidden
   dimension: the static form of "table memory ∝ 1/S".
5. **Donation audit** — ``donate_batch``-style donation must survive to
   the executable: at least ``min_donated`` entry parameters appear in
   ``input_output_alias`` (established aliases) or ``buffer_donor``
   (retained donatable buffers).  XLA drops donation silently; this
   turns that into a failure.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis.hlo import (
    Collective, HloModule, buffer_donors, group_axes,
    input_output_aliases, iter_collectives, shape_dims,
)


@dataclasses.dataclass(frozen=True)
class CollectiveRule:
    """One whitelisted collective family: ``kind`` spanning exactly the
    mesh ``axes``, with count bounds and an optional closed-form wire
    byte budget (summed over every match)."""

    kind: str                      # e.g. "reduce-scatter"
    axes: Tuple[str, ...]          # spanned mesh axes, e.g. ("model",)
    min_count: int = 1
    max_count: int = 1
    expected_bytes: Optional[float] = None
    tol: float = 0.02              # relative tolerance on expected_bytes
    note: str = ""

    @property
    def label(self) -> str:
        return f"{self.kind}@{'+'.join(self.axes) or 'none'}"


@dataclasses.dataclass(frozen=True)
class CommContract:
    """The full communication/memory contract of one jitted program."""

    name: str
    mesh_axes: Tuple[Tuple[str, int], ...]   # row-major (name, size)
    rules: Tuple[CollectiveRule, ...] = ()
    # replication audit: shape SUFFIXES that must never materialize
    # (e.g. ((V, d), (S*rows, d))) and single dims that must not appear
    forbidden_suffixes: Tuple[Tuple[int, ...], ...] = ()
    forbidden_dims: Tuple[int, ...] = ()
    # dtype-aware variant: suffixes forbidden ONLY for f32 buffers — the
    # int8 table contract ("no fp32 full-table buffer in the compiled
    # program") where the same-shaped int8 code stack is exactly what
    # SHOULD exist
    forbidden_f32_suffixes: Tuple[Tuple[int, ...], ...] = ()
    # donation audit: entry params that must stay aliased or donatable
    min_donated: int = 0
    notes: str = ""


@dataclasses.dataclass
class RuleResult:
    """One rule's observed matches."""

    rule: CollectiveRule
    count: float = 0.0
    wire_bytes: float = 0.0

    def as_row(self) -> Dict[str, object]:
        return {
            "rule": self.rule.label,
            "count": self.count,
            "wire_bytes": self.wire_bytes,
            "expected_bytes": self.rule.expected_bytes,
        }


@dataclasses.dataclass
class AuditReport:
    """Everything :func:`audit_hlo` measured, plus the violations."""

    program: str
    contract: CommContract
    violations: List[str] = dataclasses.field(default_factory=list)
    rule_results: List[RuleResult] = dataclasses.field(default_factory=list)
    stray: List[Collective] = dataclasses.field(default_factory=list)
    n_aliased: int = 0
    n_donor: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations

    def as_row(self) -> Dict[str, object]:
        """JSON-friendly summary (one ``comm_audit`` benchmark row)."""
        return {
            "program": self.program,
            "ok": self.ok,
            "violations": list(self.violations),
            "rules": [r.as_row() for r in self.rule_results],
            "wire_bytes": sum(r.wire_bytes for r in self.rule_results),
            "expected_bytes": sum(
                r.rule.expected_bytes or 0.0 for r in self.rule_results),
            "aliased": self.n_aliased,
            "donor": self.n_donor,
            "min_donated": self.contract.min_donated,
        }


def _audit_collectives(mod: HloModule, contract: CommContract,
                       report: AuditReport) -> None:
    results = [RuleResult(rule) for rule in contract.rules]
    for c in iter_collectives(mod):
        axes = group_axes(c.replica_groups, contract.mesh_axes)
        if c.replica_groups is not None and not axes:
            # all-singleton groups: a degenerate collective moving no
            # bytes (e.g. a pmean lowered on a size-1 axis) — not traffic
            continue
        for res in results:
            if res.rule.kind == c.kind and set(res.rule.axes) == axes:
                res.count += c.scale
                res.wire_bytes += c.wire_bytes
                break
        else:
            report.stray.append(c)
            report.violations.append(
                f"stray collective: {c.kind} over axes "
                f"{sorted(axes)} — {c.line[:120]}")
    for res in results:
        rule = res.rule
        if not rule.min_count <= res.count <= rule.max_count:
            report.violations.append(
                f"{rule.label}: count {res.count:g} outside "
                f"[{rule.min_count}, {rule.max_count}]"
                + (f" ({rule.note})" if rule.note else ""))
        if rule.expected_bytes is not None and res.count:
            err = abs(res.wire_bytes - rule.expected_bytes)
            if err > rule.tol * rule.expected_bytes:
                report.violations.append(
                    f"{rule.label}: wire bytes {res.wire_bytes:.0f} vs "
                    f"closed-form {rule.expected_bytes:.0f} "
                    f"(tol {rule.tol:.0%})"
                    + (f" ({rule.note})" if rule.note else ""))
    report.rule_results = results


def _audit_replication(mod: HloModule, contract: CommContract,
                       report: AuditReport) -> None:
    if not (contract.forbidden_suffixes or contract.forbidden_dims
            or contract.forbidden_f32_suffixes):
        return

    def suffix_match(dims, suffixes):
        return any(len(dims) >= len(suf) and dims[-len(suf):] == suf
                   for suf in suffixes)

    flagged = 0
    for comp in mod.comps:
        if not mod.top_level(comp):
            continue
        for inst in mod.instructions(comp):
            for dtype, dims in shape_dims(inst.type_str):
                bad = (suffix_match(dims, contract.forbidden_suffixes)
                       or any(d in contract.forbidden_dims for d in dims)
                       or (dtype == "f32" and suffix_match(
                           dims, contract.forbidden_f32_suffixes)))
                if bad:
                    flagged += 1
                    if flagged <= 5:       # cap the noise, keep the count
                        report.violations.append(
                            f"replicated buffer {dims} in {comp}: "
                            f"{inst.line.strip()[:120]}")
                    break
    if flagged > 5:
        report.violations.append(
            f"... {flagged - 5} more forbidden-shape buffers")


def _audit_donation(mod: HloModule, contract: CommContract,
                    report: AuditReport) -> None:
    aliases = input_output_aliases(mod.text)
    donors = buffer_donors(mod.text)
    report.n_aliased = len({(a.param, a.param_index) for a in aliases})
    report.n_donor = len(donors)
    if contract.min_donated <= 0:
        return
    total = report.n_aliased + report.n_donor
    if total < contract.min_donated:
        report.violations.append(
            f"donation dropped: {total} entry params aliased/donatable "
            f"({report.n_aliased} aliased + {report.n_donor} donor), "
            f"contract requires >= {contract.min_donated}")


def audit_hlo(hlo_text: str, contract: CommContract,
              program: Optional[str] = None) -> AuditReport:
    """Run every audit of ``contract`` against one per-device
    post-optimization HLO module text."""
    mod = HloModule(hlo_text)
    report = AuditReport(program=program or contract.name,
                         contract=contract)
    _audit_collectives(mod, contract, report)
    _audit_replication(mod, contract, report)
    _audit_donation(mod, contract, report)
    return report


def format_report_table(reports: List[AuditReport]) -> str:
    """Fixed-width per-program contract table (the CLI/step-summary
    output)."""
    headers = ("program", "collectives (count, wire KiB / expected)",
               "donated", "status")
    rows: List[Tuple[str, str, str, str]] = []
    for rep in reports:
        cells = []
        for res in rep.rule_results:
            if not res.count and res.rule.min_count == 0:
                continue
            exp = (f"/{res.rule.expected_bytes / 1024:.1f}"
                   if res.rule.expected_bytes is not None else "")
            cells.append(f"{res.rule.label} x{res.count:g} "
                         f"{res.wire_bytes / 1024:.1f}{exp}")
        donated = f"{rep.n_aliased}+{rep.n_donor}"
        if rep.contract.min_donated:
            donated += f" (>= {rep.contract.min_donated})"
        status = "OK" if rep.ok else f"FAIL ({len(rep.violations)})"
        rows.append((rep.program, "; ".join(cells) or "none", donated,
                     status))
    widths = [max(len(headers[i]), *(len(r[i]) for r in rows)) if rows
              else len(headers[i]) for i in range(4)]
    lines = ["  ".join(h.ljust(w) for h, w in zip(headers, widths)),
             "  ".join("-" * w for w in widths)]
    lines += ["  ".join(c.ljust(w) for c, w in zip(row, widths))
              for row in rows]
    for rep in reports:
        for v in rep.violations:
            lines.append(f"  !! {rep.program}: {v}")
    return "\n".join(lines)
