"""qwen2.5-32b [dense] — GQA kv=8, QKV bias.  [hf:Qwen/Qwen2.5-0.5B family]"""
from repro.nn.transformer import ArchConfig

ARCH = ArchConfig(
    name="qwen2.5-32b", arch_type="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_base=1_000_000.0, mlp_act="silu", mlp_glu=True,
    tie_embeddings=False,
    citation="hf:Qwen/Qwen2.5-0.5B",
)
