"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512, compressed KV cache),
2 shared + 64 routed experts top-6, first layer dense.  [arXiv:2405.04434]

The assignment line reads "64e top-6 ... 2 shared+160 routed"; the released
V2-Lite has 64 routed + 2 shared (160 routed is full V2) — we implement the
V2-Lite values and note the discrepancy here.
"""
from repro.nn.transformer import ArchConfig

ARCH = ArchConfig(
    name="deepseek-v2-lite-16b", arch_type="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944,                      # the single dense layer's FFN
    vocab_size=102400,
    num_experts=64, top_k=6, num_shared_experts=2, d_ff_expert=1408,
    first_k_dense=1,
    use_mla=True, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    mlp_act="silu", mlp_glu=True, tie_embeddings=False,
    citation="arXiv:2405.04434",
)
