"""Architecture registry: the 10 assigned archs (+ long-context variant) and
the paper's own RGCN configurations.  ``--arch <id>`` resolves here."""
from __future__ import annotations

from typing import Dict

from repro.nn.transformer import ArchConfig

from repro.configs.glm4_9b import ARCH as GLM4_9B
from repro.configs.qwen3_32b import ARCH as QWEN3_32B
from repro.configs.qwen2_5_32b import ARCH as QWEN2_5_32B
from repro.configs.gemma_2b import ARCH as GEMMA_2B, ARCH_LONG as GEMMA_2B_SW
from repro.configs.whisper_large_v3 import ARCH as WHISPER_LARGE_V3
from repro.configs.rwkv6_3b import ARCH as RWKV6_3B
from repro.configs.recurrentgemma_9b import ARCH as RECURRENTGEMMA_9B
from repro.configs.arctic_480b import ARCH as ARCTIC_480B
from repro.configs.qwen2_vl_7b import ARCH as QWEN2_VL_7B
from repro.configs.deepseek_v2_lite_16b import ARCH as DEEPSEEK_V2_LITE_16B

ARCHS: Dict[str, ArchConfig] = {
    a.name: a for a in [
        GLM4_9B, QWEN3_32B, QWEN2_5_32B, GEMMA_2B, WHISPER_LARGE_V3,
        RWKV6_3B, RECURRENTGEMMA_9B, ARCTIC_480B, QWEN2_VL_7B,
        DEEPSEEK_V2_LITE_16B,
    ]
}
ARCHS["gemma-2b-sw"] = GEMMA_2B_SW   # long-context sliding-window variant

ASSIGNED = [
    "glm4-9b", "qwen3-32b", "whisper-large-v3", "rwkv6-3b", "gemma-2b",
    "recurrentgemma-9b", "arctic-480b", "qwen2-vl-7b", "qwen2.5-32b",
    "deepseek-v2-lite-16b",
]


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


# The paper's own model configurations (RGCN link prediction, §4.4)
from repro.training.trainer import TrainConfig

RGCN_FB15K237 = TrainConfig(
    num_trainers=8, strategy="vertex_cut", num_hops=2,
    hidden_dim=75, num_bases=2, num_negatives=1,
    batch_size=None,            # full edge batch (paper §4.4)
    learning_rate=0.01, dropout=0.2, epochs=100,
)

RGCN_CITATION2 = TrainConfig(
    num_trainers=8, strategy="vertex_cut", num_hops=2,
    hidden_dim=32, num_bases=2, num_negatives=1,
    batch_size=118_000,         # paper: ~118k edge mini-batch
    learning_rate=0.01, dropout=0.2, epochs=100,
)
