"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 pattern
(rec, rec, attn), window 2048, MQA kv=1.  [arXiv:2402.19427]"""
from repro.nn.transformer import ArchConfig

ARCH = ArchConfig(
    name="recurrentgemma-9b", arch_type="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256000,
    hybrid_pattern=("rec", "rec", "attn"), local_window=2048,
    lru_width=4096, conv1d_width=4,
    mlp_act="gelu_tanh", mlp_glu=True, tie_embeddings=True,
    citation="arXiv:2402.19427",
)
