"""rwkv6-3b [ssm] — Finch: token shift + data-dependent decay WKV.
Attention-free; decode state is O(1) in sequence length, so the
``long_500k`` shape runs natively.  [arXiv:2404.05892]"""
from repro.nn.transformer import ArchConfig

ARCH = ArchConfig(
    name="rwkv6-3b", arch_type="rwkv",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    rwkv_head_dim=64, tie_embeddings=False,
    citation="arXiv:2404.05892",
)
