"""arctic-480b [moe] — dense-MoE hybrid: every layer sums a dense FFN
residual branch and a 128-expert top-2 MoE branch.
[hf:Snowflake/snowflake-arctic-base]"""
from repro.nn.transformer import ArchConfig

ARCH = ArchConfig(
    name="arctic-480b", arch_type="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    num_experts=128, top_k=2, d_ff_expert=4864,
    moe_dense_residual=True,
    mlp_act="silu", mlp_glu=True, tie_embeddings=False,
    citation="hf:Snowflake/snowflake-arctic-base",
)
