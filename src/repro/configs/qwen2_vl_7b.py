"""qwen2-vl-7b [vlm] — M-RoPE (temporal/height/width rotary sections),
dynamic-resolution vision tokens.  The ViT encoder is a STUB: the language
backbone consumes precomputed patch embeddings + 3-D positions supplied by
``input_specs``.  [arXiv:2409.12191]"""
from repro.nn.transformer import ArchConfig

ARCH = ArchConfig(
    name="qwen2-vl-7b", arch_type="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, m_rope=True, rope_base=1_000_000.0,
    vision_dim=1280,
    mlp_act="silu", mlp_glu=True, tie_embeddings=False,
    citation="arXiv:2409.12191",
)
