"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1).  [arXiv:2403.08295]

``sliding_window`` is OUR beyond-config long-context variant enabling the
``long_500k`` decode shape (sub-quadratic window attention); the paper-exact
gemma-2b is full attention — the dry-run lowers both (window=None for the
standard shapes).
"""
from repro.nn.transformer import ArchConfig

ARCH = ArchConfig(
    name="gemma-2b", arch_type="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=256000,
    mlp_act="gelu_tanh", mlp_glu=True, rope_base=10000.0,
    tie_embeddings=True,
    citation="arXiv:2403.08295",
)

# long-context variant (long_500k decode): 4096-token sliding window
import dataclasses
ARCH_LONG = dataclasses.replace(ARCH, name="gemma-2b-sw",
                                sliding_window=4096)
