"""whisper-large-v3 [audio enc-dec] — conv/mel frontend is a STUB; this is
the 32L encoder + 32L decoder transformer backbone.  [arXiv:2212.04356]

Backbone adaptation notes (DESIGN.md §4): Whisper uses learned absolute
positions + LayerNorm; the backbone here follows the repo-wide pre-norm/RoPE
conventions — the assigned dimensions (d=1280, 20 heads, d_ff=5120,
vocab=51866) are exact.
"""
from repro.nn.transformer import ArchConfig

ARCH = ArchConfig(
    name="whisper-large-v3", arch_type="encdec",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    encoder_layers=32, encoder_frames=1500,
    mlp_act="gelu", mlp_glu=False, tie_embeddings=True,
    citation="arXiv:2212.04356",
)
