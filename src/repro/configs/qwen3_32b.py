"""qwen3-32b [dense] — qk_norm, GQA kv=8.  [hf:Qwen/Qwen3-8B family]"""
from repro.nn.transformer import ArchConfig

ARCH = ArchConfig(
    name="qwen3-32b", arch_type="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=25600, vocab_size=151936,
    qk_norm=True, rope_base=1_000_000.0, mlp_act="silu", mlp_glu=True,
    tie_embeddings=False,
    citation="hf:Qwen/Qwen3-8B",
)
