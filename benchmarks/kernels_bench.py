"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp reference.

On this container the Pallas kernels execute in interpret mode, so absolute
times are NOT TPU times — the bench exists to (a) pin the op set per paper
table, (b) compare the XLA reference path's scaling, (c) give the
roofline's per-op byte/flop counts a measured sanity anchor, and (d) gate
kernel-vs-XLA PARITY for every registered decoder's query form: a decoder
whose ``rank_scores`` drifts off the kernel path (or whose prepare/epilogue
disagree with the XLA oracle) raises here and fails the bench."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels import ops, ref


def run(quick: bool = True):
    rows = []
    rng = np.random.default_rng(0)
    v, e, d, nb, r = (512, 2048, 75, 2, 474) if quick else \
        (4096, 16384, 75, 2, 474)
    h = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    src = jnp.asarray(np.sort(rng.integers(0, v, e)), jnp.int32)
    rel = jnp.asarray(rng.integers(0, r, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    mask = jnp.ones(e, bool)
    bases = jnp.asarray(rng.normal(size=(nb, d, d)) * 0.1, jnp.float32)
    coeffs = jnp.asarray(rng.normal(size=(r, nb)), jnp.float32)

    def k_msg():
        ops.rgcn_message_basis(h, src, rel, dst, mask, bases,
                               coeffs).block_until_ready()

    def r_msg():
        ref.rgcn_message_ref(h, src, rel, dst, mask, bases,
                             coeffs).block_until_ready()

    jr_msg = jax.jit(ref.rgcn_message_ref)

    def rj_msg():
        jr_msg(h, src, rel, dst, mask, bases, coeffs).block_until_ready()

    t_pallas = time_call(k_msg)
    t_ref = time_call(rj_msg)
    flops = 2.0 * e * nb * d * d
    rows.append({"name": "rgcn_message_pallas_interpret",
                 "us_per_call": t_pallas * 1e6,
                 "flops": int(flops), "V": v, "E": e})
    rows.append({"name": "rgcn_message_xla_ref",
                 "us_per_call": t_ref * 1e6,
                 "gflops_per_s": round(flops / t_ref / 1e9, 2)})

    b, c = (256, 4096) if quick else (1024, 16384)
    d_kge = 76          # even: complex / rotate split re/im halves
    hs = jnp.asarray(rng.normal(size=(b, d_kge)), jnp.float32)
    rl = jnp.asarray(rng.integers(0, r, b), jnp.int32)
    cand = jnp.asarray(rng.normal(size=(c, d_kge)), jnp.float32)
    bytes_moved = (b * d_kge + c * d_kge + b * c) * 4.0

    # per-decoder query-form parity gate + timing: kernel vs XLA oracle
    from repro.models.decoders import (
        get_decoder, init_decoder_params, registered_decoders,
        score_against_candidates,
    )
    for name in registered_decoders():
        dec = get_decoder(name)
        p = init_decoder_params(jax.random.PRNGKey(0), name, r, d_kge)

        def k_score():
            dec.rank_scores(p, hs, rl, cand).block_until_ready()

        jr_score = jax.jit(lambda hs, rl, cand: score_against_candidates(
            p, dec, hs, rl, cand))

        def r_score():
            jr_score(hs, rl, cand).block_until_ready()

        got = np.asarray(dec.rank_scores(p, hs, rl, cand))
        want = np.asarray(jr_score(hs, rl, cand))
        err = float(np.max(np.abs(got - want)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{name} kernel != XLA oracle")
        t_p = time_call(k_score)
        t_r = time_call(r_score)
        rows.append({"name": f"kge_score_{name}_pallas_interpret",
                     "us_per_call": t_p * 1e6, "B": b, "C": c,
                     "max_abs_err_vs_xla": err})
        rows.append({"name": f"kge_score_{name}_xla_ref",
                     "us_per_call": t_r * 1e6,
                     "gbytes_per_s": round(bytes_moved / t_r / 1e9, 2)})
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "kernels")))
