"""Figure benchmarks: F2 neighborhood growth, F6 component breakdown,
F7 convergence (distributed vs non-distributed)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import build_comp_graph, expand_all, partition_graph
from repro.data import synthetic_citation2, synthetic_fb15k
from repro.training import KGETrainer, TrainConfig


def run_f2(quick: bool = True):
    """Fig 2: average #vertices needed to embed one vertex vs #hops."""
    kg = synthetic_citation2(
        scale=0.0005 if quick else 0.002)["train"].with_inverse_relations()
    part = expand_all(kg, partition_graph(kg, 1, "random"), 1)[0]
    rng = np.random.default_rng(0)
    probe = rng.choice(part.num_core_vertices, size=32, replace=False)
    rows = []
    for hops in (1, 2, 3):
        sizes = []
        for v in probe:
            verts, _ = build_comp_graph(part, np.array([v]), hops)
            sizes.append(verts.shape[0])
        rows.append({
            "name": f"hops{hops}",
            "us_per_call": 0.0,
            "avg_vertices": round(float(np.mean(sizes)), 1),
            "p95_vertices": round(float(np.percentile(sizes, 95)), 1),
        })
    return rows


def run_f6(quick: bool = True):
    """Fig 6: per-batch component times (getComputeGraph host /
    device step) across trainer counts."""
    splits = synthetic_citation2(scale=0.0004 if quick else 0.001, seed=0)
    rows = []
    for p in (1, 2, 4, 8):
        # serial pipeline: the faithful per-component decomposition (the
        # async pipeline hides the host component it is meant to measure —
        # benchmarks/pipeline_bench.py records that overlap)
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=p, epochs=1, hidden_dim=16, batch_size=256,
            num_negatives=1, learning_rate=0.01, seed=0, pipeline="serial"))
        tr.train_epoch()          # warmup/compile epoch
        rec = tr.train_epoch()
        n = max(rec["num_batches"], 1)
        rows.append({
            "name": f"trainers{p}",
            # per-trainer per-batch times (vmapped step serializes P)
            "us_per_call": rec["t_device_step"] / n / p * 1e6,
            "get_compute_graph_ms": round(
                rec["t_get_compute_graph"] / n / p * 1e3, 2),
            "device_step_ms": round(
                rec["t_device_step"] / n / p * 1e3, 2),
            "num_batches": n,
        })
    return rows


def run_f7(quick: bool = True):
    """Fig 7: convergence — valid MRR per epoch, 1 vs 4 trainers."""
    splits = synthetic_fb15k(scale=0.015, seed=5)
    rows = []
    epochs = 8 if quick else 30
    for p in (1, 4):
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=p, epochs=epochs, hidden_dim=24,
            learning_rate=0.05, seed=0))
        curve = []
        for e in range(epochs):
            tr.train_epoch()
            if (e + 1) % 2 == 0:
                curve.append(round(tr.evaluate("valid")["valid_mrr"], 3))
        rows.append({
            "name": f"trainers{p}",
            "us_per_call": 0.0,
            "mrr_curve": "|".join(map(str, curve)),
            "final_mrr": curve[-1],
        })
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run_f2(), "f2")))
    print("\n".join(emit(run_f6(), "f6")))
    print("\n".join(emit(run_f7(), "f7")))
