"""Shared benchmark utilities.

CPU-container semantics (DESIGN.md §7): the paper's wall-clock speedups came
from 8 GPUs; on one CPU core we (a) measure real per-batch/per-epoch work,
and (b) model the cluster epoch time as ``max_i (batches_i × t_batch_i)``
over trainers — trainers run concurrently in the real system, so the slowest
trainer sets the epoch time (exactly the straggler argument of §3.2).
"""
from __future__ import annotations

import os
import sys
import time
from typing import Callable, Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
              **kw) -> float:
    """Median wall seconds of fn(*args)."""
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def fmt_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def emit(rows: List[Dict], table: str) -> List[str]:
    out = []
    for r in rows:
        name = f"{table}/{r.pop('name')}"
        us = r.pop("us_per_call", 0.0)
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        out.append(fmt_row(name, us, derived))
    return out
