"""Serving benchmark: batched greedy-decode throughput of the ServeEngine
(reduced configs, CPU numerics) across architecture families — the per-step
cost structure (attention KV cache vs recurrent state vs MoE routing) is the
point of comparison, not absolute tokens/s."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.nn import init_params
from repro.serving import Request, ServeEngine


ARCHS = ["gemma-2b", "rwkv6-3b", "recurrentgemma-9b",
         "deepseek-v2-lite-16b", "whisper-large-v3"]


def run(quick: bool = True):
    rows = []
    new_tokens = 8 if quick else 32
    for name in ARCHS:
        cfg = get_arch(name).reduced()
        params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        engine = ServeEngine(cfg, params, slots=4, max_seq=64)
        rng = np.random.default_rng(0)
        reqs = [Request(i, rng.integers(1, cfg.vocab_size, 3)
                        .astype(np.int32), max_new_tokens=new_tokens)
                for i in range(4)]
        engine.run([reqs[0]])         # compile warmup
        reqs = [Request(10 + i, r.prompt, max_new_tokens=new_tokens)
                for i, r in enumerate(reqs)]
        t0 = time.perf_counter()
        done = engine.run(reqs)
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r.output) for r in done)
        rows.append({
            "name": name,
            "us_per_call": dt / max(total_tokens, 1) * 1e6,
            "tokens": total_tokens,
            "tokens_per_s": round(total_tokens / dt, 1),
            "family": cfg.arch_type,
        })
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "serve")))
