"""Serving benchmarks: the sharded top-k KGE engine plus LM decode.

Two row families (the suite used to claim KGE latency while only timing LM
decode — now both are measured and labeled as what they are):

* ``kge-topk`` — the ``ShardedKGEServer`` + ``KGEServeEngine`` request
  path at 1/2/4 table shards: batch-synchronous p50/p99 request latency
  and QPS over a Zipf-skewed query stream, with and without the hot-entity
  head cache.  Alongside the timings the suite records the subsystem's
  contract bits: sharded top-k indices EXACTLY ``==`` dense
  ``jax.lax.top_k`` for EVERY registered decoder at every shard count,
  filtered (column-range ``CSRFilterIndex`` bias, serving sentinel
  ``t = -1``) and unfiltered.  ``benchmarks/run.py`` gates on those bits —
  the sharded path never materializes the dense ``(B, N)`` score matrix,
  so exact equality is the only acceptable answer.
* ``lm-decode`` — batched greedy-decode throughput of the LM
  ``ServeEngine`` (reduced configs, CPU numerics) across architecture
  families; the per-step cost structure (attention KV cache vs recurrent
  state vs MoE routing) is the point of comparison, not absolute tokens/s.

Writes ``BENCH_serve.json`` next to the other ``BENCH_*.json`` artifacts.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.nn import init_params
from repro.serving import Request, ServeEngine

SERVE_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                               "BENCH_serve.json")

ARCHS = ["gemma-2b", "rwkv6-3b", "recurrentgemma-9b",
         "deepseek-v2-lite-16b", "whisper-large-v3"]

SHARD_COUNTS = (1, 2, 4)


def _dense_serving_topk(emb, params, decoder, heads, rels, k,
                        filter_index=None):
    """The dense oracle: full (B, N) scores + ``jax.lax.top_k`` (with the
    serving filter semantics — every known tail masked — when filtering)."""
    from repro.eval.ranking import _filter_bias
    from repro.models.decoders import score_against_candidates

    scores = np.asarray(score_against_candidates(
        params, decoder, jnp.asarray(emb[heads]),
        jnp.asarray(rels.astype(np.int32)), jnp.asarray(emb)))
    if filter_index is not None:
        batch = np.stack([heads.astype(np.int64), rels.astype(np.int64),
                          np.full(len(heads), -1, np.int64)], axis=1)
        scores = scores + _filter_bias(filter_index, batch, emb.shape[0])
    return np.asarray(jax.lax.top_k(jnp.asarray(scores), k)[1])


def run_kge(quick: bool = True):
    """KGE serving rows + the equal-to-dense contract bits."""
    from repro.core.graph import KnowledgeGraph
    from repro.eval.ranking import CSRFilterIndex
    from repro.models.decoders import init_decoder_params, \
        registered_decoders
    from repro.serving import KGEServeEngine, ShardedKGEServer

    n, d, r_cnt = (2048, 32, 8) if quick else (16384, 64, 16)
    slots, k = 8, 10
    n_requests = 64 if quick else 256
    rng = np.random.default_rng(0)
    emb = rng.normal(scale=0.1, size=(n, d)).astype(np.float32)
    graph = KnowledgeGraph(
        src=rng.integers(0, n, n * 4), rel=rng.integers(0, r_cnt, n * 4),
        dst=rng.integers(0, n, n * 4), num_entities=n, num_relations=r_cnt)
    filter_index = CSRFilterIndex.build([graph])

    # Zipf-skewed request stream (serving traffic is hot-entity heavy)
    q_heads = np.minimum(rng.zipf(1.3, n_requests) - 1, n - 1)
    q_rels = rng.integers(0, r_cnt, n_requests)

    def drive(engine):
        lat = []
        t_start = time.perf_counter()
        for lo in range(0, n_requests, slots):
            for i in range(lo, min(lo + slots, n_requests)):
                engine.submit(int(q_heads[i]), int(q_rels[i]), k=k)
            t0 = time.perf_counter()
            done = engine.run()
            lat.extend([time.perf_counter() - t0] * len(done))
        wall = time.perf_counter() - t_start
        ms = np.array(lat) * 1e3
        return (float(np.percentile(ms, 50)), float(np.percentile(ms, 99)),
                round(n_requests / wall, 1))

    rows, sharded, equal_bits = [], [], []
    params = init_decoder_params(jax.random.PRNGKey(0), "distmult",
                                 r_cnt, d)
    check_heads = rng.integers(0, n, slots)
    check_rels = rng.integers(0, r_cnt, slots)
    for s in SHARD_COUNTS:
        server = ShardedKGEServer(emb, params, "distmult", num_shards=s,
                                  filter_index=filter_index)
        engine = KGEServeEngine(server, slots=slots, max_k=k)
        engine.submit(int(q_heads[0]), int(q_rels[0]), k=k)
        engine.run()                                   # compile warmup
        p50, p99, qps = drive(engine)

        cached = ShardedKGEServer(emb, params, "distmult", num_shards=s,
                                  cache_size=256)
        engine_c = KGEServeEngine(cached, slots=slots, max_k=k)
        engine_c.submit(int(q_heads[0]), int(q_rels[0]), k=k)
        engine_c.run()
        p50_c, p99_c, qps_c = drive(engine_c)

        equal = bool((server.topk_tails(check_heads, check_rels, k)[1] ==
                      _dense_serving_topk(emb, params, "distmult",
                                          check_heads, check_rels, k)
                      ).all())
        sharded.append({
            "num_shards": s, "p50_ms": round(p50, 3),
            "p99_ms": round(p99, 3), "qps": qps,
            "cached_p50_ms": round(p50_c, 3),
            "cached_p99_ms": round(p99_c, 3), "cached_qps": qps_c,
            "cache_hit_rate": round(cached.cache_hits / max(
                cached.cache_hits + cached.cache_misses, 1), 3),
            "topk_equal_dense": equal,
        })
        rows.append({
            "name": f"kge-topk/{s}shard", "us_per_call": p50 * 1e3,
            "p99_ms": round(p99, 3), "qps": qps,
            "cached_qps": qps_c, "equal_dense": equal,
        })

    # the contract sweep the gate enforces: every decoder x shard count x
    # filter mode must match dense jax.lax.top_k EXACTLY
    for name in registered_decoders():
        p = init_decoder_params(jax.random.PRNGKey(1), name, r_cnt, d)
        for s in SHARD_COUNTS:
            server = ShardedKGEServer(emb, p, name, num_shards=s,
                                      filter_index=filter_index)
            for filtered in (False, True):
                got = server.topk_tails(check_heads, check_rels, k,
                                        filtered=filtered)[1]
                want = _dense_serving_topk(
                    emb, p, name, check_heads, check_rels, k,
                    filter_index if filtered else None)
                equal_bits.append({
                    "decoder": name, "num_shards": s, "filtered": filtered,
                    "topk_equal_dense": bool((got == want).all())})

    payload = {
        "config": {"num_entities": n, "dim": d, "num_relations": r_cnt,
                   "slots": slots, "topk": k, "requests": n_requests,
                   "quick": quick},
        "sharded": sharded,
        "equal_dense": equal_bits,
    }
    return rows, payload


def run_lm(quick: bool = True):
    """LM greedy-decode throughput rows (labeled as what they measure)."""
    rows = []
    new_tokens = 8 if quick else 32
    for name in ARCHS:
        cfg = get_arch(name).reduced()
        params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        engine = ServeEngine(cfg, params, slots=4, max_seq=64)
        rng = np.random.default_rng(0)
        reqs = [Request(i, rng.integers(1, cfg.vocab_size, 3)
                        .astype(np.int32), max_new_tokens=new_tokens)
                for i in range(4)]
        engine.run([reqs[0]])         # compile warmup
        reqs = [Request(10 + i, r.prompt, max_new_tokens=new_tokens)
                for i, r in enumerate(reqs)]
        t0 = time.perf_counter()
        done = engine.run(reqs)
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r.output) for r in done)
        rows.append({
            "name": f"lm-decode/{name}",
            "us_per_call": dt / max(total_tokens, 1) * 1e6,
            "tokens": total_tokens,
            "tokens_per_s": round(total_tokens / dt, 1),
            "family": cfg.arch_type,
            "truncated": sum(r.truncated for r in done),
        })
    return rows


def run(quick: bool = True):
    kge_rows, payload = run_kge(quick)
    lm_rows = run_lm(quick)
    payload["lm_decode"] = lm_rows
    with open(SERVE_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return kge_rows + lm_rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "serve")))
