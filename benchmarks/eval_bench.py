"""Evaluation-subsystem benchmark (PR 3): the host filter-index cost and the
filtered-ranking wall clock, dense vs candidate-axis-sharded.

Three measurements:

* filter-index BUILD — the per-triplet dict-of-sets Python loop
  (``build_filter_index``, kept as reference) vs the one-lexsort vectorized
  ``CSRFilterIndex.build``;
* per-batch BIAS construction — the Python double loop over (test row,
  known tail) vs the CSR searchsorted + scatter, plus the COLUMN-RANGE
  form: building all per-shard bias blocks straight from CSR vs slicing
  a dense bias (the sharded eval path's host cost, no (B, N) intermediate);
* end-to-end filtered ranking — dense ``ranking_metrics`` vs
  ``sharded_ranking_metrics`` at 2/4 shards (simulated mesh), recording that
  the sharded metrics are EXACTLY the dense ones — for BOTH candidate
  protocols (all-entities and the routed ogbl candidate lists);
* per-decoder sharded-ranking throughput — EVERY registered decoder
  (``repro.models.decoders``) through the 2-shard candidate-axis-sharded
  path, wall clock + triplets/s + the sharded==dense equality bit, so a
  decoder silently dropping off the sharded path shows up in the record.

Writes ``BENCH_eval.json`` next to the repo root so the eval-path perf
trajectory is recorded across PRs (acceptance gate: CSR filter build ≥5x
the loop baseline), and emits the usual CSV rows via ``benchmarks.run``.

Run: PYTHONPATH=src python -m benchmarks.eval_bench [--full]
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from benchmarks.common import emit, time_call

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_eval.json")


def run(quick: bool = True) -> List[Dict]:
    from repro.core.graph import make_synthetic_kg, split_train_valid_test
    from repro.eval import (
        CSRFilterIndex, build_filter_index, ranking_metrics,
        sharded_ranking_metrics,
    )
    from repro.eval.ranking import _filter_bias

    n_ent, n_rel, n_edge = (3000, 24, 60_000) if quick else \
        (20_000, 120, 400_000)
    kg = make_synthetic_kg(n_ent, n_rel, n_edge, seed=0)
    splits = split_train_valid_test(kg)
    graphs = [g.with_inverse_relations() for g in splits.values()]
    n_trip = sum(g.num_edges for g in graphs)

    # ---- filter-index build: Python loop vs vectorized CSR ----
    # capture each timed call's last result so nothing runs an extra
    # time just to fetch it (the loop build dominates --full wall clock)
    res: Dict[str, object] = {}

    def timed(name, fn):
        seconds = time_call(lambda: res.__setitem__(name, fn()))
        return seconds, res[name]

    loop_s, ref_idx = timed("ref", lambda: build_filter_index(graphs))
    csr_s, csr_idx = timed("csr", lambda: CSRFilterIndex.build(graphs))
    build_speedup = loop_s / max(csr_s, 1e-9)

    # ---- per-batch bias: double loop vs searchsorted + scatter ----
    test = splits["test"].with_inverse_relations().triplets()[:512]
    bias_loop_s, bias_loop = timed(
        "bias_ref", lambda: _filter_bias(ref_idx, test, n_ent))
    bias_csr_s, bias_csr = timed(
        "bias_csr", lambda: _filter_bias(csr_idx, test, n_ent))
    np.testing.assert_array_equal(bias_loop, bias_csr)
    bias_speedup = bias_loop_s / max(bias_csr_s, 1e-9)

    # ---- per-shard bias blocks straight from CSR (column-range form) ----
    from repro.eval import shard_filter_bias_block
    from repro.sharding.embedding import ShardedTableLayout, \
        shard_bias_blocks
    blocks_layout = ShardedTableLayout(n_ent, 4)
    blk_dense_s, blk_dense = timed(
        "blk_dense", lambda: shard_bias_blocks(
            _filter_bias(csr_idx, test, n_ent), blocks_layout))
    blk_range_s, blk_range = timed(
        "blk_range", lambda: np.stack([
            shard_filter_bias_block(csr_idx, test, blocks_layout, s)
            for s in range(4)]))
    np.testing.assert_array_equal(blk_dense, blk_range)

    # ---- ranking wall clock: dense vs candidate-axis-sharded ----
    rng = np.random.default_rng(0)
    d = 32 if quick else 64
    emb = rng.normal(size=(n_ent, d)).astype(np.float32)
    dparams = {"rel_diag":
               rng.normal(size=(2 * n_rel, d)).astype(np.float32)}
    rank_trips = test[:256]
    dense_s, m_dense = timed(
        "dense", lambda: ranking_metrics(emb, dparams, rank_trips, csr_idx))
    sharded_rows = []
    for s in (2, 4):
        wall, m_sh = timed(
            f"sh{s}", lambda s=s: sharded_ranking_metrics(
                emb, dparams, rank_trips, csr_idx, s))
        sharded_rows.append({
            "num_shards": s,
            "rank_wall_s": round(wall, 4),
            "metrics_equal_dense": m_sh == m_dense,
        })

    # ---- ogbl candidate-list protocol: dense vs routed-sharded ----
    cand_rng = np.random.default_rng(7)
    cand = cand_rng.integers(
        0, n_ent, size=(rank_trips.shape[0], 64)).astype(np.int32)
    cand_dense_s, m_cand = timed(
        "cand_dense", lambda: ranking_metrics(
            emb, dparams, rank_trips, csr_idx, candidates=cand))
    candidate_rows = []
    for s in (2, 4):
        wall, m_cs = timed(
            f"cand_sh{s}", lambda s=s: sharded_ranking_metrics(
                emb, dparams, rank_trips, csr_idx, s, candidates=cand))
        candidate_rows.append({
            "num_shards": s,
            "rank_wall_s": round(wall, 4),
            "metrics_equal_dense": m_cs == m_cand,
        })

    # ---- per-decoder 2-shard throughput (registry-driven) ----
    import jax
    from repro.models.decoders import init_decoder_params, \
        registered_decoders
    decoder_rows = []
    for name in registered_decoders():
        p = jax.tree_util.tree_map(np.asarray, init_decoder_params(
            jax.random.PRNGKey(0), name, 2 * n_rel, d))
        dd, m_d = timed(f"dec_dense_{name}", lambda: ranking_metrics(
            emb, p, rank_trips, csr_idx, decoder=name))
        ds, m_s = timed(f"dec_sh_{name}", lambda: sharded_ranking_metrics(
            emb, p, rank_trips, csr_idx, 2, decoder=name))
        decoder_rows.append({
            "decoder": name,
            "dense_wall_s": round(dd, 4),
            "sharded2_wall_s": round(ds, 4),
            "sharded_triplets_per_s":
                round(rank_trips.shape[0] / max(ds, 1e-9), 1),
            "metrics_equal_dense": m_s == m_d,
        })

    payload = {
        "bench": "eval",
        "graph": {"entities": n_ent, "relations": n_rel,
                  "filter_triplets": n_trip, "quick": quick},
        "filter_build": {
            "loop_s": round(loop_s, 4),
            "csr_s": round(csr_s, 4),
            "speedup": round(build_speedup, 2),
        },
        "bias_build": {
            "batch": int(test.shape[0]),
            "loop_s": round(bias_loop_s, 4),
            "csr_s": round(bias_csr_s, 4),
            "speedup": round(bias_speedup, 2),
        },
        "bias_blocks_4shard": {
            "batch": int(test.shape[0]),
            "dense_split_s": round(blk_dense_s, 4),
            "csr_range_s": round(blk_range_s, 4),
        },
        "ranking": {
            "test_triplets": int(rank_trips.shape[0]),
            "hidden_dim": d,
            "dense_wall_s": round(dense_s, 4),
            "mrr": m_dense["mrr"],
            "sharded": sharded_rows,
        },
        "candidate_ranking": {
            "test_triplets": int(rank_trips.shape[0]),
            "candidates_per_row": int(cand.shape[1]),
            "dense_wall_s": round(cand_dense_s, 4),
            "mrr": m_cand["mrr"],
            "sharded": candidate_rows,
        },
        "per_decoder": decoder_rows,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    rows = [
        {"name": "filter_build_loop", "us_per_call": loop_s * 1e6,
         "triplets": n_trip},
        {"name": "filter_build_csr", "us_per_call": csr_s * 1e6,
         "speedup_vs_loop": round(build_speedup, 2)},
        {"name": "bias_loop", "us_per_call": bias_loop_s * 1e6,
         "batch": int(test.shape[0])},
        {"name": "bias_csr", "us_per_call": bias_csr_s * 1e6,
         "speedup_vs_loop": round(bias_speedup, 2)},
        {"name": "rank_dense", "us_per_call": dense_s * 1e6,
         "mrr": round(m_dense["mrr"], 5)},
    ]
    for r in sharded_rows:
        rows.append({"name": f"rank_sharded_{r['num_shards']}",
                     "us_per_call": r["rank_wall_s"] * 1e6,
                     "equal_dense": r["metrics_equal_dense"]})
    rows.append({"name": "bias_blocks_csr_range",
                 "us_per_call": blk_range_s * 1e6,
                 "dense_split_us": round(blk_dense_s * 1e6, 1)})
    rows.append({"name": "rank_candidates_dense",
                 "us_per_call": cand_dense_s * 1e6,
                 "mrr": round(m_cand["mrr"], 5)})
    for r in candidate_rows:
        rows.append({"name": f"rank_candidates_sharded_{r['num_shards']}",
                     "us_per_call": r["rank_wall_s"] * 1e6,
                     "equal_dense": r["metrics_equal_dense"]})
    for r in decoder_rows:
        rows.append({"name": f"rank_decoder_{r['decoder']}_sh2",
                     "us_per_call": r["sharded2_wall_s"] * 1e6,
                     "triplets_per_s": r["sharded_triplets_per_s"],
                     "equal_dense": r["metrics_equal_dense"]})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    print("\n".join(emit(run(quick=not ap.parse_args().full), "eval")))
