"""Benchmark harness entry point — one function per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--full] [--only t2,t3,...]

Prints ``name,us_per_call,derived`` CSV rows (one per measured
configuration).  ``--full`` uses larger synthetic datasets; the default
quick mode finishes on a single CPU core in minutes.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks.common import emit


def check_embedding_gate() -> str:
    """Perf gate over the freshly written ``BENCH_embedding.json``: the
    2-shard gather+exchange must stay within ``GATE_RATIO``x the dense
    replicated gather (ROADMAP open item 2 — the old masked-sum chain sat
    at ~3x and this keeps the regression from silently returning), the
    int8 table must hold its per-device bytes ratio (<= 0.3x fp32), and
    the int8 sharded-eval MRR must stay within the documented drift
    tolerance of fp32.  Returns a summary line; raises on violation."""
    from benchmarks.pipeline_bench import EMBED_JSON_PATH, GATE_RATIO
    with open(EMBED_JSON_PATH) as f:
        payload = json.load(f)
    two = next(r for r in payload["sharded"] if r["num_shards"] == 2)
    ratio = two["sharded_over_dense_ratio"]
    if ratio > GATE_RATIO:
        raise RuntimeError(
            f"embedding gate FAILED: 2-shard gather+exchange is "
            f"{ratio:.2f}x dense (limit {GATE_RATIO}x) — "
            f"{two['gather_exchange_us']}us vs "
            f"{payload['dense_gather_us']}us dense")
    quant = payload["quant"]
    if quant["bytes_ratio_2shard"] > quant["bytes_ratio_limit"]:
        raise RuntimeError(
            f"embedding gate FAILED: int8 table bytes are "
            f"{quant['bytes_ratio_2shard']:.3f}x fp32 per device "
            f"(limit {quant['bytes_ratio_limit']}x)")
    if quant["mrr_drift"] > quant["mrr_drift_limit"]:
        raise RuntimeError(
            f"embedding gate FAILED: int8 eval MRR drift "
            f"{quant['mrr_drift']:.4f} exceeds the documented tolerance "
            f"{quant['mrr_drift_limit']} (fp32 {quant['mrr_fp32']:.4f} "
            f"vs int8 {quant['mrr_int8']:.4f})")
    return (f"embedding gate ok: 2-shard gather+exchange "
            f"{ratio:.2f}x dense (limit {GATE_RATIO}x); int8 table "
            f"{quant['bytes_ratio_2shard']:.3f}x bytes "
            f"(limit {quant['bytes_ratio_limit']}x), MRR drift "
            f"{quant['mrr_drift']:.4f} "
            f"(limit {quant['mrr_drift_limit']})")


def check_serve_gate() -> str:
    """Correctness gate over the freshly written ``BENCH_serve.json``:
    every sharded top-k result — per decoder, per shard count, filtered
    and unfiltered — must be EXACTLY equal to dense ``jax.lax.top_k``
    (the serving engine never materializes the dense score matrix, so
    exact equality is the contract, not a tolerance).  Returns a summary
    line; raises on violation."""
    from benchmarks.serve_bench import SERVE_JSON_PATH
    with open(SERVE_JSON_PATH) as f:
        payload = json.load(f)
    bits = payload["equal_dense"] + payload["sharded"]
    bad = [b for b in bits if not b["topk_equal_dense"]]
    if bad:
        raise RuntimeError(
            f"serve gate FAILED: sharded top-k != dense jax.lax.top_k "
            f"for {bad}")
    n_dec = len({b["decoder"] for b in payload["equal_dense"]})
    n_shard = len({b["num_shards"] for b in payload["equal_dense"]})
    return (f"serve gate ok: sharded top-k == dense for {n_dec} decoders "
            f"x {n_shard} shard counts, filtered+unfiltered "
            f"({len(bits)} checks)")


def check_comm_audit_gate() -> str:
    """Contract gate over the freshly written ``BENCH_pipeline.json``:
    every ``comm_audit`` row — one per lowered production program — must
    pass its CommContract (collective whitelist, replication, donation,
    and parsed collective bytes within the closed-form budget).
    Returns a summary line; raises on violation with the audit table."""
    from benchmarks.pipeline_bench import JSON_PATH
    with open(JSON_PATH) as f:
        payload = json.load(f)
    audit = payload["comm_audit"]
    bad = [r for r in audit["rows"] if not r["ok"]]
    if not audit["ok"] or bad:
        detail = "\n".join(
            f"  {r['program']}: {'; '.join(r['violations'])}"
            for r in bad) or audit.get("stderr", "")
        raise RuntimeError(
            f"comm_audit gate FAILED: contract violations in "
            f"{[r['program'] for r in bad] or 'the audit subprocess'}\n"
            f"{detail}\n{audit.get('table', '')}")
    total = sum(r["wire_bytes"] for r in audit["rows"])
    return (f"comm_audit gate ok: {len(audit['rows'])} programs within "
            f"contract, {total / 1024:.1f} KiB collective wire bytes "
            f"within closed-form budget")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import comm_analysis, eval_bench, figs, kernels_bench, \
        pipeline_bench, roofline, serve_bench
    from benchmarks import t2_partition_stats, t3_accuracy_speedup
    from benchmarks import t4_fixed_updates, t5_partition_strategies

    suites = {
        "pipeline": lambda: pipeline_bench.run(quick),  # BENCH_pipeline.json
        "embedding":                                    # BENCH_embedding.json
            lambda: pipeline_bench.run_embedding(quick),
        "eval": lambda: eval_bench.run(quick),          # BENCH_eval.json
        "t2": lambda: t2_partition_stats.run(quick),      # Table 2
        "t3": lambda: t3_accuracy_speedup.run(quick),     # Table 3
        "t4": lambda: t4_fixed_updates.run(quick),        # Table 4
        "t5": lambda: t5_partition_strategies.run(quick),  # Table 5
        "f2": lambda: figs.run_f2(quick),                 # Figure 2
        "f6": lambda: figs.run_f6(quick),                 # Figure 6
        "f7": lambda: figs.run_f7(quick),                 # Figure 7
        "kernels": lambda: kernels_bench.run(quick),
        "serve": lambda: serve_bench.run(quick),        # BENCH_serve.json
        "comm": lambda: comm_analysis.run(quick),
        "roofline": lambda: roofline.run(quick),          # deliverable (g)
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn()
            for line in emit(rows, name):
                print(line, flush=True)
            if name == "pipeline":
                print(f"# {check_comm_audit_gate()}", file=sys.stderr)
            if name == "embedding":
                print(f"# {check_embedding_gate()}", file=sys.stderr)
            if name == "serve":
                print(f"# {check_serve_gate()}", file=sys.stderr)
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failed.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if failed:
        sys.exit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
