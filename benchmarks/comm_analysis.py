"""Communication-volume analysis: what self-sufficiency SAVES.

The paper's central claim is that neighborhood expansion trades replicated
storage/compute for ZERO neighbor traffic.  This analysis quantifies the
counterfactual — a DistDGL-style system that fetches remote n-hop
neighborhood state on demand — against the paper's design, per epoch:

  fetch bytes (remote)  = Σ_partitions |remote vertices in the n-hop
                          closure of its core edges| × d × 4 B × epochs'
                          (each epoch re-fetches: embeddings change)
  paper's bytes         = gradient AllReduce only (|params| × 4 B / epoch)
  paper's one-time cost = support-vertex features shipped ONCE at startup

This is the table DESIGN.md §2 promises; it runs on host numpy only.
"""
from __future__ import annotations


from benchmarks.common import emit
from repro.core import expand_all, partition_graph
from repro.data import synthetic_citation2


def run(quick: bool = True):
    splits = synthetic_citation2(scale=0.0008 if quick else 0.002, seed=0)
    kg = splits["train"].with_inverse_relations()
    d = 128                      # feature dim
    hidden = 32
    params = d * hidden * 2 + hidden * hidden * 2 + \
        kg.num_relations * (2 + hidden)      # rgcn basis + decoder approx
    rows = []
    for p in (2, 4, 8):
        parts = partition_graph(kg, p, "vertex_cut", seed=0)
        expanded = expand_all(kg, parts, num_hops=2)
        fetch_bytes = 0
        support_bytes = 0
        for part, sp in zip(parts, expanded):
            n_core = sp.num_core_vertices
            n_support = sp.num_local_vertices - n_core
            # remote-fetch design: every support vertex's CURRENT state is
            # re-fetched each epoch (embeddings / hidden states go stale)
            fetch_bytes += n_support * d * 4
            # paper's design: the same vertices' INPUT features ship once
            support_bytes += n_support * d * 4
        grad_bytes = params * 4 * 2          # ring all-reduce ≈ 2× params
        rows.append({
            "name": f"partitions{p}",
            "us_per_call": 0.0,
            "remote_fetch_MB_per_epoch": round(fetch_bytes / 1e6, 2),
            "paper_gradient_MB_per_epoch": round(grad_bytes / 1e6, 3),
            "paper_one_time_support_MB": round(support_bytes / 1e6, 2),
            "per_epoch_saving_x": round(fetch_bytes / grad_bytes, 1),
        })
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "comm")))
