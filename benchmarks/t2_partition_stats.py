"""Table 2: partition statistics — core edges, total edges after 2-hop
neighborhood expansion, replication factor — for P ∈ {2, 4, 8} on both
dataset shapes."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import expand_all, partition_graph, replication_factor
from repro.data import synthetic_citation2, synthetic_fb15k


def run(quick: bool = True):
    rows = []
    datasets = {
        "fb15k237": synthetic_fb15k(scale=0.02 if quick else 0.1)["train"],
        "citation2": synthetic_citation2(
            scale=0.0005 if quick else 0.002)["train"],
    }
    for dname, kg in datasets.items():
        kgi = kg.with_inverse_relations()
        for p in (2, 4, 8):
            t0 = __import__("time").perf_counter()
            parts = partition_graph(kgi, p, "vertex_cut", seed=0)
            exp = expand_all(kgi, parts, num_hops=2)
            dt = __import__("time").perf_counter() - t0
            core = np.array([e.num_core_edges for e in exp])
            total = np.array([e.num_local_edges for e in exp])
            rows.append({
                "name": f"{dname}_p{p}",
                "us_per_call": dt * 1e6,
                "core_edges_mean": int(core.mean()),
                "core_edges_std": int(core.std()),
                "total_edges_mean": int(total.mean()),
                "total_edges_std": int(total.std()),
                "rf": round(replication_factor(kgi, parts), 2),
            })
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "t2")))
