"""Roofline report (deliverable g): reads experiments/dryrun.jsonl and emits
the per-(arch × shape × mesh) three-term table, the dominant bottleneck, the
MODEL_FLOPS/HLO_FLOPs useful-compute ratio, and the three hillclimb picks
(worst roofline fraction / most collective-bound / most paper-representative).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

DEFAULT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "experiments", "dryrun.jsonl")


def load(path: str = DEFAULT_PATH) -> List[Dict]:
    rows: Dict = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            rows[(r["arch"], r["shape"], r["mesh"])] = r   # last wins
    return list(rows.values())


def table(rows: List[Dict], mesh: str = "single") -> str:
    """Markdown roofline table for one mesh."""
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | bound frac |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped ({r['note']}) | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — |")
            continue
        t = r["roofline"]
        total = max(t["compute_s"], t["memory_s"], t["collective_s"])
        frac = t["compute_s"] / total if total else 0.0
        ur = r.get("useful_flops_ratio")
        ur_s = f"{ur:.2f}" if ur is not None else "n/a"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"{t['dominant']} | {ur_s} | {frac:.2f} |")
    return "\n".join(lines)


def hillclimb_picks(rows: List[Dict]) -> Dict[str, Dict]:
    """The three §Perf targets."""
    ok = [r for r in rows if r["status"] == "ok" and r["mesh"] == "single"]

    def frac(r):
        t = r["roofline"]
        total = max(t["compute_s"], t["memory_s"], t["collective_s"])
        return t["compute_s"] / total if total else 0.0

    worst = min(ok, key=frac)
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"] /
               (r["roofline"]["compute_s"] + 1e-12))
    return {
        "worst_roofline_fraction": worst,
        "most_collective_bound": coll,
        # most representative of the paper's technique: the data-parallel
        # train shape on the arch whose gradient AllReduce dominates — and
        # separately the RGCN pipeline itself (benchmarked in t3/t5)
        "paper_representative": next(
            (r for r in ok if r["shape"] == "train_4k"
             and r["roofline"]["dominant"] == "collective"), worst),
    }


def run(quick: bool = True):
    if not os.path.exists(DEFAULT_PATH):
        return [{"name": "missing", "us_per_call": 0.0,
                 "note": "run repro.launch.dryrun first"}]
    rows = load()
    ok = [r for r in rows if r["status"] == "ok"]
    out = []
    for r in ok:
        t = r["roofline"]
        out.append({
            "name": f"{r['arch']}_{r['shape']}_{r['mesh']}",
            "us_per_call": max(t["compute_s"], t["memory_s"],
                               t["collective_s"]) * 1e6,
            "dominant": t["dominant"],
            "compute_s": round(t["compute_s"], 4),
            "memory_s": round(t["memory_s"], 4),
            "collective_s": round(t["collective_s"], 4),
            "useful_ratio": round(r.get("useful_flops_ratio") or 0, 3),
        })
    return out


if __name__ == "__main__":
    rows = load()
    print(table(rows, "single"))
    print()
    picks = hillclimb_picks(rows)
    for k, v in picks.items():
        print(f"{k}: {v['arch']} × {v['shape']}")
