"""Table 5: partitioning-strategy ablation — vertex-cut (KaHIP analogue) vs
edge-cut (METIS analogue) vs random, each followed by neighborhood
expansion; partition sizes and modeled epoch time at fixed model updates."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.data import synthetic_fb15k
from repro.training import KGETrainer, TrainConfig


def run(quick: bool = True):
    rows = []
    splits = synthetic_fb15k(scale=0.02 if quick else 0.08, seed=2)
    for strategy in ("vertex_cut", "edge_cut", "random"):
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=4, epochs=1, hidden_dim=24, batch_size=256,
            num_negatives=1, learning_rate=0.05, seed=0,
            strategy=strategy))
        core = np.array([p.num_core_edges for p in tr.partitions])
        total = np.array([p.num_local_edges for p in tr.partitions])
        rec = tr.train_epoch()
        # per-trainer batch time (vmapped CPU step serializes 4 trainers);
        # epoch time = STRAGGLER: the most-loaded partition's batch count
        # (the paper's §3.2 imbalance argument — edge-cut's skewed
        # partitions set the epoch time)
        t_batch = rec["t_device_step"] / max(rec["num_batches"], 1) / 4
        straggler_batches = int(np.ceil(core.max() / 256))
        epoch_model_s = straggler_batches * t_batch
        rows.append({
            "name": strategy,
            "us_per_call": t_batch * 1e6,
            "core_edges_mean": int(core.mean()),
            "core_edges_std": int(core.std()),
            "total_edges_mean": int(total.mean()),
            "total_edges_std": int(total.std()),
            "rf": round(tr.replication_factor, 2),
            "load_balance": round(float(core.max() / core.mean()), 2),
            "epoch_model_s": round(epoch_model_s, 3),
        })
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "t5")))
