"""Table 4: scaling with a FIXED number of model updates — the batch size
per trainer shrinks as trainers grow (global batch constant), so speedup
comes purely from smaller per-trainer batches."""
from __future__ import annotations

from benchmarks.common import emit
from repro.data import synthetic_fb15k
from repro.training import KGETrainer, TrainConfig


def run(quick: bool = True):
    rows = []
    splits = synthetic_fb15k(scale=0.02 if quick else 0.08, seed=1)
    global_batch = 1024
    base = None
    for p in (1, 2, 4, 8):
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=p, epochs=1, hidden_dim=24,
            batch_size=max(global_batch // p, 8),
            num_negatives=1, learning_rate=0.05, seed=0))
        rec = tr.train_epoch()
        # per-trainer time: the vmapped CPU step serializes all P trainers
        t_batch = rec["t_device_step"] / max(rec["num_batches"], 1) / p
        epoch_model_s = rec["num_batches"] * t_batch
        if base is None:
            base = epoch_model_s
        rows.append({
            "name": f"trainers{p}",
            "us_per_call": t_batch * 1e6,
            "edges_per_batch": max(global_batch // p, 8),
            "num_updates": rec["num_batches"],
            "epoch_model_s": round(epoch_model_s, 3),
            "speedup": round(base / max(epoch_model_s, 1e-9), 2),
        })
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "t4")))
