"""Table 3: MRR / Hits@1 and epoch-time speedup vs number of trainers.

Accuracy: measured exactly (distributed == non-distributed is the claim).
Speedup: cluster epoch time modeled as ``max_i batches_i × t_batch(i)``
(trainers run concurrently; see benchmarks.common docstring) with t_batch
measured on-device per partition — the same-batch-size protocol of §4.5.1,
where the batch count per trainer falls with P.
"""
from __future__ import annotations



from benchmarks.common import emit
from repro.data import synthetic_fb15k
from repro.training import KGETrainer, TrainConfig


def run(quick: bool = True):
    rows = []
    splits = synthetic_fb15k(scale=0.02 if quick else 0.08, seed=0)
    epochs = 6 if quick else 25
    base_time = None
    for p in (1, 2, 4, 8):
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=p, epochs=epochs, hidden_dim=24,
            batch_size=512, num_negatives=1, learning_rate=0.05, seed=0))
        hist = tr.fit()
        m = tr.evaluate("test")
        # model the concurrent-cluster epoch: the vmapped CPU step times
        # all P trainers SEQUENTIALLY, so one trainer's per-batch time is
        # t_step/P; trainers run concurrently in the real cluster, epoch =
        # batches_per_trainer × per-trainer batch time
        t_step = hist[-1]["t_device_step"] / max(hist[-1]["num_batches"], 1)
        t_batch = t_step / p
        batches_per_trainer = hist[-1]["num_batches"]
        epoch_model_s = batches_per_trainer * t_batch
        if base_time is None:
            base_time = epoch_model_s
        rows.append({
            "name": f"trainers{p}",
            "us_per_call": t_batch * 1e6,
            "mrr": round(m["test_mrr"], 3),
            "hits1": round(m["test_hits@1"], 3),
            "epoch_model_s": round(epoch_model_s, 3),
            "speedup": round(base_time / max(epoch_model_s, 1e-9), 2),
            "loss": round(hist[-1]["loss"], 4),
        })
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "t3")))
