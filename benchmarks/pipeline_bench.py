"""Input-pipeline benchmark: serial vs async epoch wall-clock and the
host/device overlap fraction (the Fig. 6 bottleneck, attacked).

Writes ``BENCH_pipeline.json`` next to the repo root so the perf trajectory
of the input pipeline is recorded across PRs, and emits the usual CSV rows
via ``benchmarks.run``.

Run: PYTHONPATH=src python -m benchmarks.pipeline_bench [--full]
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from benchmarks.common import emit
import numpy as np

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_pipeline.json")


def _measure(splits, kind: str, quick: bool) -> Dict[str, float]:
    from repro.training import KGETrainer, TrainConfig

    tr = KGETrainer(splits, TrainConfig(
        num_trainers=4, strategy="vertex_cut", num_hops=2, hidden_dim=32,
        num_negatives=1, batch_size=256, learning_rate=0.01, seed=0,
        pipeline=kind))
    tr.train_epoch()                      # warmup + compile epoch
    epochs = 2 if quick else 5
    walls, recs = [], []
    for _ in range(epochs):
        t0 = time.perf_counter()
        rec = tr.train_epoch()
        walls.append(time.perf_counter() - t0)
        recs.append(rec)
    return {
        "epoch_wall_s": float(np.median(walls)),
        "host_build_s": float(np.median(
            [r["t_host_build"] for r in recs])),
        "host_exposed_s": float(np.median(
            [r["t_get_compute_graph"] for r in recs])),
        "device_step_s": float(np.median(
            [r["t_device_step"] for r in recs])),
        "overlap_fraction": float(np.median(
            [r["overlap_fraction"] for r in recs])),
        "num_batches": int(recs[0]["num_batches"]),
    }


def run(quick: bool = True) -> List[Dict]:
    from repro.data import synthetic_citation2

    splits = synthetic_citation2(scale=0.0008 if quick else 0.002, seed=0)
    kg = splits["train"]
    results = {kind: _measure(splits, kind, quick)
               for kind in ("serial", "async")}
    speedup = results["serial"]["epoch_wall_s"] / \
        max(results["async"]["epoch_wall_s"], 1e-9)

    payload = {
        "bench": "pipeline",
        "graph": {"entities": int(kg.num_entities),
                  "edges": int(kg.num_edges)},
        "config": {"trainers": 4, "batch_size": 256, "num_hops": 2,
                   "hidden_dim": 32, "quick": quick},
        "serial": results["serial"],
        "async": results["async"],
        "async_speedup": round(speedup, 3),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    rows = []
    for kind in ("serial", "async"):
        r = results[kind]
        rows.append({
            "name": kind,
            "us_per_call": r["epoch_wall_s"] / max(r["num_batches"], 1)
            * 1e6,
            "epoch_wall_s": round(r["epoch_wall_s"], 3),
            "host_exposed_s": round(r["host_exposed_s"], 3),
            "overlap": round(r["overlap_fraction"], 3),
        })
    rows.append({
        "name": "speedup",
        "us_per_call": 0.0,
        "async_over_serial": round(speedup, 3),
    })
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "pipeline")))
