"""Input-pipeline benchmark: serial vs async epoch wall-clock and the
host/device overlap fraction (the Fig. 6 bottleneck, attacked), plus the
sharded-entity-table variant: per-step gather+exchange time and the
embedding-table bytes each device has to hold at 1/2/4/8 model shards
(the memory wall row-sharding removes).

Writes ``BENCH_pipeline.json`` and ``BENCH_embedding.json`` next to the
repo root so both perf trajectories are recorded across PRs, and emits the
usual CSV rows via ``benchmarks.run``.

Run: PYTHONPATH=src python -m benchmarks.pipeline_bench [--full]
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from benchmarks.common import emit
import numpy as np

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_pipeline.json")
EMBED_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                               "BENCH_embedding.json")


def _hbm_per_device(tr) -> int:
    """Max bytes any one device holds for params + optimizer state (the
    spmd row's capacity headline: row-sharding the entity table divides
    its — and its adam moments' — footprint across the model axis)."""
    import jax
    per: Dict[int, int] = {}
    for arr in jax.tree_util.tree_leaves((tr.params, tr.opt_state)):
        if not hasattr(arr, "addressable_shards"):
            continue
        for sh in arr.addressable_shards:
            per[sh.device.id] = per.get(sh.device.id, 0) + sh.data.nbytes
    return max(per.values()) if per else 0


def _measure(splits, kind: str, quick: bool,
             sharded_transfer: bool = False,
             spmd=None) -> Dict[str, float]:
    from repro.training import KGETrainer, TrainConfig

    tr = KGETrainer(splits, TrainConfig(
        num_trainers=4, strategy="vertex_cut", num_hops=2, hidden_dim=32,
        num_negatives=1, batch_size=256, learning_rate=0.01, seed=0,
        pipeline=kind, sharded_transfer=sharded_transfer, spmd=spmd))
    tr.train_epoch()                      # warmup + compile epoch
    epochs = 2 if quick else 5
    walls, recs = [], []
    for _ in range(epochs):
        t0 = time.perf_counter()
        rec = tr.train_epoch()
        walls.append(time.perf_counter() - t0)
        recs.append(rec)
    return {
        "epoch_wall_s": float(np.median(walls)),
        "host_build_s": float(np.median(
            [r["t_host_build"] for r in recs])),
        "host_exposed_s": float(np.median(
            [r["t_get_compute_graph"] for r in recs])),
        "device_step_s": float(np.median(
            [r["t_device_step"] for r in recs])),
        "overlap_fraction": float(np.median(
            [r["overlap_fraction"] for r in recs])),
        "num_batches": int(recs[0]["num_batches"]),
        "hbm_per_device_bytes": _hbm_per_device(tr),
    }


AUDIT_DEVICES = 4      # forced host devices for the comm audit (2x2 mesh)


def _comm_audit(quick: bool) -> Dict:
    """Run the SPMD contract auditor (``repro.launch.audit``) in a
    subprocess — it needs a forced multi-device CPU platform, and this
    process's jax already locked the real device count — and return its
    ``comm_audit`` rows for the pipeline payload.  Quick mode audits one
    exchange layout (both dedup settings) + rank + serve; full mode
    every layout."""
    import subprocess
    import sys
    import tempfile

    cmd = [sys.executable, "-m", "repro.launch.audit",
           "--devices", str(AUDIT_DEVICES), "--quiet"]
    if quick:
        cmd += ["--exchanges", "psum_scatter"]
    with tempfile.NamedTemporaryFile(suffix=".json") as tmp:
        cmd += ["--json", tmp.name]
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src"),
             env.get("PYTHONPATH", "")])
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              env=env, timeout=1200)
        if proc.returncode != 0:
            # keep the violation table in the payload — the run.py gate
            # raises on it, with the table in the error message
            return {"ok": False, "returncode": proc.returncode,
                    "table": proc.stdout, "stderr": proc.stderr[-2000:],
                    "rows": []}
        with open(tmp.name) as f:
            rows = json.load(f)["comm_audit"]
    return {"ok": all(r["ok"] for r in rows), "returncode": 0,
            "table": proc.stdout, "rows": rows}


def run(quick: bool = True) -> List[Dict]:
    from repro.data import synthetic_citation2

    splits = synthetic_citation2(scale=0.0008 if quick else 0.002, seed=0)
    kg = splits["train"]
    results = {kind: _measure(splits, kind, quick)
               for kind in ("serial", "async")}
    # per-axis NamedSharding device_put instead of jnp.asarray (on a
    # 1-device box this measures the pure placement-API overhead; on a
    # real mesh it buys the per-device slice placement)
    results["async_sharded"] = _measure(splits, "async", quick,
                                        sharded_transfer=True)
    # the REAL shard_map step (spmd=True forces it even on the 1-device
    # box, where the 1x1 mesh measures pure shard_map dispatch overhead
    # vs the vmap simulation; on a multi-device host it runs the mesh
    # fit_spmd_mesh picks) — step time + per-device param/opt-state HBM
    import jax
    results["spmd"] = _measure(splits, "async", quick, spmd=True)
    speedup = results["serial"]["epoch_wall_s"] / \
        max(results["async"]["epoch_wall_s"], 1e-9)

    payload = {
        "bench": "pipeline",
        "graph": {"entities": int(kg.num_entities),
                  "edges": int(kg.num_edges)},
        "config": {"trainers": 4, "batch_size": 256, "num_hops": 2,
                   "hidden_dim": 32, "quick": quick,
                   "devices": int(jax.device_count())},
        "serial": results["serial"],
        "async": results["async"],
        "async_sharded_transfer": results["async_sharded"],
        "spmd": results["spmd"],
        "async_speedup": round(speedup, 3),
        # static SPMD contract audit: collective whitelist + closed-form
        # byte budget per production program (repro.analysis)
        "comm_audit": _comm_audit(quick),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    rows = []
    for kind in ("serial", "async", "async_sharded", "spmd"):
        r = results[kind]
        rows.append({
            "name": kind,
            "us_per_call": r["epoch_wall_s"] / max(r["num_batches"], 1)
            * 1e6,
            "epoch_wall_s": round(r["epoch_wall_s"], 3),
            "host_exposed_s": round(r["host_exposed_s"], 3),
            "overlap": round(r["overlap_fraction"], 3),
            "hbm_per_device_mib":
                round(r["hbm_per_device_bytes"] / 2**20, 2),
        })
    rows.append({
        "name": "speedup",
        "us_per_call": 0.0,
        "async_over_serial": round(speedup, 3),
    })
    audit = payload["comm_audit"]
    rows.append({
        "name": "comm_audit",
        "us_per_call": 0.0,
        "programs": len(audit["rows"]),
        "ok": audit["ok"],
    })
    return rows


# ---------------------------------------------------------------------- #
# Sharded entity table: gather+exchange time, table bytes per device
# ---------------------------------------------------------------------- #
GATE_RATIO = 1.5   # max allowed 2-shard gather+exchange / dense gather —
#   the regression bar benchmarks/run.py enforces (ROADMAP open item 2:
#   the old masked-sum chain sat at 3x)


def _time_gather(fn, *args, iters: int = 30) -> float:
    import jax
    fn(*args)[0].block_until_ready()           # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


QUANT_BYTES_RATIO_LIMIT = 0.3   # int8 table bytes / fp32 bytes per device
#   — closed form (d·1 + 4) / (d·4) = 0.266 at d=64; gated so a storage
#   regression (e.g. accidentally materializing fp32 rows) cannot land
QUANT_MRR_DRIFT_LIMIT = 0.02    # |MRR(int8) - MRR(fp32)| on the sharded
#   eval — the documented accuracy cost of row-wise symmetric int8 with
#   pow2 scales (per-element error <= scale/2); measured drift on the
#   quick synthetic eval is ~1e-3


def _quant_eval_drift(quick: bool, shards_out: List[Dict]) -> Dict:
    """Measure the int8 table's end-to-end accuracy cost: filtered MRR of
    the 2-shard sharded eval over the quantized table vs the identical
    eval over the fp32 table, same embeddings, same filter index.  Gated
    by ``benchmarks/run.py`` together with the per-device bytes ratio."""
    from repro.core.graph import make_synthetic_kg, split_train_valid_test
    from repro.eval import CSRFilterIndex, ranking_metrics

    n_ent, n_rel, n_edge = (2000, 8, 12_000) if quick else \
        (10_000, 24, 80_000)
    d = 32 if quick else 64
    kg = make_synthetic_kg(n_ent, n_rel, n_edge, seed=0)
    splits = split_train_valid_test(kg)
    graphs = [g.with_inverse_relations() for g in splits.values()]
    csr = CSRFilterIndex.build(graphs)
    rng = np.random.default_rng(1)
    emb = rng.normal(size=(n_ent, d)).astype(np.float32)
    dparams = {"rel_diag":
               rng.normal(size=(2 * n_rel, d)).astype(np.float32)}
    test = splits["test"].with_inverse_relations().triplets()[:256]
    m_fp32 = ranking_metrics(emb, dparams, test, csr, num_shards=2)
    m_int8 = ranking_metrics(emb, dparams, test, csr, num_shards=2,
                             table_dtype="int8")
    two = next(r for r in shards_out if r["num_shards"] == 2)
    return {
        "bytes_ratio_limit": QUANT_BYTES_RATIO_LIMIT,
        "bytes_ratio_2shard": two["quant_bytes_ratio"],
        "mrr_drift_limit": QUANT_MRR_DRIFT_LIMIT,
        "mrr_fp32": round(m_fp32["mrr"], 6),
        "mrr_int8": round(m_int8["mrr"], 6),
        "mrr_drift": round(abs(m_int8["mrr"] - m_fp32["mrr"]), 6),
        "eval": {"entities": n_ent, "dim": d, "test_triplets": len(test)},
    }


def _zipf_ids(rng, v: int, batch: int, a: float = 1.3) -> np.ndarray:
    """Skewed gather ids on the workload shape KGE batches actually have:
    Zipf-ranked popularity over a random entity permutation (so the hot
    set is not the contiguous low-id block — dedup wins must come from
    repetition, not shard locality)."""
    ranks = (rng.zipf(a, size=batch) - 1) % v
    return rng.permutation(v)[ranks].astype(np.int32)


def run_embedding(quick: bool = True) -> List[Dict]:
    """Dense replicated gather vs shard-local gather + exchange at 1-8
    model shards (simulated mesh), three variants per shard count:

    * ``fused`` — the flat-index fused gather (the default exchange);
    * ``chain`` — the original take → mask → sum chain (the PR-2 path the
      fused kernel replaced; kept as the regression reference);
    * ``dedup`` — fused over the unique-id plan + on-device expansion.

    ``sharded_over_dense_ratio`` (fused / dense) is the gated headline:
    ``benchmarks/run.py`` exits non-zero when the 2-shard ratio exceeds
    ``GATE_RATIO``.  A zipfian id case measures dedup on skewed batches.
    Per-device table bytes must shrink ∝ 1/num_shards — that is the
    capacity the sharding buys.

    Each shard count also measures the quantized (int8) table: the
    fused-dequant gather time, the per-device bytes
    (``rows·(d + 4)`` — codes plus the f32 scale sidecar, gated at
    ``QUANT_BYTES_RATIO_LIMIT`` x fp32) and the closed-form exchange
    wire bytes per row; a top-level ``quant`` section measures the
    end-to-end MRR drift of the int8 sharded eval vs fp32 (gated at
    ``QUANT_MRR_DRIFT_LIMIT``)."""
    import jax
    import jax.numpy as jnp
    from repro.sharding.embedding import (
        QuantizedTableLayout, ShardedTableLayout, plan_local_gather,
        plan_unique_gather, quantize_rows, shard_table, sharded_gather,
        sharded_dequant_gather,
    )

    v, d = (20_000, 64) if quick else (200_000, 128)
    batch = 4096
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    ids = rng.integers(0, v, size=batch).astype(np.int32)
    zipf = _zipf_ids(rng, v, batch)

    dense_us = _time_gather(
        jax.jit(lambda t, i: (t[i],)), table, jnp.asarray(ids)) * 1e6

    fused_fn = jax.jit(lambda t, i, o: (sharded_gather(t, i, o),))
    quant_fn = jax.jit(lambda c, sc, i, o: (
        sharded_dequant_gather(c, sc, i, o),))
    chain_fn = jax.jit(lambda t, i, o: (
        sharded_gather(t, i, o, exchange="masked_sum"),))
    dedup_fn = jax.jit(lambda t, i, o, inv: (
        sharded_gather(t, i, o, inverse=inv),))

    def time_variants(layout, sh, batch_ids):
        li, ow = plan_local_gather(layout, batch_ids)
        li, ow = jnp.asarray(li), jnp.asarray(ow)
        ul, uo, inv = plan_unique_gather(layout, batch_ids)
        out = {
            "fused_us": _time_gather(fused_fn, sh, li, ow) * 1e6,
            "chain_us": _time_gather(chain_fn, sh, li, ow) * 1e6,
            "dedup_us": _time_gather(
                dedup_fn, sh, jnp.asarray(ul), jnp.asarray(uo),
                jnp.asarray(inv)) * 1e6,
            "unique_ids": int(len(np.unique(batch_ids))),
            "plan_slots": int(ul.shape[1]),
        }
        return out

    shards_out = []
    for s in (1, 2, 4, 8):
        layout = ShardedTableLayout(v, s)
        sh = shard_table(table, layout)
        uni = time_variants(layout, sh, ids)
        zip_ = time_variants(layout, sh, zipf)
        # quantized (int8) variant: same gather plan over the int8 code
        # stack + per-row f32 scales, dequant fused into the gather —
        # per-device bytes drop to rows·(d·1 + 4) and only int8 codes
        # (plus the 4-byte scale sidecar) would cross the wire
        codes, scales = quantize_rows(sh)
        li, ow = plan_local_gather(layout, ids)
        quant_us = _time_gather(
            quant_fn, codes, scales, jnp.asarray(li), jnp.asarray(ow)) * 1e6
        q_bytes = QuantizedTableLayout(v, s).bytes_per_shard(d)
        shards_out.append({
            "num_shards": s,
            "gather_exchange_us": round(uni["fused_us"], 2),
            "chain_exchange_us": round(uni["chain_us"], 2),
            "dedup_gather_us": round(uni["dedup_us"], 2),
            "sharded_over_dense_ratio":
                round(uni["fused_us"] / max(dense_us, 1e-9), 3),
            "unique_ids": uni["unique_ids"],
            "zipf": {
                "gather_exchange_us": round(zip_["fused_us"], 2),
                "dedup_gather_us": round(zip_["dedup_us"], 2),
                "unique_ids": zip_["unique_ids"],
                "plan_slots": zip_["plan_slots"],
            },
            "table_bytes_per_device": layout.bytes_per_shard(d),
            "rows_per_shard": layout.rows_per_shard,
            "quant_gather_us": round(quant_us, 2),
            "quant_table_bytes_per_device": q_bytes,
            "quant_bytes_ratio":
                round(q_bytes / layout.bytes_per_shard(d), 4),
            # closed-form wire bytes per gathered row on the exchange
            "wire_bytes_per_row": d * 4,
            "quant_wire_bytes_per_row": d * 1 + 4,
        })

    payload = {
        "bench": "embedding",
        "table": {"entities": v, "dim": d, "batch_gather": batch,
                  "dense_bytes": v * d * 4, "quick": quick},
        "dense_gather_us": round(dense_us, 2),
        "gate_max_2shard_ratio": GATE_RATIO,
        "sharded": shards_out,
        "quant": _quant_eval_drift(quick, shards_out),
    }
    with open(EMBED_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    rows = [{"name": "dense", "us_per_call": round(dense_us, 2),
             "table_mib_per_device": round(v * d * 4 / 2**20, 2)}]
    for r in shards_out:
        rows.append({
            "name": f"sharded_{r['num_shards']}",
            "us_per_call": r["gather_exchange_us"],
            "over_dense": r["sharded_over_dense_ratio"],
            "chain_us": r["chain_exchange_us"],
            "dedup_us": r["dedup_gather_us"],
            "zipf_dedup_us": r["zipf"]["dedup_gather_us"],
            "table_mib_per_device":
                round(r["table_bytes_per_device"] / 2**20, 2),
            "quant_us": r["quant_gather_us"],
            "quant_mib_per_device":
                round(r["quant_table_bytes_per_device"] / 2**20, 2),
        })
    q = payload["quant"]
    rows.append({"name": "quant_mrr_drift",
                 "us_per_call": 0.0,
                 "mrr_fp32": q["mrr_fp32"], "mrr_int8": q["mrr_int8"],
                 "drift": q["mrr_drift"], "limit": q["mrr_drift_limit"]})
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "pipeline")))
    print("\n".join(emit(run_embedding(), "embedding")))
