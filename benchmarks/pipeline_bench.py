"""Input-pipeline benchmark: serial vs async epoch wall-clock and the
host/device overlap fraction (the Fig. 6 bottleneck, attacked), plus the
sharded-entity-table variant: per-step gather+exchange time and the
embedding-table bytes each device has to hold at 1/2/4/8 model shards
(the memory wall row-sharding removes).

Writes ``BENCH_pipeline.json`` and ``BENCH_embedding.json`` next to the
repo root so both perf trajectories are recorded across PRs, and emits the
usual CSV rows via ``benchmarks.run``.

Run: PYTHONPATH=src python -m benchmarks.pipeline_bench [--full]
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from benchmarks.common import emit
import numpy as np

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_pipeline.json")
EMBED_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                               "BENCH_embedding.json")


def _measure(splits, kind: str, quick: bool,
             sharded_transfer: bool = False) -> Dict[str, float]:
    from repro.training import KGETrainer, TrainConfig

    tr = KGETrainer(splits, TrainConfig(
        num_trainers=4, strategy="vertex_cut", num_hops=2, hidden_dim=32,
        num_negatives=1, batch_size=256, learning_rate=0.01, seed=0,
        pipeline=kind, sharded_transfer=sharded_transfer))
    tr.train_epoch()                      # warmup + compile epoch
    epochs = 2 if quick else 5
    walls, recs = [], []
    for _ in range(epochs):
        t0 = time.perf_counter()
        rec = tr.train_epoch()
        walls.append(time.perf_counter() - t0)
        recs.append(rec)
    return {
        "epoch_wall_s": float(np.median(walls)),
        "host_build_s": float(np.median(
            [r["t_host_build"] for r in recs])),
        "host_exposed_s": float(np.median(
            [r["t_get_compute_graph"] for r in recs])),
        "device_step_s": float(np.median(
            [r["t_device_step"] for r in recs])),
        "overlap_fraction": float(np.median(
            [r["overlap_fraction"] for r in recs])),
        "num_batches": int(recs[0]["num_batches"]),
    }


def run(quick: bool = True) -> List[Dict]:
    from repro.data import synthetic_citation2

    splits = synthetic_citation2(scale=0.0008 if quick else 0.002, seed=0)
    kg = splits["train"]
    results = {kind: _measure(splits, kind, quick)
               for kind in ("serial", "async")}
    # per-axis NamedSharding device_put instead of jnp.asarray (on a
    # 1-device box this measures the pure placement-API overhead; on a
    # real mesh it buys the per-device slice placement)
    results["async_sharded"] = _measure(splits, "async", quick,
                                        sharded_transfer=True)
    speedup = results["serial"]["epoch_wall_s"] / \
        max(results["async"]["epoch_wall_s"], 1e-9)

    payload = {
        "bench": "pipeline",
        "graph": {"entities": int(kg.num_entities),
                  "edges": int(kg.num_edges)},
        "config": {"trainers": 4, "batch_size": 256, "num_hops": 2,
                   "hidden_dim": 32, "quick": quick},
        "serial": results["serial"],
        "async": results["async"],
        "async_sharded_transfer": results["async_sharded"],
        "async_speedup": round(speedup, 3),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    rows = []
    for kind in ("serial", "async", "async_sharded"):
        r = results[kind]
        rows.append({
            "name": kind,
            "us_per_call": r["epoch_wall_s"] / max(r["num_batches"], 1)
            * 1e6,
            "epoch_wall_s": round(r["epoch_wall_s"], 3),
            "host_exposed_s": round(r["host_exposed_s"], 3),
            "overlap": round(r["overlap_fraction"], 3),
        })
    rows.append({
        "name": "speedup",
        "us_per_call": 0.0,
        "async_over_serial": round(speedup, 3),
    })
    return rows


# ---------------------------------------------------------------------- #
# Sharded entity table: gather+exchange time, table bytes per device
# ---------------------------------------------------------------------- #
def _time_gather(fn, *args, iters: int = 30) -> float:
    import jax
    fn(*args)[0].block_until_ready()           # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run_embedding(quick: bool = True) -> List[Dict]:
    """Dense replicated gather vs shard-local gather + exchange at 1-8
    model shards (simulated mesh).  Per-device table bytes must shrink
    ∝ 1/num_shards — that is the capacity the sharding buys."""
    import jax
    import jax.numpy as jnp
    from repro.sharding.embedding import (
        ShardedTableLayout, plan_local_gather, shard_table, sharded_gather,
    )

    v, d = (20_000, 64) if quick else (200_000, 128)
    batch = 4096
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    ids = rng.integers(0, v, size=batch).astype(np.int32)

    dense_us = _time_gather(
        jax.jit(lambda t, i: (t[i],)), table, jnp.asarray(ids)) * 1e6

    shards_out = []
    for s in (1, 2, 4, 8):
        layout = ShardedTableLayout(v, s)
        sh = shard_table(table, layout)
        li, ow = plan_local_gather(layout, ids)
        us = _time_gather(
            jax.jit(lambda t, i, o: (sharded_gather(t, i, o),)),
            sh, jnp.asarray(li), jnp.asarray(ow)) * 1e6
        shards_out.append({
            "num_shards": s,
            "gather_exchange_us": round(us, 2),
            "table_bytes_per_device": layout.bytes_per_shard(d),
            "rows_per_shard": layout.rows_per_shard,
        })

    payload = {
        "bench": "embedding",
        "table": {"entities": v, "dim": d, "batch_gather": batch,
                  "dense_bytes": v * d * 4, "quick": quick},
        "dense_gather_us": round(dense_us, 2),
        "sharded": shards_out,
    }
    with open(EMBED_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")

    rows = [{"name": "dense", "us_per_call": round(dense_us, 2),
             "table_mib_per_device": round(v * d * 4 / 2**20, 2)}]
    for r in shards_out:
        rows.append({
            "name": f"sharded_{r['num_shards']}",
            "us_per_call": r["gather_exchange_us"],
            "table_mib_per_device":
                round(r["table_bytes_per_device"] / 2**20, 2),
        })
    return rows


if __name__ == "__main__":
    print("\n".join(emit(run(), "pipeline")))
    print("\n".join(emit(run_embedding(), "embedding")))
