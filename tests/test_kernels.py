"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.rgcn_message import basis_message, segment_sum_onehot


def _mk(rng, v, e, d_in, d_out, nb, r, dtype=np.float32):
    return dict(
        h=jnp.asarray(rng.normal(size=(v, d_in)), dtype),
        src=jnp.asarray(rng.integers(0, v, e), jnp.int32),
        rel=jnp.asarray(rng.integers(0, r, e), jnp.int32),
        dst=jnp.asarray(rng.integers(0, v, e), jnp.int32),
        mask=jnp.asarray(rng.random(e) > 0.15),
        bases=jnp.asarray(rng.normal(size=(nb, d_in, d_out)) * 0.2, dtype),
        coeffs=jnp.asarray(rng.normal(size=(r, nb)), dtype),
    )


SHAPES = [
    (64, 200, 16, 16, 2, 5),
    (128, 512, 32, 48, 3, 11),
    (300, 1024, 75, 75, 2, 474),    # paper's FB15k-237 dims (2×237 rels)
    (33, 129, 8, 8, 1, 2),          # non-aligned
]


@pytest.mark.parametrize("v,e,d_in,d_out,nb,r", SHAPES)
def test_rgcn_kernel_allclose(v, e, d_in, d_out, nb, r):
    rng = np.random.default_rng(v + e)
    a = _mk(rng, v, e, d_in, d_out, nb, r)
    got = ops.rgcn_message_basis(a["h"], a["src"], a["rel"], a["dst"],
                                 a["mask"], a["bases"], a["coeffs"])
    want = ref.rgcn_message_ref(a["h"], a["src"], a["rel"], a["dst"],
                                a["mask"], a["bases"], a["coeffs"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_rgcn_kernel_grads_match_ref():
    rng = np.random.default_rng(3)
    a = _mk(rng, 50, 150, 16, 16, 2, 4)

    def f_kernel(h, bases, coeffs):
        return ops.rgcn_message_basis(
            h, a["src"], a["rel"], a["dst"], a["mask"], bases, coeffs).sum()

    def f_ref(h, bases, coeffs):
        return ref.rgcn_message_ref(
            h, a["src"], a["rel"], a["dst"], a["mask"], bases, coeffs).sum()

    gk = jax.grad(f_kernel, (0, 1, 2))(a["h"], a["bases"], a["coeffs"])
    gr = jax.grad(f_ref, (0, 1, 2))(a["h"], a["bases"], a["coeffs"])
    for x, y in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    v=st.integers(8, 200), e=st.integers(8, 600),
    d=st.sampled_from([8, 16, 32, 75]), nb=st.integers(1, 3),
    r=st.integers(1, 12), seed=st.integers(0, 99),
)
def test_property_rgcn_kernel(v, e, d, nb, r, seed):
    rng = np.random.default_rng(seed)
    a = _mk(rng, v, e, d, d, nb, r)
    got = ops.rgcn_message_basis(a["h"], a["src"], a["rel"], a["dst"],
                                 a["mask"], a["bases"], a["coeffs"])
    want = ref.rgcn_message_ref(a["h"], a["src"], a["rel"], a["dst"],
                                a["mask"], a["bases"], a["coeffs"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_basis_message_bf16():
    rng = np.random.default_rng(0)
    e, d, nb = 256, 32, 2
    h_t = jnp.asarray(rng.normal(size=(e, d)), jnp.bfloat16)
    coef = jnp.asarray(rng.normal(size=(e, nb)), jnp.bfloat16)
    bases = jnp.asarray(rng.normal(size=(nb, d, d)) * 0.1, jnp.bfloat16)
    mask = jnp.ones(e, bool)
    got = basis_message(h_t, coef, bases, mask)
    want = ref.basis_message_ref(h_t.astype(jnp.float32),
                                 coef.astype(jnp.float32),
                                 bases.astype(jnp.float32), mask)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), rtol=0.05, atol=0.05)


def test_segment_sum_sorted_and_unsorted():
    rng = np.random.default_rng(1)
    e, v, d = 512, 256, 16
    msg = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    mask = jnp.asarray(rng.random(e) > 0.2)
    for s in (seg, jnp.sort(seg)):
        agg, deg = segment_sum_onehot(msg, s, mask, v)
        wagg, wdeg = ref.segment_mean_ref(msg, s, mask, v)
        np.testing.assert_allclose(np.asarray(agg), np.asarray(wagg),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(deg[:, 0]),
                                   np.asarray(wdeg), rtol=1e-6, atol=0)


KGE_SHAPES = [(32, 100, 16), (128, 1000, 76), (200, 333, 32), (1, 128, 64)]


@pytest.mark.parametrize("b,c,d", KGE_SHAPES)
def test_kge_score_query_form_allclose(b, c, d):
    """Raw query-form kernel vs oracle, both epilogue families."""
    from repro.kernels.kge_score import EPILOGUES
    rng = np.random.default_rng(b * c)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    cand = jnp.asarray(rng.normal(size=(c, d)), jnp.float32)
    qb = jnp.asarray(rng.random(b), jnp.float32)
    cb = jnp.asarray(rng.random(c), jnp.float32)
    bias = jnp.asarray(
        np.where(rng.random((b, c)) < 0.1, -1e9, 0.0), jnp.float32)
    for epi in EPILOGUES:
        got = ops.kge_score_padded(q, cand, bias, qb, cb, epilogue=epi)
        want = ref.kge_score_ref(q, cand, bias, qb, cb, epilogue=epi)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_kge_score_no_bias():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(10, 8)), jnp.float32)
    cand = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    got = ops.kge_score_padded(q, cand)
    want = ref.kge_score_ref(q, cand)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,c,d", [(32, 100, 16), (130, 280, 24)])
def test_kge_rank_scores_every_decoder(b, c, d):
    """Decoder.rank_scores (Pallas) vs score_against_candidates (XLA) for
    every registered decoder — a decoder silently dropping off the kernel
    path fails here before it fails the bench gate."""
    from repro.models.decoders import (
        init_decoder_params, registered_decoders, get_decoder,
        score_against_candidates,
    )
    rng = np.random.default_rng(b + c)
    h = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    rel = jnp.asarray(rng.integers(0, 7, b), jnp.int32)
    cand = jnp.asarray(rng.normal(size=(c, d)), jnp.float32)
    bias = jnp.asarray(
        np.where(rng.random((b, c)) < 0.1, -1e9, 0.0), jnp.float32)
    for name in registered_decoders():
        dec = get_decoder(name)
        p = init_decoder_params(jax.random.PRNGKey(3), name, 7, d)
        got = dec.rank_scores(p, h, rel, cand, bias)
        want = score_against_candidates(p, name, h, rel, cand, bias)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


# ---------------------------------------------------------------------- #
# Chunked WKV kernel (RWKV-6 time-mix core)
# ---------------------------------------------------------------------- #
WKV_SHAPES = [(8, 64, 16, 16), (16, 128, 32, 32), (3, 50, 8, 16),
              (8, 64, 64, 64)]


@pytest.mark.parametrize("bh,s,hd,chunk", WKV_SHAPES)
def test_wkv_kernel_allclose(bh, s, hd, chunk):
    from repro.kernels.ops import wkv_chunked_op
    from repro.kernels.ref import wkv_chunk_ref
    rng = np.random.default_rng(bh * s)
    r = jnp.asarray(rng.normal(size=(bh, s, hd)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, s, hd)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, s, hd)) * 0.5, jnp.float32)
    lw = jnp.asarray(-np.exp(rng.normal(size=(bh, s, hd)) * 0.3 - 3),
                     jnp.float32)
    u = jnp.asarray(rng.normal(size=(bh, hd)) * 0.1, jnp.float32)
    got = wkv_chunked_op(r, k, v, lw, u, chunk=chunk)
    want = wkv_chunk_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(bh=st.integers(1, 12), s=st.integers(4, 80),
       hd=st.sampled_from([8, 16]), seed=st.integers(0, 50))
def test_property_wkv_kernel(bh, s, hd, seed):
    from repro.kernels.ops import wkv_chunked_op
    from repro.kernels.ref import wkv_chunk_ref
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(bh, s, hd)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, s, hd)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, s, hd)) * 0.3, jnp.float32)
    lw = jnp.asarray(-np.exp(rng.normal(size=(bh, s, hd)) * 0.2 - 3),
                     jnp.float32)
    u = jnp.asarray(rng.normal(size=(bh, hd)) * 0.1, jnp.float32)
    got = wkv_chunked_op(r, k, v, lw, u, chunk=16)
    want = wkv_chunk_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ====================================================================== #
# Fused sharded-table gather (sharded_gather.py / ops.fused_sharded_gather)
# ====================================================================== #
def _sharded_setup(rng, n, d, s, v):
    from repro.sharding.embedding import (
        ShardedTableLayout, plan_local_gather, shard_table,
    )
    dense = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    lay = ShardedTableLayout(n, s)
    table = shard_table(dense, lay)
    ids = np.asarray(rng.integers(0, n, v), np.int32)
    local, owned = plan_local_gather(lay, ids)
    return dense, lay, table, ids, jnp.asarray(local), jnp.asarray(owned)


@pytest.mark.parametrize("s,n,d,v", [
    (1, 256, 8, 128), (2, 256, 8, 128), (4, 300, 16, 256),
])
def test_fused_gather_kernel_bitwise_vs_xla_and_ref(s, n, d, v):
    """The Pallas gather kernel (interpret), the XLA lowering the CPU path
    uses, and the original take->mask->sum chain all agree BITWISE."""
    from repro.kernels.sharded_gather import fused_gather
    rng = np.random.default_rng(s * n)
    dense, lay, table, ids, local, owned = _sharded_setup(rng, n, d, s, v)
    flat, anyo = ops.flat_gather_plan(local, owned, lay.rows_per_shard)
    flat_table = table.reshape(-1, d)
    kern = fused_gather(flat_table, flat, anyo, interpret=True)
    xla = jnp.where(anyo[:, None], flat_table[flat], 0.0)
    chain = ref.sharded_gather_ref(table, local, owned)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(xla))
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(chain))
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(dense[ids]))


def test_fused_gather_kernel_masks_unowned_rows():
    """Dedup-plan padding: slots no shard owns must gather exact zeros."""
    from repro.kernels.sharded_gather import fused_gather
    rng = np.random.default_rng(7)
    table = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    flat = jnp.asarray([3, 0, 5, 0], jnp.int32)
    anyo = jnp.asarray([True, False, True, False])
    out = np.asarray(fused_gather(table, flat, anyo, interpret=True))
    np.testing.assert_array_equal(out[0], np.asarray(table[3]))
    np.testing.assert_array_equal(out[2], np.asarray(table[5]))
    assert (out[1] == 0).all() and (out[3] == 0).all()


@pytest.mark.parametrize("s,n,d,v", [(2, 256, 8, 128), (4, 256, 16, 256)])
def test_scatter_add_kernel_matches_ref(s, n, d, v):
    from repro.kernels.sharded_gather import scatter_add_onehot
    rng = np.random.default_rng(s + v)
    _, lay, _, _, local, owned = _sharded_setup(rng, n, d, s, v)
    flat, anyo = ops.flat_gather_plan(local, owned, lay.rows_per_shard)
    g = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    got = scatter_add_onehot(g, flat, anyo, lay.padded_rows, interpret=True)
    want = ref.sharded_scatter_add_ref(g, flat, anyo, lay.padded_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_fused_sharded_gather_grads_bitwise_vs_dense():
    """The custom VJP performs the SAME single scatter-add as the dense
    gather's VJP — gradients are bitwise equal, duplicates included."""
    from repro.sharding.embedding import unshard_table
    rng = np.random.default_rng(11)
    n, d, s = 300, 16, 4
    dense, lay, table, _, _, _ = _sharded_setup(rng, n, d, s, 8)
    ids = np.asarray([7, 7, 7, 0, n - 1, 7, 0, 5], np.int32)  # heavy dups
    from repro.sharding.embedding import plan_local_gather
    local, owned = plan_local_gather(lay, ids)
    local, owned = jnp.asarray(local), jnp.asarray(owned)
    w = jnp.arange(1.0, d + 1)
    g_sh = jax.grad(lambda t: jnp.sum(jnp.tanh(
        ops.fused_sharded_gather(t, local, owned)) * w))(table)
    g_d = jax.grad(lambda t: jnp.sum(jnp.tanh(t[ids]) * w))(dense)
    np.testing.assert_array_equal(
        np.asarray(unshard_table(g_sh, n)), np.asarray(g_d))
