"""End-to-end system behaviour (paper Algorithm 1 + §4.5).

These are the paper-level integration tests: full distributed pipeline
(partition → expand → sample → batch → AllReduce train → filtered eval) at a
scale that runs on CPU in seconds.
"""
import numpy as np

from repro.data import synthetic_citation2, synthetic_fb15k
from repro.training import KGETrainer, TrainConfig


def test_fullbatch_training_learns():
    """FB15k-237-style: full edge batch, learned embeddings (paper §4.4)."""
    splits = synthetic_fb15k(scale=0.015, seed=0)
    tr = KGETrainer(splits, TrainConfig(
        num_trainers=2, epochs=8, hidden_dim=24, batch_size=None,
        learning_rate=0.05))
    hist = tr.fit()
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.01
    m = tr.evaluate("test")
    assert m["test_mrr"] > 0.03        # way above random (1/log-n scale)
    assert 0 <= m["test_hits@10"] <= 1


def test_minibatch_training_learns():
    """ogbl-citation2-style: features + edge mini-batch (paper §4.4)."""
    splits = synthetic_citation2(scale=0.0003, seed=0)
    tr = KGETrainer(splits, TrainConfig(
        num_trainers=2, epochs=3, hidden_dim=16, batch_size=128,
        num_negatives=1, learning_rate=0.01))
    hist = tr.fit()
    assert hist[-1]["loss"] < hist[0]["loss"]
    # timing instrumentation present (Fig. 6 components)
    assert hist[0]["t_get_compute_graph"] > 0
    assert hist[0]["num_batches"] >= 1


def test_partition_count_changes_batches_not_quality():
    """§4.5.4: fixed batch size across trainers ⇒ fewer batches per trainer
    as trainers grow (the mechanism behind the paper's speedup)."""
    splits = synthetic_fb15k(scale=0.015, seed=1)
    counts = {}
    for p in (1, 2, 4):
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=p, epochs=1, hidden_dim=16, batch_size=256,
            learning_rate=0.05))
        rec = tr.train_epoch()
        counts[p] = rec["num_batches"]
    assert counts[1] >= counts[2] >= counts[4]
    assert counts[4] < counts[1]


def test_kernel_path_matches_ref_training():
    """use_kernel=True (Pallas message passing) trains to the same loss
    trajectory as the jnp reference path."""
    splits = synthetic_fb15k(scale=0.01, seed=3)
    losses = {}
    for use_kernel in (False, True):
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=2, epochs=3, hidden_dim=16,
            learning_rate=0.05, use_kernel=use_kernel, dropout=0.0))
        hist = tr.fit()
        losses[use_kernel] = [h["loss"] for h in hist]
    np.testing.assert_allclose(losses[False], losses[True],
                               rtol=1e-3, atol=1e-4)


def test_checkpoint_resume_continues(tmp_path):
    """Trainer-level resume is EXACT: ``save_checkpoint`` persists the
    epoch counter and PRNG key next to params + optimizer state, and
    ``restore`` rewinds all four — so the resumed trainer draws the SAME
    per-epoch keys the uninterrupted run does.  The trainer-side state is
    deliberately scrambled before the restore: without the persisted
    epoch + key the negative-sampling / dropout stream would silently
    restart at epoch 1 and the losses diverge."""
    import jax
    splits = synthetic_fb15k(scale=0.01, seed=4)
    cfg = TrainConfig(num_trainers=2, epochs=2, hidden_dim=16,
                      learning_rate=0.05)
    tr = KGETrainer(splits, cfg)
    tr.fit(2)
    path = tr.save_checkpoint(str(tmp_path))
    tr2 = KGETrainer(splits, cfg)
    tr2._epoch = 0
    tr2._key = jax.random.PRNGKey(999)
    assert tr2.restore(path) == 2
    np.testing.assert_array_equal(np.asarray(tr._key),
                                  np.asarray(tr2._key))
    r1 = tr.train_epoch()
    r2 = tr2.train_epoch()
    assert r1["loss"] == r2["loss"]
    for a, b in zip(jax.tree_util.tree_leaves(tr.params),
                    jax.tree_util.tree_leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
