"""Quantized (int8) entity table: property-based differential suite.

Three contracts, each against an independent oracle:

* **Round trip** — ``dequantize(quantize(x))`` is within ``scale/2`` of
  ``x`` per element, for arbitrary row magnitudes (all-zero rows,
  single-element rows, deep-subnormal through near-overflow dynamic
  range), and quantization is idempotent / bitwise identical between the
  numpy (host pipeline) and jax (in-jit) implementations and the
  independent search-table oracle in ``repro.kernels.ref``.
* **Fused-dequant gather** — the production gather over ``(codes,
  scales)`` equals dequantize-then-gather bitwise on CPU, for random
  plans with duplicate and out-of-order ids, on both the XLA lowering
  and the Pallas kernel in interpret mode.
* **Checkpoint round trip** — quantized ⇄ fp32 ⇄ resharded restores
  preserve codes+scales exactly, fp32 → int8 requantizes
  deterministically, and dtype/shape mismatches fail with explicit
  errors.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.ops import dequant_sharded_gather
from repro.sharding.embedding import (
    INT8_QMAX, QuantizedTableLayout, ShardedTableLayout,
    dequantize_rows, dequantize_table, plan_local_gather, quantize_rows,
    quantize_table, shard_table, sharded_dequant_gather,
)
from repro.training.checkpoint import restore_checkpoint, save_checkpoint


def _table(seed: int, rows: int, d: int, emin: int, emax: int,
           zero_row: bool) -> np.ndarray:
    """Random fp32 table with magnitudes spanning ``2^[emin, emax]`` —
    the exponent sweep is the point: uniform floats never exercise the
    subnormal-scale and near-overflow branches of the quantizer."""
    rng = np.random.default_rng(seed)
    lo, hi = sorted((emin, emax))
    exp = rng.uniform(lo, hi, size=(rows, d))
    mant = rng.uniform(1.0, 2.0, size=(rows, d))
    sign = rng.choice([-1.0, 1.0], size=(rows, d))
    x = (sign * mant * np.exp2(exp)).astype(np.float32)
    if zero_row:
        x[0] = 0.0
    return x


# ---------------------------------------------------------------------- #
# round trip + cross-implementation equivalence
# ---------------------------------------------------------------------- #
@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rows=st.integers(1, 16),
       d=st.integers(1, 33), emin=st.integers(-140, 35),
       emax=st.integers(-140, 35), zero_row=st.booleans())
def test_property_round_trip_error_bound(seed, rows, d, emin, emax,
                                         zero_row):
    x = _table(seed, rows, d, emin, emax, zero_row)
    codes, scales = quantize_rows(x)
    assert codes.dtype == np.int8 and scales.dtype == np.float32
    assert np.all(np.abs(codes.astype(np.int32)) <= INT8_QMAX)
    err = np.abs(dequantize_rows(codes, scales) - x)
    # scale is a power of two >= amax/127, so rint never clips and the
    # round-trip error is the rounding error alone: <= scale/2 exactly
    assert np.all(err <= scales[:, None] / 2.0)
    # all-zero rows quantize to scale 0 + zero codes (not a tiny scale)
    zero = np.all(x == 0.0, axis=-1)
    np.testing.assert_array_equal(scales[zero], 0.0)
    np.testing.assert_array_equal(codes[zero], 0)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rows=st.integers(1, 16),
       d=st.integers(1, 33), emin=st.integers(-140, 35),
       emax=st.integers(-140, 35), zero_row=st.booleans())
def test_property_impl_matches_oracle_bitwise(seed, rows, d, emin, emax,
                                              zero_row):
    x = _table(seed, rows, d, emin, emax, zero_row)
    codes_np, scales_np = quantize_rows(x)
    codes_jx, scales_jx = quantize_rows(jnp.asarray(x))
    codes_rf, scales_rf = ref.quantize_rows_ref(jnp.asarray(x))
    # numpy == jax == independent search-table oracle, bitwise
    np.testing.assert_array_equal(codes_np, np.asarray(codes_jx))
    np.testing.assert_array_equal(scales_np, np.asarray(scales_jx))
    np.testing.assert_array_equal(codes_np, np.asarray(codes_rf))
    np.testing.assert_array_equal(scales_np, np.asarray(scales_rf))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rows=st.integers(1, 12),
       d=st.integers(1, 17), emin=st.integers(-140, 35),
       emax=st.integers(-140, 35))
def test_property_quantization_idempotent(seed, rows, d, emin, emax):
    x = _table(seed, rows, d, emin, emax, zero_row=False)
    codes, scales = quantize_rows(x)
    codes2, scales2 = quantize_rows(dequantize_rows(codes, scales))
    np.testing.assert_array_equal(codes, codes2)
    np.testing.assert_array_equal(scales, scales2)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), v=st.integers(1, 64),
       s=st.sampled_from([1, 2, 4]), d=st.integers(1, 19),
       batch=st.integers(1, 48))
def test_property_fused_dequant_gather_matches_ref(seed, v, s, d, batch):
    rng = np.random.default_rng(seed)
    emb = _table(seed + 1, v, d, -20, 20, zero_row=v > 1)
    layout = ShardedTableLayout(v, s)
    codes, scales = quantize_rows(shard_table(emb, layout))
    # ids with duplicates (sampled with replacement) and out-of-order
    # structure (a reversed block appended)
    ids = rng.integers(0, v, size=batch)
    ids = np.concatenate([ids, ids[::-1]])
    li, ow = plan_local_gather(layout, ids)
    li, ow = jnp.asarray(li), jnp.asarray(ow)
    want = np.asarray(ref.dequant_gather_ref(
        jnp.asarray(codes), jnp.asarray(scales), li, ow))
    got_xla = np.asarray(sharded_dequant_gather(
        jnp.asarray(codes), jnp.asarray(scales), li, ow))
    got_pallas = np.asarray(dequant_sharded_gather(
        jnp.asarray(codes), jnp.asarray(scales), li, ow,
        use_kernel=True, interpret=True))
    np.testing.assert_array_equal(got_xla, want)
    np.testing.assert_array_equal(got_pallas, want)
    # equals a dense gather of the dequantized table at the global ids —
    # in the contiguous row-block layout global row g sits at flat row g
    dq_flat = np.asarray(dequantize_rows(codes, scales)).reshape(-1, d)
    np.testing.assert_array_equal(want, dq_flat[ids])


def test_layout_bytes_ratio_below_gate():
    # the acceptance bar: int8 per-device bytes <= 0.3x fp32 at equal
    # shard count, closed form (d + 4) / (4 d) at d=64
    for v, s in [(20_000, 1), (20_000, 2), (11_111, 4), (64, 8)]:
        q = QuantizedTableLayout(v, s)
        f = ShardedTableLayout(v, s)
        assert q.rows_per_shard == f.rows_per_shard
        ratio = q.bytes_per_shard(64) / f.bytes_per_shard(64)
        assert ratio <= 0.3
        assert q.bytes_per_shard(64) == q.rows_per_shard * (64 + 4)


def test_quantize_table_dict_round_trip():
    emb = _table(0, 12, 8, -4, 4, zero_row=True)
    stacked = shard_table(emb, ShardedTableLayout(12, 2))
    q = quantize_table(stacked)
    assert set(q) == {"codes", "scales"}
    codes, scales = quantize_rows(stacked)
    np.testing.assert_array_equal(q["codes"], codes)
    err = np.abs(np.asarray(dequantize_table(q)) - stacked)
    assert np.all(err <= np.asarray(scales)[..., None] / 2.0)


# ---------------------------------------------------------------------- #
# checkpoint round trips
# ---------------------------------------------------------------------- #
V, D = 37, 8


def _quant_tree(emb: np.ndarray, s: int):
    stacked = shard_table(emb, ShardedTableLayout(V, s))
    return {"params": {"entity_embedding": quantize_table(stacked)},
            "w": np.ones((3, 3), np.float32)}


def _fp32_tree(emb: np.ndarray, s: int):
    table = emb if s == 0 else shard_table(emb, ShardedTableLayout(V, s))
    return {"params": {"entity_embedding": table},
            "w": np.ones((3, 3), np.float32)}


@pytest.fixture()
def emb():
    return _table(7, V, D, -6, 6, zero_row=True)


def test_ckpt_quant_reshard_exact(tmp_path, emb):
    # quantized @ 2 shards -> quantized @ 4 shards -> back: codes and
    # scales are pad/trim-reshaped bitwise, never requantized
    path = save_checkpoint(str(tmp_path), 1, _quant_tree(emb, 2))
    _, t4 = restore_checkpoint(path, _quant_tree(emb, 4), entity_rows=V)
    want4 = _quant_tree(emb, 4)["params"]["entity_embedding"]
    np.testing.assert_array_equal(
        t4["params"]["entity_embedding"]["codes"], want4["codes"])
    np.testing.assert_array_equal(
        t4["params"]["entity_embedding"]["scales"], want4["scales"])
    path4 = save_checkpoint(str(tmp_path / "b"), 2, t4)
    _, t2 = restore_checkpoint(path4, _quant_tree(emb, 2), entity_rows=V)
    want2 = _quant_tree(emb, 2)["params"]["entity_embedding"]
    np.testing.assert_array_equal(
        t2["params"]["entity_embedding"]["codes"], want2["codes"])
    np.testing.assert_array_equal(
        t2["params"]["entity_embedding"]["scales"], want2["scales"])


def test_ckpt_quant_to_fp32_is_dequantize(tmp_path, emb):
    path = save_checkpoint(str(tmp_path), 1, _quant_tree(emb, 2))
    _, tree = restore_checkpoint(path, _fp32_tree(emb, 0), entity_rows=V)
    stacked = shard_table(emb, ShardedTableLayout(V, 2))
    codes, scales = quantize_rows(stacked)
    want = np.asarray(dequantize_rows(codes, scales)).reshape(-1, D)[:V]
    np.testing.assert_array_equal(
        tree["params"]["entity_embedding"], want)


def test_ckpt_fp32_to_quant_requantizes_deterministically(tmp_path, emb):
    path = save_checkpoint(str(tmp_path), 1, _fp32_tree(emb, 0))
    _, a = restore_checkpoint(path, _quant_tree(emb, 2), entity_rows=V)
    _, b = restore_checkpoint(path, _quant_tree(emb, 2), entity_rows=V)
    want = _quant_tree(emb, 2)["params"]["entity_embedding"]
    got = a["params"]["entity_embedding"]
    np.testing.assert_array_equal(got["codes"], want["codes"])
    np.testing.assert_array_equal(got["scales"], want["scales"])
    # restoring the same checkpoint twice yields identical bits
    np.testing.assert_array_equal(
        got["codes"], b["params"]["entity_embedding"]["codes"])
    np.testing.assert_array_equal(
        got["scales"], b["params"]["entity_embedding"]["scales"])


def test_ckpt_full_cycle_fp32_quant_reshard_fp32(tmp_path, emb):
    # fp32 dense -> int8 @ 2 -> int8 @ 4 -> fp32 sharded @ 2: the final
    # table is exactly the dequantized image of the single quantization
    p1 = save_checkpoint(str(tmp_path / "1"), 1, _fp32_tree(emb, 0))
    _, q2 = restore_checkpoint(p1, _quant_tree(emb, 2), entity_rows=V)
    p2 = save_checkpoint(str(tmp_path / "2"), 2, q2)
    _, q4 = restore_checkpoint(p2, _quant_tree(emb, 4), entity_rows=V)
    p3 = save_checkpoint(str(tmp_path / "3"), 3, q4)
    _, f2 = restore_checkpoint(p3, _fp32_tree(emb, 2), entity_rows=V)
    stacked = shard_table(emb, ShardedTableLayout(V, 2))
    codes, scales = quantize_rows(stacked)
    np.testing.assert_array_equal(
        f2["params"]["entity_embedding"],
        np.asarray(dequantize_rows(codes, scales)))


def test_ckpt_wrong_code_dtype_errors(tmp_path, emb):
    tree = _quant_tree(emb, 2)
    tree["params"]["entity_embedding"]["codes"] = \
        tree["params"]["entity_embedding"]["codes"].astype(np.int16)
    path = save_checkpoint(str(tmp_path), 1, tree)
    with pytest.raises(ValueError, match="not a quantized table"):
        restore_checkpoint(path, _fp32_tree(emb, 0), entity_rows=V)
    with pytest.raises(ValueError, match="not a quantized table"):
        restore_checkpoint(path, _quant_tree(emb, 4), entity_rows=V)


def test_ckpt_non_f32_source_refuses_requantize(tmp_path, emb):
    tree = _fp32_tree(emb, 0)
    tree["params"]["entity_embedding"] = \
        tree["params"]["entity_embedding"].astype(np.float64)
    path = save_checkpoint(str(tmp_path), 1, tree)
    with pytest.raises(ValueError, match="expected float32"):
        restore_checkpoint(path, _quant_tree(emb, 2), entity_rows=V)


def test_ckpt_vocab_mismatch_errors(tmp_path, emb):
    path = save_checkpoint(str(tmp_path), 1, _quant_tree(emb, 2))
    wrong = _table(8, V + 5, D, -4, 4, zero_row=False)

    def like(s):
        stacked = shard_table(wrong, ShardedTableLayout(V + 5, s))
        return {"params": {"entity_embedding": quantize_table(stacked)},
                "w": np.ones((3, 3), np.float32)}
    with pytest.raises((ValueError, KeyError)):
        restore_checkpoint(path, like(2), entity_rows=V + 5)
