"""Input-pipeline subsystem (async prefetch == serial reference) and the
vectorized host data paths (CSR gather, chunked HDRF, budget pairing)."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    make_synthetic_kg, expand_all, partition_graph, plan_budgets,
)
from repro.core.minibatch import (
    _PartitionCSR, iterate_edge_minibatches, negatives_of_positives,
    sample_epoch_negatives,
)
from repro.core.partition import (
    _vertex_cut_partition_loop, vertex_cut_partition,
)
from repro.data.pipeline import (
    AsyncMinibatchPipeline, BatchShardings, FullGraphPipeline, PipelineStats,
    SerialMinibatchPipeline, make_input_pipeline,
)
from repro.sharding.embedding import ShardedTableLayout


def _expanded(kg, p, seed=0):
    return expand_all(kg, partition_graph(kg, p, "vertex_cut", seed=seed), 2)


def _batches_equal(a, b):
    assert type(a) is type(b)
    for f in dataclasses.fields(a):
        x, y = getattr(a, f.name), getattr(b, f.name)
        assert x.dtype == y.dtype and np.array_equal(x, y), f.name


# ====================================================================== #
# Tentpole acceptance: async pipeline == serial reference, bitwise
# ====================================================================== #
class TestPipelineEquivalence:
    @pytest.mark.parametrize("num_parts", [2, 4])
    @pytest.mark.parametrize("sampler", ["constraint", "global"])
    def test_async_bitwise_matches_serial(self, small_kg, num_parts,
                                          sampler):
        parts = _expanded(small_kg, num_parts)
        budget = plan_budgets(parts, 48, 2, 2, seed=0, sampler=sampler)
        kw = dict(batch_size=48, num_negatives=2, num_hops=2,
                  budget=budget, seed=11, sampler=sampler)
        serial = SerialMinibatchPipeline(parts, **kw)
        asynch = AsyncMinibatchPipeline(parts, prefetch=2, **kw)
        for epoch in (1, 2, 3):
            got_s = list(serial.epoch_batches(epoch))
            got_a = list(asynch.epoch_batches(epoch))
            assert len(got_s) == len(got_a) > 0
            for sb, ab in zip(got_s, got_a):
                _batches_equal(sb, ab)

    def test_stream_is_deterministic_per_epoch(self, small_kg):
        """Same (seed, epoch) → same stream; different epoch → different
        shuffle (the checkpoint-resume contract)."""
        parts = _expanded(small_kg, 2)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        kw = dict(batch_size=32, num_negatives=1, num_hops=2,
                  budget=budget, seed=3)
        p1 = AsyncMinibatchPipeline(parts, **kw)
        p2 = AsyncMinibatchPipeline(parts, **kw)
        for a, b in zip(p1.epoch_batches(5), p2.epoch_batches(5)):
            _batches_equal(a, b)
        e1 = next(iter(p1.epoch_batches(1)))
        e2 = next(iter(p1.epoch_batches(2)))
        assert not np.array_equal(e1.triplets, e2.triplets)

    def test_device_batches_match_host_batches(self, small_kg):
        parts = _expanded(small_kg, 2)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        kw = dict(batch_size=32, num_negatives=1, num_hops=2,
                  budget=budget, seed=7)
        pipe = AsyncMinibatchPipeline(parts, **kw)
        host = list(pipe.epoch_batches(1))
        dev = list(pipe.device_batches(1))
        assert len(host) == len(dev)
        for hb, db in zip(host, dev):
            for f in dataclasses.fields(hb):
                np.testing.assert_array_equal(
                    np.asarray(db[f.name]), getattr(hb, f.name))

    @pytest.mark.parametrize("num_shards", [2, 4])
    def test_async_device_batches_carry_identical_plans(self, small_kg,
                                                        num_shards):
        """serial == async extends to sharded-table batches: the gather
        plan the collator precomputes is part of the equivalence."""
        parts = _expanded(small_kg, 2)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        layout = ShardedTableLayout(small_kg.num_entities, num_shards)
        kw = dict(batch_size=32, num_negatives=1, num_hops=2,
                  budget=budget, seed=13, table_layout=layout)
        serial = SerialMinibatchPipeline(parts, **kw)
        asynch = AsyncMinibatchPipeline(parts, prefetch=2, **kw)
        got_s = list(serial.device_batches(1))
        got_a = list(asynch.device_batches(1))
        assert len(got_s) == len(got_a) > 0
        for sb, ab in zip(got_s, got_a):
            assert set(sb) == set(ab)
            assert "shard_local_ids" in sb and "shard_owned" in sb
            # (P, S, V_b): trainer axis leading, then the shard axis
            assert sb["shard_local_ids"].shape[:2] == (2, num_shards)
            for k in sb:
                np.testing.assert_array_equal(np.asarray(sb[k]),
                                              np.asarray(ab[k]))

    def test_async_stats_overlap_bounds(self, small_kg):
        parts = _expanded(small_kg, 4)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        pipe = make_input_pipeline(
            "async", parts, batch_size=32, num_negatives=1, num_hops=2,
            budget=budget, seed=0)
        n = sum(1 for _ in pipe.epoch_batches(1))
        stats = pipe.last_stats
        assert stats.num_batches == n > 0
        assert stats.host_build_s > 0
        assert stats.warmup_s > 0       # pipeline fill is accounted...
        assert 0.0 <= stats.overlap_fraction() <= 1.0

    def test_worker_error_propagates(self, small_kg):
        parts = _expanded(small_kg, 2)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        pipe = AsyncMinibatchPipeline(
            parts, batch_size=32, num_negatives=1, num_hops=2,
            budget=budget, seed=0)
        pipe.partition_stream = lambda epoch, i: (_ for _ in ()).throw(
            RuntimeError("boom"))
        with pytest.raises(RuntimeError, match="pipeline worker failed"):
            list(pipe.epoch_batches(1))

    def test_unknown_pipeline_kind_rejected(self, small_kg):
        parts = _expanded(small_kg, 2)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        with pytest.raises(ValueError, match="unknown pipeline"):
            make_input_pipeline(
                "turbo", parts, batch_size=32, num_negatives=1,
                num_hops=2, budget=budget)


class TestFullGraphPipeline:
    def test_one_cached_device_batch_per_epoch(self, partitioned):
        from repro.core import pad_partitions
        _, expanded = partitioned
        pipe = FullGraphPipeline(pad_partitions(expanded))
        b1 = list(pipe.device_batches(1))
        b2 = list(pipe.device_batches(2))
        assert len(b1) == len(b2) == 1
        # epoch-invariant: transferred once, reused (identity, not copy)
        assert b1[0]["src"] is b2[0]["src"]
        assert pipe.last_stats.num_batches == 1


# ====================================================================== #
# Vectorized host paths == loop references
# ====================================================================== #
class TestVectorizedCSR:
    def test_matches_loop(self, partitioned):
        _, expanded = partitioned
        rng = np.random.default_rng(0)
        for sp in expanded:
            csr = _PartitionCSR(sp)
            for _ in range(25):
                v = rng.integers(0, sp.num_local_vertices,
                                 size=rng.integers(0, 64))
                got = csr.in_edges_of(v)
                want = csr.in_edges_of_loop(v)
                assert got.dtype == want.dtype
                np.testing.assert_array_equal(got, want)

    def test_empty_and_isolated(self, partitioned):
        _, expanded = partitioned
        csr = _PartitionCSR(expanded[0])
        assert csr.in_edges_of(np.zeros(0, np.int64)).size == 0
        # vertices with no in-edges contribute empty spans
        deg = np.diff(csr.indptr)
        lonely = np.nonzero(deg == 0)[0]
        if lonely.size:
            assert csr.in_edges_of(lonely[:4]).size == 0


class TestChunkedHDRF:
    @pytest.mark.parametrize("p,seed", [(2, 0), (4, 1), (8, 2)])
    def test_matches_loop(self, p, seed):
        kg = make_synthetic_kg(250, 6, 2200,
                               seed=seed).with_inverse_relations()
        chunked = vertex_cut_partition(kg, p, seed=seed, chunk_size=256)
        loop = _vertex_cut_partition_loop(kg, p, seed=seed)
        for a, b in zip(chunked, loop):
            np.testing.assert_array_equal(a.core_edge_ids, b.core_edge_ids)

    def test_matches_loop_tight_cap(self):
        """Balance-cap saturation exercises the -inf masking path."""
        kg = make_synthetic_kg(80, 4, 1500, seed=9).with_inverse_relations()
        chunked = vertex_cut_partition(kg, 4, seed=9, balance_slack=1.0,
                                       chunk_size=128)
        loop = _vertex_cut_partition_loop(kg, 4, seed=9, balance_slack=1.0)
        for a, b in zip(chunked, loop):
            np.testing.assert_array_equal(a.core_edge_ids, b.core_edge_ids)


# ====================================================================== #
# plan_budgets probe pairing (satellite fix)
# ====================================================================== #
class TestBudgetPairing:
    def test_negatives_of_positives_rows(self):
        neg = np.arange(30, dtype=np.int32).reshape(10, 3)  # 5 pos × s=2
        got = negatives_of_positives(neg, np.array([3, 0]), 2)
        np.testing.assert_array_equal(got, neg[[6, 7, 0, 1]])
        assert negatives_of_positives(
            np.zeros((0, 3), np.int32), np.array([0]), 2).shape == (0, 3)

    @pytest.mark.parametrize("sampler", ["constraint", "global"])
    def test_budget_admits_every_epoch_batch(self, small_kg, sampler):
        """The probe now pairs positives with THEIR epoch negatives, so the
        measured maxima cover what the iterator actually builds — a full
        epoch fits the budget on every partition."""
        parts = _expanded(small_kg, 4)
        budget = plan_budgets(parts, 48, 2, 2, seed=0, sampler=sampler)
        for i, sp in enumerate(parts):
            rng = np.random.default_rng(100 + i)
            n = 0
            for _ in iterate_edge_minibatches(rng, sp, 48, 2, 2, budget,
                                              sampler=sampler):
                n += 1           # raises ValueError if a batch overflows
            assert n >= 1

    def test_global_sampler_draws_beyond_core(self, partitioned):
        _, expanded = partitioned
        sp = max(expanded,
                 key=lambda s: s.num_local_vertices - s.num_core_vertices)
        assert sp.num_local_vertices > sp.num_core_vertices
        rng = np.random.default_rng(0)
        neg = sample_epoch_negatives(rng, sp, 8, sampler="global")
        corrupted = np.concatenate([neg[:, 0], neg[:, 2]])
        assert corrupted.max() >= sp.num_core_vertices  # support vertex hit
        with pytest.raises(ValueError, match="unknown negative sampler"):
            sample_epoch_negatives(rng, sp, 1, sampler="nope")


# ====================================================================== #
# Pipeline stats: warm-up split out, only consumed batches counted
# ====================================================================== #
class TestPipelineStatsAccounting:
    def test_overlap_uses_steady_state_only(self):
        """overlap_fraction divides exposed by CONSUMED steady-state build
        time; warm-up lives in its own field and does not inflate it."""
        stats = PipelineStats(host_build_s=2.0, exposed_wait_s=0.5,
                              warmup_s=10.0, num_batches=5)
        assert stats.overlap_fraction() == pytest.approx(0.75)
        # degenerate single-batch epoch: everything is warm-up, overlap 0
        assert PipelineStats(warmup_s=1.0,
                             num_batches=1).overlap_fraction() == 0.0

    def test_serial_first_batch_is_warmup(self, small_kg):
        parts = _expanded(small_kg, 2)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        pipe = SerialMinibatchPipeline(
            parts, batch_size=32, num_negatives=1, num_hops=2,
            budget=budget, seed=0)
        n = sum(1 for _ in pipe.epoch_batches(1))
        stats = pipe.last_stats
        assert stats.num_batches == n
        assert stats.warmup_s > 0
        # serial exposes every steady-state build
        assert stats.exposed_wait_s == stats.host_build_s
        assert stats.overlap_fraction() == 0.0

    def test_unconsumed_prefetch_tail_not_counted(self, small_kg):
        """With a deep prefetch queue and a consumer that stops early, the
        tail of built-but-never-consumed batches must not count toward
        host_build_s (the double-counting that inflated overlap)."""
        parts = _expanded(small_kg, 2)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        deep = AsyncMinibatchPipeline(
            parts, batch_size=32, num_negatives=1, num_hops=2,
            budget=budget, seed=0, prefetch=8)
        it = deep.epoch_batches(1)
        for _ in range(3):          # consume 3 batches, abandon the rest
            next(it)
        it.close()
        shallow_total = deep.last_stats.host_build_s
        # 2 steady-state batches of build time, not 3 + the prefetched tail
        full = AsyncMinibatchPipeline(
            parts, batch_size=32, num_negatives=1, num_hops=2,
            budget=budget, seed=0, prefetch=8)
        n_total = sum(1 for _ in full.epoch_batches(1))
        assert n_total > 3
        assert deep.last_stats.num_batches == 3
        assert shallow_total < full.last_stats.host_build_s
        # same contract on the device path, where the collator thread runs
        # ahead of the consumer: abandoned batches never enter the stats
        dev = AsyncMinibatchPipeline(
            parts, batch_size=32, num_negatives=1, num_hops=2,
            budget=budget, seed=0, prefetch=8)
        it = dev.device_batches(1)
        for _ in range(3):
            next(it)
        it.close()
        assert dev.last_stats.num_batches == 3
        assert dev.last_stats.host_build_s < full.last_stats.host_build_s


# ====================================================================== #
# Sharded-table checkpoints round-trip across layouts
# ====================================================================== #
class TestShardedCheckpointRoundTrip:
    def test_save_sharded_restore_replicated_and_back(self, tmp_path):
        import jax
        from repro.models import KGEConfig, RGCNConfig, init_kge_params
        from repro.training import restore_checkpoint, save_checkpoint
        from repro.sharding.embedding import unshard_table

        def cfg(s):
            return KGEConfig(rgcn=RGCNConfig(
                num_entities=101, num_relations=6, hidden_dim=16,
                num_layers=2, num_bases=2, num_table_shards=s))

        p_dense = init_kge_params(jax.random.PRNGKey(0), cfg(1))
        p_shard = init_kge_params(jax.random.PRNGKey(0), cfg(4))
        assert p_shard["entity_embedding"].shape[0] == 4

        # sharded -> replicated
        path = save_checkpoint(str(tmp_path / "a"), 1, p_shard)
        step, restored = restore_checkpoint(path, p_dense)
        assert step == 1
        for a, b in zip(jax.tree_util.tree_leaves(restored),
                        jax.tree_util.tree_leaves(p_dense)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # replicated -> sharded (and across shard counts)
        path = save_checkpoint(str(tmp_path / "b"), 2, p_dense)
        _, restored = restore_checkpoint(path, p_shard)
        np.testing.assert_array_equal(
            np.asarray(restored["entity_embedding"]),
            np.asarray(p_shard["entity_embedding"]))
        p2 = init_kge_params(jax.random.PRNGKey(0), cfg(2))
        path = save_checkpoint(str(tmp_path / "c"), 3, p_shard)
        _, restored = restore_checkpoint(path, p2)
        np.testing.assert_array_equal(
            unshard_table(np.asarray(restored["entity_embedding"]), 101),
            np.asarray(p_dense["entity_embedding"]))

    def test_non_table_shape_mismatch_still_strict(self, tmp_path):
        from repro.training import restore_checkpoint, save_checkpoint
        path = save_checkpoint(str(tmp_path), 0, {"w": np.zeros((3, 4))})
        with pytest.raises(ValueError, match="shape mismatch"):
            restore_checkpoint(path, {"w": np.zeros((4, 4))})


# ====================================================================== #
# Full-graph pipeline carries an epoch-invariant plan
# ====================================================================== #
class TestFullGraphShardedPlan:
    def test_resident_batch_has_plan(self, partitioned):
        from repro.core import pad_partitions
        _, expanded = partitioned
        pb = pad_partitions(expanded)
        n_ent = int(pb.local_to_global.max()) + 1
        pipe = FullGraphPipeline(
            pb, table_layout=ShardedTableLayout(n_ent, 2))
        (b,) = list(pipe.device_batches(1))
        assert b["shard_local_ids"].shape[:2] == \
            (pb.local_to_global.shape[0], 2)
        # exactly one owner per (trainer, vertex) slot
        np.testing.assert_array_equal(
            np.asarray(b["shard_owned"]).sum(axis=1),
            np.ones(pb.local_to_global.shape))


# ====================================================================== #
# Sharded host→device transfer (tentpole: real-mesh data path)
# ====================================================================== #
class TestShardedTransfer:
    def _shardings(self, data=1, model=1):
        from repro.launch.mesh import make_host_mesh
        return BatchShardings(make_host_mesh(data, model))

    def test_bitwise_identical_to_serial_on_one_device_mesh(self, small_kg):
        """The acceptance contract: per-axis device_put transfer yields
        the bitwise-identical stream to the serial single-device reference
        on a 1-device mesh — gather plans included."""
        parts = _expanded(small_kg, 2)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        layout = ShardedTableLayout(small_kg.num_entities, 2)
        kw = dict(batch_size=32, num_negatives=1, num_hops=2,
                  budget=budget, seed=13, table_layout=layout)
        serial = SerialMinibatchPipeline(parts, **kw)
        asynch = AsyncMinibatchPipeline(parts, prefetch=2,
                                        shardings=self._shardings(), **kw)
        got_s = list(serial.device_batches(1))
        got_a = list(asynch.device_batches(1))
        assert len(got_s) == len(got_a) > 0
        for sb, ab in zip(got_s, got_a):
            assert set(sb) == set(ab)
            for k in sb:
                a, b = np.asarray(sb[k]), np.asarray(ab[k])
                assert a.dtype == b.dtype and np.array_equal(a, b), k

    def test_batches_carry_committed_shardings(self, small_kg):
        """Every batch field lands with the data-axis NamedSharding, and
        the gather-plan blocks with the data×model sharding."""
        parts = _expanded(small_kg, 2)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        sh = self._shardings()
        layout = ShardedTableLayout(small_kg.num_entities, 2)
        pipe = AsyncMinibatchPipeline(
            parts, batch_size=32, num_negatives=1, num_hops=2,
            budget=budget, seed=0, table_layout=layout, shardings=sh)
        batch = next(iter(pipe.device_batches(1)))
        for k, v in batch.items():
            if k in ("shard_local_ids", "shard_owned"):
                assert v.sharding == sh.plan, k
            else:
                assert v.sharding == sh.batch, k

    def test_indivisible_layouts_fail_fast(self, small_kg):
        """A partition count (or table shard count) the mesh axes cannot
        split evenly raises at construction, not from a transfer thread.
        (A 1-device box cannot build a real multi-device mesh, so the axis
        sizes are faked — only the check logic is under test.)"""
        parts = _expanded(small_kg, 3)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)

        class _FakeShardings(BatchShardings):
            def __init__(self, data, model):
                self._d, self._m = data, model
                self.data_axis, self.model_axis = "data", "model"
                self.batch = self.plan = None

            @property
            def data_size(self):
                return self._d

            @property
            def model_size(self):
                return self._m

        with pytest.raises(ValueError, match="partitions"):
            AsyncMinibatchPipeline(
                parts, batch_size=32, num_negatives=1, num_hops=2,
                budget=budget, seed=0, shardings=_FakeShardings(2, 1))
        with pytest.raises(ValueError, match="table shards"):
            AsyncMinibatchPipeline(
                parts, batch_size=32, num_negatives=1, num_hops=2,
                budget=budget, seed=0,
                table_layout=ShardedTableLayout(small_kg.num_entities, 3),
                shardings=_FakeShardings(1, 2))

    def test_fullgraph_resident_batch_sharded(self, partitioned):
        from repro.core import pad_partitions
        _, expanded = partitioned
        pb = pad_partitions(expanded)
        n_ent = int(pb.local_to_global.max()) + 1
        sh = self._shardings()
        plain = FullGraphPipeline(
            pb, table_layout=ShardedTableLayout(n_ent, 2))
        sharded = FullGraphPipeline(
            pb, table_layout=ShardedTableLayout(n_ent, 2), shardings=sh)
        (b_plain,) = list(plain.device_batches(1))
        (b_shard,) = list(sharded.device_batches(1))
        assert set(b_plain) == set(b_shard)
        for k in b_plain:
            np.testing.assert_array_equal(np.asarray(b_plain[k]),
                                          np.asarray(b_shard[k]))
            assert b_shard[k].sharding in (sh.batch, sh.plan)
        # still one resident transfer, reused across epochs
        (b2,) = list(sharded.device_batches(2))
        assert b_shard["src"] is b2["src"]

    def test_trainer_sharded_transfer_matches_plain(self):
        """TrainConfig.sharded_transfer changes batch placement, never the
        math: losses are identical to the single-device transfer."""
        from repro.data import synthetic_citation2
        from repro.training import KGETrainer, TrainConfig
        splits = synthetic_citation2(scale=0.0003, seed=0)
        losses = {}
        for st in (False, True):
            tr = KGETrainer(splits, TrainConfig(
                num_trainers=2, epochs=2, hidden_dim=16, batch_size=128,
                num_negatives=1, learning_rate=0.01, seed=0,
                sharded_transfer=st))
            losses[st] = [h["loss"] for h in tr.fit()]
            tr.close()
        assert losses[False] == losses[True]


# Real 2-device data axis: every partition slice lands on its own device
_TWO_DEVICE_TRANSFER_SCRIPT = """
import numpy as np, jax
assert jax.device_count() == 2, jax.devices()
from repro.core import make_synthetic_kg, expand_all, partition_graph, \\
    plan_budgets
from repro.data.pipeline import (
    AsyncMinibatchPipeline, BatchShardings, SerialMinibatchPipeline,
)
from repro.launch.mesh import make_host_mesh
from repro.sharding.embedding import ShardedTableLayout

kg = make_synthetic_kg(300, 10, 2500, seed=7).with_inverse_relations()
parts = expand_all(kg, partition_graph(kg, 2, "vertex_cut", seed=0), 2)
budget = plan_budgets(parts, 32, 1, 2, seed=0)
layout = ShardedTableLayout(kg.num_entities, 2)
sh = BatchShardings(make_host_mesh(2, 1))   # data=2: one partition each
kw = dict(batch_size=32, num_negatives=1, num_hops=2, budget=budget,
          seed=13, table_layout=layout)
serial = SerialMinibatchPipeline(parts, **kw)
asynch = AsyncMinibatchPipeline(parts, prefetch=2, shardings=sh, **kw)
got_s = list(serial.device_batches(1))
got_a = list(asynch.device_batches(1))
assert len(got_s) == len(got_a) > 0
for sb, ab in zip(got_s, got_a):
    for k in sb:
        # values are bitwise identical to the single-device reference ...
        np.testing.assert_array_equal(np.asarray(sb[k]), np.asarray(ab[k]))
    # ... and each data-axis device holds exactly its own partition's
    # slice of the stacked trainer axis (sharded transfer, not broadcast)
    for k in ("src", "triplets", "gather_global"):
        if k not in ab:
            continue
        host = np.asarray(sb[k])
        shards = sorted(ab[k].addressable_shards,
                        key=lambda s: s.index[0].start or 0)
        assert len(shards) == 2
        for i, s in enumerate(shards):
            np.testing.assert_array_equal(np.asarray(s.data)[0], host[i])
print("TWO_DEVICE_TRANSFER_OK")
"""


@pytest.mark.slow
def test_two_device_sharded_transfer():
    """Force 2 host devices and drive the REAL per-axis device_put: the
    async transfer must place each partition's slice on its own data-axis
    device while staying bitwise identical to the serial reference."""
    import os
    import subprocess
    import sys
    repo = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    proc = subprocess.run(
        [sys.executable, "-c", _TWO_DEVICE_TRANSFER_SCRIPT], cwd=repo,
        env=env, capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "TWO_DEVICE_TRANSFER_OK" in proc.stdout


# ====================================================================== #
# Trainer integration: pipeline choice does not change the math
# ====================================================================== #
class TestTrainerPipelineIntegration:
    def test_serial_and_async_trainers_match(self):
        from repro.data import synthetic_citation2
        from repro.training import KGETrainer, TrainConfig
        splits = synthetic_citation2(scale=0.0003, seed=0)
        losses = {}
        for kind in ("serial", "async"):
            tr = KGETrainer(splits, TrainConfig(
                num_trainers=2, epochs=2, hidden_dim=16, batch_size=128,
                num_negatives=1, learning_rate=0.01, seed=0,
                pipeline=kind))
            hist = tr.fit()
            losses[kind] = [h["loss"] for h in hist]
            assert all(h["num_batches"] >= 1 for h in hist)
        # identical batch streams + identical step ⇒ identical losses
        assert losses["serial"] == losses["async"]
