"""RWKV-6 / RG-LRU scan-vs-step consistency and MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import moe as M
from repro.nn import recurrent as R


class TestRWKV:
    def test_decode_matches_scan(self):
        rng = np.random.default_rng(0)
        b, s, d, hd = 2, 10, 32, 8
        p = R.rwkv_params(jax.random.PRNGKey(0), d, hd)
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        full = R.rwkv_apply(p, x, hd)
        state = R.rwkv_init_state(b, d, hd)
        outs = []
        for t in range(s):
            o, state = R.rwkv_decode(p, x[:, t:t + 1], state, hd)
            outs.append(o)
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=2e-4, atol=2e-5)

    def test_decay_in_unit_interval(self):
        p = R.rwkv_params(jax.random.PRNGKey(1), 16, 8)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(3, 16)), jnp.float32)
        *_, decay = R._rwkv_mix(p, x, jnp.zeros_like(x))
        assert bool((decay > 0).all()) and bool((decay < 1).all())

    def test_state_carries_information(self):
        """Same token, different history ⇒ different output (recurrence)."""
        p = R.rwkv_params(jax.random.PRNGKey(2), 16, 8)
        tok = jnp.ones((1, 1, 16))
        s0 = R.rwkv_init_state(1, 16, 8)
        o1, s1 = R.rwkv_decode(p, tok, s0, 8)
        o2, _ = R.rwkv_decode(p, tok, s1, 8)
        assert float(jnp.abs(o1 - o2).max()) > 1e-6


class TestRGLRU:
    def test_decode_matches_scan(self):
        rng = np.random.default_rng(3)
        b, s, d, w = 2, 9, 16, 24
        p = R.rglru_params(jax.random.PRNGKey(3), d, w)
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        full = R.rglru_apply(p, x)
        state = R.rglru_init_state(b, w)
        outs = []
        for t in range(s):
            o, state = R.rglru_decode(p, x[:, t:t + 1], state)
            outs.append(o)
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=2e-4, atol=2e-5)

    def test_gates_bounded(self):
        p = R.rglru_params(jax.random.PRNGKey(4), 8, 8)
        rng = np.random.default_rng(4)
        xw = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
        a, scale = R._rglru_gates(p, xw)
        assert bool((a > 0).all()) and bool((a < 1).all())
        assert bool((scale >= 0).all()) and bool((scale <= 1).all())


class TestMoE:
    def _params(self, d=16, e=4, ff=32, shared=0, dense=0):
        return M.moe_params(jax.random.PRNGKey(0), d, num_experts=e,
                            d_ff_expert=ff, num_shared=shared,
                            dense_residual_ff=dense)

    def test_topk_sparsity_equivalence(self):
        """Dense-dispatch output == explicit loop over selected experts."""
        rng = np.random.default_rng(5)
        d, e, k = 16, 4, 2
        p = self._params(d=d, e=e)
        x = jnp.asarray(rng.normal(size=(2, 3, d)), jnp.float32)
        out, _ = M.moe_apply(p, x, top_k=k)

        logits = x @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        tv, ti = jax.lax.top_k(probs, k)
        tv = tv / tv.sum(-1, keepdims=True)
        want = np.zeros_like(np.asarray(x))
        for bi in range(2):
            for si in range(3):
                for kk in range(k):
                    ei = int(ti[bi, si, kk])
                    h = np.asarray(x[bi, si]) @ np.asarray(p["w_in"][ei])
                    g = jax.nn.silu(
                        np.asarray(x[bi, si]) @ np.asarray(p["w_gate"][ei]))
                    y = (np.asarray(g) * h) @ np.asarray(p["w_out"][ei])
                    want[bi, si] += float(tv[bi, si, kk]) * y
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4,
                                   atol=1e-5)

    def test_aux_loss_range(self):
        """Load-balance aux is ≥ 1 (perfectly balanced == 1 for top-1)."""
        rng = np.random.default_rng(6)
        p = self._params()
        x = jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)
        _, aux = M.moe_apply(p, x, top_k=1)
        assert float(aux) >= 0.99

    def test_shared_and_dense_branches(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(1, 4, 16)), jnp.float32)
        p0 = self._params()
        p1 = self._params(shared=1)
        p2 = self._params(dense=32)
        o0, _ = M.moe_apply(p0, x, top_k=2)
        o1, _ = M.moe_apply(p1, x, top_k=2)
        o2, _ = M.moe_apply(p2, x, top_k=2)
        assert "shared" in p1 and "dense" in p2
        assert o0.shape == o1.shape == o2.shape
