"""Beyond-paper optimized paths must match their faithful baselines:
chunked WKV == sequential scan; capacity MoE == dense dispatch (ample
capacity); cached cross-K/V decode == recompute decode; 1-D sharding rules
drop the tensor axis."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.nn import decode_step, init_decode_cache, init_params, loss_fn
from repro.nn import moe as M
from repro.nn import recurrent as R


class TestChunkedRWKV:
    @pytest.mark.parametrize("chunk", [8, 16, 32])
    def test_matches_sequential(self, chunk):
        rng = np.random.default_rng(chunk)
        b, s, d, hd = 2, 64, 32, 8
        p = R.rwkv_params(jax.random.PRNGKey(0), d, hd)
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        seq = R.rwkv_apply(p, x, hd)
        chk = R.rwkv_apply_chunked(p, x, hd, chunk=chunk)
        np.testing.assert_allclose(np.asarray(chk), np.asarray(seq),
                                   rtol=1e-3, atol=1e-5)

    def test_grads_match(self):
        rng = np.random.default_rng(1)
        p = R.rwkv_params(jax.random.PRNGKey(0), 16, 8)
        x = jnp.asarray(rng.normal(size=(1, 32, 16)), jnp.float32)
        g1 = jax.grad(lambda q: R.rwkv_apply(q, x, 8).sum())(p)
        g2 = jax.grad(
            lambda q: R.rwkv_apply_chunked(q, x, 8, chunk=16).sum())(p)
        for a, b in zip(jax.tree_util.tree_leaves(g1),
                        jax.tree_util.tree_leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-3, atol=1e-4)

    def test_full_model_loss_matches(self):
        cfg_seq = get_arch("rwkv6-3b").reduced()
        cfg_chk = dataclasses.replace(cfg_seq, rwkv_mode="chunked",
                                      rwkv_chunk=8)
        params = init_params(jax.random.PRNGKey(0), cfg_seq,
                             dtype=jnp.float32)
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}
        l1, _ = loss_fn(params, cfg_seq, batch)
        l2, _ = loss_fn(params, cfg_chk, batch)
        assert float(l1) == pytest.approx(float(l2), rel=1e-4)


class TestCapacityMoE:
    def test_equals_dense_with_ample_capacity(self):
        rng = np.random.default_rng(0)
        p = M.moe_params(jax.random.PRNGKey(0), 16, num_experts=4,
                         d_ff_expert=32, num_shared=1, dense_residual_ff=32)
        x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
        od, _ = M.moe_apply(p, x, top_k=2)
        oc, _ = M.moe_apply_capacity(p, x, top_k=2, capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(oc), np.asarray(od),
                                   rtol=1e-4, atol=1e-5)

    def test_capacity_drops_are_bounded(self):
        """Tight capacity keeps outputs finite and within dense range."""
        rng = np.random.default_rng(1)
        p = M.moe_params(jax.random.PRNGKey(1), 8, num_experts=4,
                         d_ff_expert=16)
        x = jnp.asarray(rng.normal(size=(1, 32, 8)), jnp.float32)
        oc, aux = M.moe_apply_capacity(p, x, top_k=2, capacity_factor=1.0)
        assert bool(jnp.isfinite(oc).all()) and np.isfinite(float(aux))

    def test_full_model_grad_flows(self):
        cfg = dataclasses.replace(get_arch("arctic-480b").reduced(),
                                  moe_dispatch="capacity")
        params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}
        g = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
        assert all(bool(jnp.isfinite(x).all())
                   for x in jax.tree_util.tree_leaves(g))


class TestCrossKVCache:
    def test_cached_decode_matches_recompute(self):
        from repro.nn.attention import cross_kv_cache
        cfg0 = get_arch("whisper-large-v3").reduced()
        cfg1 = dataclasses.replace(cfg0, cache_cross_kv=True)
        params = init_params(jax.random.PRNGKey(0), cfg0,
                             dtype=jnp.float32)
        b = 2
        enc = jnp.asarray(
            np.random.default_rng(0).normal(
                size=(b, cfg0.encoder_frames, cfg0.d_model)) * 0.1,
            jnp.float32)
        c0 = init_decode_cache(cfg0, b, 16, dtype=jnp.float32)
        c1 = init_decode_cache(cfg1, b, 16, dtype=jnp.float32)
        c0["encoder_out"] = enc
        c1["encoder_out"] = enc
        gp = params["groups"][0]
        c1["groups"][0]["cross_kv"] = jax.vmap(
            lambda lp: cross_kv_cache(
                lp["cross_attn"], enc, num_kv_heads=cfg1.num_heads,
                head_dim=cfg1.resolved_head_dim))(gp)
        tok = jnp.ones((b, 1), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        l0, _ = decode_step(params, cfg0, tok, c0, pos)
        l1, _ = decode_step(params, cfg1, tok, c1, pos)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                                   rtol=1e-4, atol=1e-5)


class TestShardingModes:
    def test_1d_drops_tensor_axis(self):
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import spec_for_param
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        s2d = spec_for_param(("attn", "w_q"), (64, 128), mesh, mode="2d")
        s1d = spec_for_param(("attn", "w_q"), (64, 128), mesh, mode="1d")
        assert s2d == P("data", "model")
        assert s1d == P("data", None)

    def test_model_flops_analytic(self):
        """MODEL_FLOPS sanity: train ≈ 3× prefill per token; moe active
        discount applied."""
        from repro.launch import specs as S
        cfg = get_arch("glm4-9b")
        tr = S.model_flops(cfg, S.INPUT_SHAPES["train_4k"])
        pf = S.model_flops(cfg, S.INPUT_SHAPES["prefill_32k"])
        tokens_tr = 256 * 4096
        tokens_pf = 32 * 32768
        # per-token: train = 6N + attn(4k), prefill = 2N + attn(32k);
        # the 3:1 param-term ratio is diluted by the longer prefill
        # attention span, so the measured ratio sits in (2, 3)
        ratio = (tr / tokens_tr) / (pf / tokens_pf)
        assert 2.0 < ratio < 3.0

    def test_scan_trip_counts(self):
        from repro.launch.specs import scan_trip_count
        assert scan_trip_count(get_arch("qwen3-32b")) == 64
        assert scan_trip_count(get_arch("recurrentgemma-9b")) == 12
        assert scan_trip_count(get_arch("deepseek-v2-lite-16b")) == 26


class TestWKVKernelMode:
    def test_kernel_mode_matches_sequential(self):
        from repro.nn import recurrent as R
        rng = np.random.default_rng(3)
        p = R.rwkv_params(jax.random.PRNGKey(0), 32, 8)
        x = jnp.asarray(rng.normal(size=(2, 32, 32)), jnp.float32)
        seq = R.rwkv_apply(p, x, 8)
        krn = R.rwkv_apply_kernel(p, x, 8, chunk=16)
        np.testing.assert_allclose(np.asarray(krn), np.asarray(seq),
                                   rtol=1e-4, atol=1e-5)

    def test_kernel_mode_trains(self):
        cfg = dataclasses.replace(get_arch("rwkv6-3b").reduced(),
                                  rwkv_mode="chunked_kernel", rwkv_chunk=8)
        params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}
        (loss, _), g = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        assert np.isfinite(float(loss))
        assert all(bool(jnp.isfinite(x).all())
                   for x in jax.tree_util.tree_leaves(g))
