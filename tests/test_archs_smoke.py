"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED variant
of each family (2 layers, d_model ≤ 256, ≤ 4 experts) runs one forward /
train step and one decode step on CPU — shapes asserted, no NaNs.  The FULL
configs are exercised only by the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, get_arch
from repro.nn import (
    count_params, decode_step, init_decode_cache, init_params, loss_fn,
)

B, S = 2, 16


def _batch(cfg):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.vision_dim)) * 0.1, jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
    if cfg.arch_type == "encdec":
        batch["audio_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)) * 0.1,
            jnp.float32)
    return batch


@pytest.mark.parametrize("name", ASSIGNED)
def test_reduced_train_step(name):
    cfg = get_arch(name).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    assert count_params(params) > 0
    batch = _batch(cfg)
    (loss, aux), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert float(loss) < 2 * np.log(cfg.vocab_size) + 5
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all())
    assert aux["nll"].shape == ()


@pytest.mark.parametrize("name", ASSIGNED)
def test_reduced_decode_step(name):
    cfg = get_arch(name).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    cache = init_decode_cache(cfg, B, 32, dtype=jnp.float32)
    if cfg.arch_type == "encdec":
        cache["encoder_out"] = jnp.zeros(
            (B, cfg.encoder_frames, cfg.d_model), jnp.float32)
    kw = {}
    if cfg.m_rope:
        kw["positions_3d"] = jnp.zeros((B, 1, 3), jnp.int32)
    logits, cache2 = decode_step(
        params, cfg, jnp.ones((B, 1), jnp.int32), cache,
        jnp.zeros((B,), jnp.int32), **kw)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache2) ==
            jax.tree_util.tree_structure(cache))


def test_full_config_dims_exact():
    """The assignment table, verbatim."""
    t = {
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
    }
    for name, (L, d, h, kv, ff, v) in t.items():
        cfg = ARCHS[name]
        assert cfg.num_layers == L, name
        assert cfg.d_model == d, name
        assert cfg.num_heads == h, name
        assert cfg.num_kv_heads == kv, name
        assert cfg.vocab_size == v, name
        if name == "deepseek-v2-lite-16b":
            assert cfg.d_ff_expert == ff
            assert cfg.kv_lora_rank == 512 and cfg.use_mla
        else:
            assert cfg.d_ff == ff, name
    assert ARCHS["arctic-480b"].num_experts == 128
    assert ARCHS["arctic-480b"].top_k == 2
    assert ARCHS["deepseek-v2-lite-16b"].top_k == 6
    assert ARCHS["recurrentgemma-9b"].hybrid_pattern == \
        ("rec", "rec", "attn")
    assert ARCHS["gemma-2b"].head_dim == 256


def test_moe_active_params_fraction():
    """arctic-480b: active params must be far below total (top-2 of 128)."""
    from repro.launch.specs import _param_counts
    total, active = _param_counts(ARCHS["arctic-480b"])
    assert total > 4e11               # ~480B
    assert active < 0.1 * total       # top-2/128 + dense + attn
