"""Serving subsystem tests: the sharded top-k contract, filtered serving,
dynamic batching integrity, and the truncation / k-clamp regressions.

The load-bearing gate is EXACT equality (``==``, not allclose) between the
sharded per-shard-topk + merge path and dense ``jax.lax.top_k`` — the
sharded path never materializes the dense score matrix, so bit-exactness
is the only evidence it computes the same answer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import KnowledgeGraph
from repro.eval.ranking import (
    CSRFilterIndex, _filter_bias, build_filter_index,
)
from repro.kernels.ops import merge_topk, topk_padded
from repro.kernels.ref import topk_ref
from repro.models.decoders import (
    init_decoder_params, registered_decoders, score_against_candidates,
)
from repro.serving import (
    KGEServeEngine, KGEServer, Request, ServeEngine,
    ShardedKGEServer,
)

N_ENT, DIM, N_REL = 57, 8, 3


@pytest.fixture(scope="module")
def emb():
    rng = np.random.default_rng(0)
    e = rng.normal(size=(N_ENT, DIM)).astype(np.float32)
    e[7] = e[19]          # exact duplicate rows -> exact score ties
    e[40] = e[19]
    return e


@pytest.fixture(scope="module")
def graph(emb):
    rng = np.random.default_rng(1)
    return KnowledgeGraph(
        src=rng.integers(0, N_ENT, 400), rel=rng.integers(0, N_REL, 400),
        dst=rng.integers(0, N_ENT, 400), num_entities=N_ENT,
        num_relations=N_REL)


def dense_topk(emb, params, decoder, heads, rels, k, filter_index=None):
    """Dense oracle with serving filter semantics (every known tail of
    (h, r) masked — sentinel t = -1, no held-out true tail)."""
    scores = np.asarray(score_against_candidates(
        params, decoder, jnp.asarray(emb[heads]),
        jnp.asarray(np.asarray(rels).astype(np.int32)), jnp.asarray(emb)))
    if filter_index is not None:
        batch = np.stack(
            [np.asarray(heads, np.int64), np.asarray(rels, np.int64),
             np.full(len(heads), -1, np.int64)], axis=1)
        scores = scores + _filter_bias(filter_index, batch, emb.shape[0])
    v, i = jax.lax.top_k(jnp.asarray(scores), k)
    return np.asarray(v), np.asarray(i)


# ---------------------------------------------------------------------- #
# top-k kernel parity
# ---------------------------------------------------------------------- #
class TestTopkKernel:
    @pytest.mark.parametrize("k", [1, 3, 17])
    def test_kernel_ref_lax_agree(self, k):
        """Pallas kernel == jnp oracle == jax.lax.top_k, values AND
        indices, on tie-heavy data (selection is arithmetic-free)."""
        rng = np.random.default_rng(2)
        scores = rng.normal(size=(5, 40)).astype(np.float32)
        scores[:, 11] = scores[:, 3]       # duplicate columns -> ties
        scores[:, 29] = scores[:, 3]
        scores[2] = 1.0                    # an all-equal row
        s = jnp.asarray(scores)
        kv, ki = topk_padded(s, k, use_kernel=True, interpret=True)
        rv, ri = topk_ref(s, k)
        lv, li = jax.lax.top_k(s, k)
        for got_v, got_i in ((kv, ki), (rv, ri)):
            assert (np.asarray(got_v) == np.asarray(lv)).all()
            assert (np.asarray(got_i) == np.asarray(li)).all()

    def test_neg_inf_rows_drain_in_index_order(self):
        """Repeated -inf entries (filtered/padded candidates) must come
        out in ascending index order like lax.top_k, not loop forever."""
        s = jnp.asarray(np.full((3, 8), -np.inf, np.float32))
        kv, ki = topk_padded(s, 4, use_kernel=True, interpret=True)
        lv, li = jax.lax.top_k(s, 4)
        assert (np.asarray(ki) == np.asarray(li)).all()
        assert np.isneginf(np.asarray(kv)).all()

    def test_k_out_of_range_raises(self):
        s = jnp.zeros((2, 6), jnp.float32)
        with pytest.raises(ValueError):
            topk_padded(s, 0)
        with pytest.raises(ValueError):
            topk_padded(s, 7)

    def test_merge_topk_tie_break_by_position(self):
        """merge picks the lowest CONCAT position among equal values and
        returns that position's id — the shard-order invariant the global
        merge's exactness rests on."""
        vals = jnp.asarray([[5.0, 1.0, 5.0, 3.0]])
        ids = jnp.asarray([[30, 11, 2, 7]], dtype=jnp.int32)
        mv, mi = merge_topk(vals, ids, 3)
        assert np.asarray(mv).tolist() == [[5.0, 5.0, 3.0]]
        assert np.asarray(mi).tolist() == [[30, 2, 7]]


# ---------------------------------------------------------------------- #
# sharded top-k == dense, per decoder / shard count / filter mode
# ---------------------------------------------------------------------- #
class TestShardedTopk:
    @pytest.mark.parametrize("decoder", registered_decoders())
    @pytest.mark.parametrize("shards", [1, 2, 4])
    def test_equal_dense_every_decoder(self, emb, decoder, shards):
        """Sharded per-shard-topk + merge == dense jax.lax.top_k, values
        AND indices, including exact ties and duplicate heads."""
        p = init_decoder_params(jax.random.PRNGKey(0), decoder, N_REL, DIM)
        heads = np.array([0, 7, 19, 19, 50])   # duplicates + tied rows
        rels = np.array([0, 1, 2, 2, 0])
        srv = ShardedKGEServer(emb, p, decoder, num_shards=shards)
        sv, si = srv.topk_tails(heads, rels, 11)
        dv, di = dense_topk(emb, p, decoder, heads, rels, 11)
        assert (si == di).all()
        assert (sv == dv).all()

    def test_k_clamps_to_vocab(self, emb):
        p = init_decoder_params(jax.random.PRNGKey(0), "distmult",
                                N_REL, DIM)
        srv = ShardedKGEServer(emb, p, num_shards=2)
        sv, si = srv.topk_tails(np.array([0]), np.array([0]), k=10 * N_ENT)
        assert si.shape == (1, N_ENT)
        # a full-vocab result is a permutation of all entity ids — layout
        # padding rows never leak out
        assert sorted(si[0].tolist()) == list(range(N_ENT))
        with pytest.raises(ValueError):
            srv.topk_tails(np.array([0]), np.array([0]), k=0)

    @pytest.mark.parametrize("decoder", registered_decoders())
    @pytest.mark.parametrize("shards", [1, 2, 4])
    def test_int8_equal_dense_over_dequantized_table(self, emb, decoder,
                                                     shards):
        """``table_dtype="int8"``: only codes + scales live on device and
        the top-k program dequantizes per shard block — values AND indices
        must EXACTLY equal dense top-k over the dequantized table (the
        dequant is one exact power-of-two multiply per element, so the
        sharded and dense paths see bit-identical scores)."""
        from repro.sharding.embedding import dequantize_rows, quantize_rows
        dq = np.asarray(dequantize_rows(*quantize_rows(emb)))
        p = init_decoder_params(jax.random.PRNGKey(0), decoder, N_REL, DIM)
        heads = np.array([0, 7, 19, 19, 50])   # duplicates + tied rows
        rels = np.array([0, 1, 2, 2, 0])
        srv = ShardedKGEServer(emb, p, decoder, num_shards=shards,
                               table_dtype="int8")
        sv, si = srv.topk_tails(heads, rels, 11)
        dv, di = dense_topk(dq, p, decoder, heads, rels, 11)
        assert (si == di).all()
        assert (sv == dv).all()

    @pytest.mark.parametrize("shards", [1, 2, 4])
    def test_int8_filtered_equal_dense(self, emb, graph, shards):
        """Filtered int8 serving == dense + filter bias over the
        dequantized table, exactly."""
        from repro.sharding.embedding import dequantize_rows, quantize_rows
        dq = np.asarray(dequantize_rows(*quantize_rows(emb)))
        p = init_decoder_params(jax.random.PRNGKey(1), "distmult",
                                N_REL, DIM)
        heads = np.array([0, 3, 7, 19])
        rels = np.array([0, 1, 2, 2])
        csr = CSRFilterIndex.build([graph])
        dv, di = dense_topk(dq, p, "distmult", heads, rels, 9, csr)
        srv = ShardedKGEServer(emb, p, num_shards=shards,
                               filter_index=csr, table_dtype="int8")
        sv, si = srv.topk_tails(heads, rels, 9, filtered=True)
        assert (si == di).all()
        assert (sv == dv).all()

    @pytest.mark.parametrize("shards", [1, 2, 4])
    def test_filtered_equal_dense_csr_and_dict(self, emb, graph, shards):
        """Filtered serving == dense + serving-sentinel filter bias, for
        both the CSR index and the dict reference form."""
        p = init_decoder_params(jax.random.PRNGKey(1), "distmult",
                                N_REL, DIM)
        heads = np.array([0, 3, 7, 19])
        rels = np.array([0, 1, 2, 2])
        csr = CSRFilterIndex.build([graph])
        ref = build_filter_index([graph])
        dv, di = dense_topk(emb, p, "distmult", heads, rels, 9, csr)
        for idx in (csr, ref):
            srv = ShardedKGEServer(emb, p, num_shards=shards,
                                   filter_index=idx)
            sv, si = srv.topk_tails(heads, rels, 9, filtered=True)
            assert (si == di).all()
            assert (sv == dv).all()

    def test_filtered_masks_all_known_tails(self, emb, graph):
        """Serving has no held-out true tail: EVERY known tail of (h, r)
        must be filtered (the sentinel t = -1 semantics), unlike eval
        which un-filters the row's own tail."""
        p = init_decoder_params(jax.random.PRNGKey(1), "distmult",
                                N_REL, DIM)
        csr = CSRFilterIndex.build([graph])
        h, r = int(graph.src[0]), int(graph.rel[0])
        known = set(csr.tails_of(h, r).tolist())
        assert known, "fixture graph must have known tails for the probe"
        srv = ShardedKGEServer(emb, p, num_shards=2, filter_index=csr)
        _, si = srv.topk_tails(np.array([h]), np.array([r]),
                               k=N_ENT - len(known), filtered=True)
        assert not (set(si[0].tolist()) & known)

    def test_filtered_without_index_raises(self, emb):
        p = init_decoder_params(jax.random.PRNGKey(0), "distmult",
                                N_REL, DIM)
        srv = ShardedKGEServer(emb, p, num_shards=2)
        with pytest.raises(ValueError):
            srv.topk_tails(np.array([0]), np.array([0]), filtered=True)

    def test_head_cache_changes_no_bits(self, emb):
        """The hot-entity LRU only short-circuits the gather exchange —
        results are bitwise identical, and repeats actually hit."""
        p = init_decoder_params(jax.random.PRNGKey(2), "distmult",
                                N_REL, DIM)
        heads = np.array([5, 5, 19, 5])
        rels = np.array([0, 1, 2, 0])
        plain = ShardedKGEServer(emb, p, num_shards=2)
        cached = ShardedKGEServer(emb, p, num_shards=2, cache_size=16)
        for _ in range(2):                    # second round is all hits
            pv, pi = plain.topk_tails(heads, rels, 7)
            cv, ci = cached.topk_tails(heads, rels, 7)
            assert (pi == ci).all() and (pv == cv).all()
        assert cached.cache_hits > 0
        assert len(cached._cache) <= 16

    def test_cache_smaller_than_batch_falls_back(self, emb):
        """A batch with more unique heads than cache entries still answers
        correctly (direct-gather fallback)."""
        p = init_decoder_params(jax.random.PRNGKey(2), "distmult",
                                N_REL, DIM)
        heads = np.arange(8)
        rels = np.zeros(8, np.int64)
        plain = ShardedKGEServer(emb, p, num_shards=2)
        tiny = ShardedKGEServer(emb, p, num_shards=2, cache_size=2)
        pv, pi = plain.topk_tails(heads, rels, 5)
        cv, ci = tiny.topk_tails(heads, rels, 5)
        assert (pi == ci).all() and (pv == cv).all()
        assert len(tiny._cache) <= 2


# ---------------------------------------------------------------------- #
# dynamic batching
# ---------------------------------------------------------------------- #
class TestKGEServeEngine:
    def _server(self, emb, **kw):
        p = init_decoder_params(jax.random.PRNGKey(3), "distmult",
                                N_REL, DIM)
        return ShardedKGEServer(emb, p, num_shards=2, **kw), p

    def test_out_of_order_integrity(self, emb):
        """smallest-k-first admission completes requests out of submission
        order; every response must still equal ITS OWN query's dense
        top-k (integrity by identity, not order)."""
        srv, p = self._server(emb)
        eng = KGEServeEngine(srv, slots=3, max_k=9,
                             policy="smallest-k-first")
        rng = np.random.default_rng(4)
        reqs = [eng.submit(int(h), int(r), k=int(k)) for h, r, k in zip(
            rng.integers(0, N_ENT, 10), rng.integers(0, N_REL, 10),
            rng.integers(1, 10, 10))]
        done = eng.run()
        assert len(done) == 10 and all(r.done for r in reqs)
        order = [r.request_id for r in done]
        assert order != sorted(order), "policy must reorder completion"
        for r in reqs:
            dv, di = dense_topk(emb, p, "distmult", np.array([r.head]),
                                np.array([r.relation]), r.k)
            assert (r.tails == di[0]).all() and (r.scores == dv[0]).all()

    def test_fifo_partial_batches_and_padding(self, emb):
        """Queue sizes that don't divide slots still answer every request
        (pad slots are dropped); per-request k slices the shared max_k."""
        srv, p = self._server(emb)
        eng = KGEServeEngine(srv, slots=4, max_k=8)
        reqs = [eng.submit(i % N_ENT, i % N_REL, k=1 + i % 8)
                for i in range(7)]
        done = eng.run()
        assert [r.request_id for r in done] == \
            [r.request_id for r in reqs]          # FIFO preserves order
        assert eng.pending == 0
        for r in reqs:
            assert r.tails.shape == (r.k,)
            _, di = dense_topk(emb, p, "distmult", np.array([r.head]),
                               np.array([r.relation]), r.k)
            assert (r.tails == di[0]).all()

    def test_k_over_max_k_rejected(self, emb):
        srv, _ = self._server(emb)
        eng = KGEServeEngine(srv, slots=2, max_k=5)
        with pytest.raises(ValueError):
            eng.submit(0, 0, k=6)
        with pytest.raises(ValueError):
            eng.submit(0, 0, k=0)

    def test_unknown_policy_rejected(self, emb):
        srv, _ = self._server(emb)
        with pytest.raises(ValueError):
            KGEServeEngine(srv, policy="largest-first")


# ---------------------------------------------------------------------- #
# regressions: LM truncation honesty + dense KGEServer k guard
# ---------------------------------------------------------------------- #
class TestRegressions:
    def test_lm_truncation_reported(self):
        """A request the max_seq horizon cuts off must NOT claim done —
        the old engine silently reported truncated output as complete."""
        from repro.configs import get_arch
        from repro.nn import init_params
        cfg = get_arch("gemma-2b").reduced()
        params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        eng = ServeEngine(cfg, params, slots=2, max_seq=8)
        cut = Request(0, np.array([1, 2, 3], np.int32), max_new_tokens=50)
        fits = Request(1, np.array([1, 2], np.int32), max_new_tokens=3)
        eng.run([cut, fits])
        assert cut.truncated and not cut.done
        assert len(cut.output) < cut.max_new_tokens
        assert fits.done and not fits.truncated
        assert len(fits.output) == 3

    def test_dense_kge_server_k_guard(self, emb):
        """k > vocab clamps instead of crashing; ties break toward the
        lowest entity id on every backend; k < 1 raises."""
        p = init_decoder_params(jax.random.PRNGKey(0), "distmult",
                                N_REL, DIM)
        srv = KGEServer(emb, p)
        top = srv.topk_tails(np.array([0, 1]), np.array([0, 1]),
                             k=10 * N_ENT)
        assert top.shape == (2, N_ENT)
        assert sorted(top[0].tolist()) == list(range(N_ENT))
        with pytest.raises(ValueError):
            srv.topk_tails(np.array([0]), np.array([0]), k=0)
        # deterministic ties: entity 7 == 19 == 40 (duplicate rows) must
        # appear in ascending id order whenever they tie
        _, di = dense_topk(emb, p, "distmult", np.array([7]),
                           np.array([0]), N_ENT)
        got = srv.topk_tails(np.array([7]), np.array([0]), k=N_ENT)
        assert (got[0] == di[0]).all()
        tied = [t for t in got[0].tolist() if t in (7, 19, 40)]
        assert tied == sorted(tied)
