"""Optional-``hypothesis`` shim: property tests skip (instead of the whole
module failing to collect) when hypothesis isn't installed.

Usage in a test module::

    from _hypothesis_compat import given, settings, st

With hypothesis present these are the real objects.  Without it, ``given``
returns a decorator that marks the test skipped, and ``st`` is a stand-in
whose strategy expressions (``st.integers(0, 5)``, ``.map(f)``, …) evaluate
to inert placeholders so module-level decorators still build.  The
fallback emits a ``PytestWarning`` at import so a CI run silently missing
hypothesis (the property suites all skipping) is visible in the warnings
summary instead of looking green by omission.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # clean environment
    import warnings

    import pytest

    HAVE_HYPOTHESIS = False
    warnings.warn(
        "hypothesis is not installed: property-based tests will be "
        "SKIPPED (pip install hypothesis to run them)",
        pytest.PytestWarning, stacklevel=2)

    class _AnyStrategy:
        """Absorbs any strategy construction/chaining."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def decorate(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return decorate

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn
        return decorate
