"""Distributed-training equivalence (paper §2.2, §4.5.1).

The paper's correctness claim: gradient-sharing BEFORE the optimizer step
makes distributed training mathematically equivalent to non-distributed
training on the union of the data.  We verify:

1. vmap+mean gradient == mean of per-trainer grads computed separately;
2. the simulated-trainer step with P=1 == a plain single-step update;
3. end-to-end: distributed (4 trainers) reaches the same loss region and
   comparable eval metrics as 1 trainer (Table 3's structure).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    expand_all, pad_partitions, partition_graph,
)
from repro.data import synthetic_fb15k
from repro.models import KGEConfig, RGCNConfig, fullgraph_loss, \
    init_kge_params
from repro.training import (
    KGETrainer, TrainConfig, adam, make_simulated_train_step,
)


def _setup(small_kg, p):
    parts = partition_graph(small_kg, p, "vertex_cut", seed=0)
    exp = expand_all(small_kg, parts, 2)
    pb = pad_partitions(exp)
    cfg = KGEConfig(rgcn=RGCNConfig(
        num_entities=small_kg.num_entities,
        num_relations=small_kg.num_relations,
        hidden_dim=16, num_layers=2, num_bases=2, dropout=0.0))
    params = init_kge_params(jax.random.PRNGKey(0), cfg)
    batch = {f.name: jnp.asarray(getattr(pb, f.name))
             for f in dataclasses.fields(pb)}
    return cfg, params, batch


def test_grad_average_equals_per_trainer_mean(small_kg):
    cfg, params, batch = _setup(small_kg, 4)
    keys = jax.random.split(jax.random.PRNGKey(1), 4)

    def loss_one(p, b, k):
        return fullgraph_loss(p, cfg, b, k, train=False)

    # per-trainer grads, averaged by hand
    gs = []
    for i in range(4):
        b_i = jax.tree_util.tree_map(lambda x: x[i], batch)
        g = jax.grad(lambda p: loss_one(p, b_i, keys[i])[0])(params)
        gs.append(g)
    manual = jax.tree_util.tree_map(
        lambda *x: sum(x) / 4.0, *gs)

    # vmapped (the simulated AllReduce path)
    def grad_one(p, b, k):
        return jax.grad(lambda q: loss_one(q, b, k)[0])(p)
    vg = jax.vmap(grad_one, in_axes=(None, 0, 0))(params, batch, keys)
    auto = jax.tree_util.tree_map(lambda g: jnp.mean(g, 0), vg)

    for a, b in zip(jax.tree_util.tree_leaves(manual),
                    jax.tree_util.tree_leaves(auto)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_single_trainer_step_equals_plain_step(small_kg):
    cfg, params, batch = _setup(small_kg, 1)
    opt = adam(0.01)
    opt_state = opt.init(params)
    key = jax.random.PRNGKey(2)

    def loss_one(p, b, k):
        return fullgraph_loss(p, cfg, b, k, train=False)

    step = make_simulated_train_step(loss_one, opt)
    p_dist, _, m = step(params, opt_state, batch,
                        key[None].repeat(1, axis=0)
                        if key.ndim else jnp.stack([key]))

    # plain non-distributed update
    b0 = jax.tree_util.tree_map(lambda x: x[0], batch)
    (loss, _), g = jax.value_and_grad(
        lambda p: loss_one(p, b0, key), has_aux=True)(params)
    upd, _ = opt.update(g, opt.init(params), params)
    p_plain = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)

    assert float(m["loss"]) == pytest.approx(float(loss), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p_dist),
                    jax.tree_util.tree_leaves(p_plain)):
        # jit-fused vs eager reduction order: tolerate ~1e-4 relative
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


@pytest.mark.slow
def test_end_to_end_accuracy_parity():
    """Table 3 structure at toy scale: 4-trainer distributed training
    matches 1-trainer metrics within tolerance."""
    splits = synthetic_fb15k(scale=0.015, seed=3)
    results = {}
    for p in (1, 4):
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=p, epochs=12, hidden_dim=24, batch_size=None,
            learning_rate=0.05, seed=0))
        tr.fit()
        results[p] = tr.evaluate("test")
    # distributed must stay within 25% relative of non-distributed MRR
    # (paper: identical to 2 decimals at real scale/epochs)
    assert results[4]["test_mrr"] > 0.5 * results[1]["test_mrr"]
    assert results[4]["test_mrr"] > 0.05


def test_trainer_keys_differ_across_trainers():
    from repro.training import split_trainer_keys
    keys = split_trainer_keys(jax.random.PRNGKey(0), 4, step=3)
    assert keys.shape[0] == 4
    assert len({tuple(np.asarray(k).tolist()) for k in keys}) == 4


# ====================================================================== #
# The REAL shard_map step (make_spmd_train_step)
# ====================================================================== #
@pytest.mark.parametrize("momentum", [0.0, 0.9])
def test_spmd_step_runs_sgd_opt_state(small_kg, momentum):
    """Regression: the spmd step's optimizer-state specs are derived from
    the REAL state structure (``derive_opt_state_specs``), not a
    hardcoded adam-shaped ``OptState(step, mu, nu)`` — plain SGD
    (``mu=None, nu=None``) and momentum SGD (``nu=None``) trace-errored
    before.  On the degenerate 1x1 mesh the step must also stay bitwise
    equal to the vmap simulation."""
    from repro.launch.mesh import make_host_mesh
    from repro.training.distributed import make_spmd_train_step
    from repro.training.optimizer import sgd

    cfg, params, batch = _setup(small_kg, 1)
    opt = sgd(0.05, momentum=momentum)
    keys = jnp.stack([jax.random.PRNGKey(2)])

    def loss_one(p, b, k):
        return fullgraph_loss(p, cfg, b, k, train=False)

    step_spmd = make_spmd_train_step(loss_one, opt, make_host_mesh(1, 1))
    step_sim = make_simulated_train_step(loss_one, opt)
    p1, o1, m1 = step_spmd(params, opt.init(params), batch, keys)
    p2, o2, m2 = step_sim(params, opt.init(params), batch, keys)
    assert float(m1["loss"]) == float(m2["loss"])
    for a, b in zip(jax.tree_util.tree_leaves((p1, o1)),
                    jax.tree_util.tree_leaves((p2, o2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_derive_opt_state_specs_structures():
    from jax.sharding import PartitionSpec as P
    from repro.training.distributed import derive_opt_state_specs
    from repro.training.optimizer import adam, sgd

    params = {"w": jnp.ones((4, 2)), "b": jnp.ones((2,))}
    p_spec = {"w": P("model"), "b": P()}
    for opt, has_mu, has_nu in [(adam(0.1), True, True),
                                (sgd(0.1), False, False),
                                (sgd(0.1, momentum=0.9), True, False)]:
        state = opt.init(params)
        specs = derive_opt_state_specs(state, params, p_spec)
        assert specs.step == P()
        assert (specs.mu == p_spec) if has_mu else (specs.mu is None)
        assert (specs.nu == p_spec) if has_nu else (specs.nu is None)
        # the spec tree must mirror the state tree exactly
        assert (jax.tree_util.tree_structure(specs, is_leaf=lambda x:
                isinstance(x, P)) == jax.tree_util.tree_structure(state))


def test_trainer_spmd_flag_resolution():
    """cfg.spmd tri-state on the single local CPU device: auto stays on
    the simulated step, False stays off, True forces the 1x1-mesh spmd
    step (and errors when the model axis cannot fit)."""
    splits = synthetic_fb15k(scale=0.01, seed=3)
    base = dict(num_trainers=2, epochs=1, hidden_dim=8, num_hops=1,
                batch_size=64)
    assert not KGETrainer(splits, TrainConfig(**base))._spmd
    assert not KGETrainer(splits, TrainConfig(spmd=False, **base))._spmd
    tr = KGETrainer(splits, TrainConfig(spmd=True, **base))
    assert tr._spmd and dict(tr.mesh.shape) == {"data": 1, "model": 1}
    if jax.device_count() == 1:
        with pytest.raises(ValueError, match="model-axis"):
            KGETrainer(splits, TrainConfig(spmd=True, num_table_shards=2,
                                           **base))


def test_trainer_exchange_validation():
    """A sim-only exchange under spmd (and vice versa) fails at trainer
    construction, not deep inside a trace."""
    splits = synthetic_fb15k(scale=0.01, seed=3)
    base = dict(num_trainers=2, epochs=1, hidden_dim=8, num_hops=1,
                batch_size=64, num_table_shards=1)
    with pytest.raises(ValueError, match="not available"):
        KGETrainer(splits, TrainConfig(spmd=True, gather_exchange="fused",
                                       **base))
    with pytest.raises(ValueError, match="not available"):
        KGETrainer(splits, TrainConfig(spmd=False, gather_exchange="psum",
                                       **base))


def test_trainer_forced_spmd_matches_simulated_one_device():
    """spmd=True on the single CPU device (1x1 mesh): per-epoch losses
    float-identical and final params bitwise vs the simulated step."""
    splits = synthetic_fb15k(scale=0.01, seed=3)
    base = dict(num_trainers=2, epochs=2, hidden_dim=8, num_hops=1,
                batch_size=64, seed=0)
    losses, finals = [], []
    for spmd in (False, True):
        tr = KGETrainer(splits, TrainConfig(spmd=spmd, **base))
        losses.append([tr.train_epoch()["loss"] for _ in range(2)])
        finals.append(jax.device_get(tr.params))
        tr.close()
    assert losses[0] == losses[1]
    for a, b in zip(jax.tree_util.tree_leaves(finals[0]),
                    jax.tree_util.tree_leaves(finals[1])):
        np.testing.assert_array_equal(a, b)


# The tentpole gate: on a FORCED 2-device mesh the spmd trainer (auto-on)
# must be float-identical in per-epoch losses and bitwise in final params
# to the simulated trainer, for the mini-batch AND full-graph paths with a
# 2-shard entity table.  Subprocess: the host device count must be forced
# before any jax import.
_SPMD_TRAINER_SCRIPT = """
import jax, numpy as np
assert jax.device_count() == 2, jax.devices()
from repro.data import synthetic_fb15k
from repro.training import KGETrainer, TrainConfig

splits = synthetic_fb15k(scale=0.01, seed=3)
base = dict(num_trainers=2, epochs=2, hidden_dim=8, num_hops=1, seed=0,
            num_table_shards=2)
for bs in (64, None):
    runs = []
    for spmd in (False, None):                 # None = auto -> on
        tr = KGETrainer(splits, TrainConfig(
            batch_size=bs, spmd=spmd, **base))
        assert tr._spmd == (spmd is None)
        if tr._spmd:
            assert dict(tr.mesh.shape) == {"data": 1, "model": 2}
        losses = [tr.train_epoch()["loss"] for _ in range(2)]
        runs.append((losses, jax.device_get(tr.params)))
        tr.close()
    (l_sim, p_sim), (l_spmd, p_spmd) = runs
    assert l_sim == l_spmd, (bs, l_sim, l_spmd)
    for a, b in zip(jax.tree_util.tree_leaves(p_sim),
                    jax.tree_util.tree_leaves(p_spmd)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("OK", "minibatch" if bs else "fullgraph")
print("SPMD_TRAINER_OK")
"""


@pytest.mark.slow
def test_spmd_trainer_two_device_matches_simulated():
    import os
    import subprocess
    import sys
    repo = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    proc = subprocess.run(
        [sys.executable, "-c", _SPMD_TRAINER_SCRIPT], cwd=repo, env=env,
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SPMD_TRAINER_OK" in proc.stdout
