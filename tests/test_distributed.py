"""Distributed-training equivalence (paper §2.2, §4.5.1).

The paper's correctness claim: gradient-sharing BEFORE the optimizer step
makes distributed training mathematically equivalent to non-distributed
training on the union of the data.  We verify:

1. vmap+mean gradient == mean of per-trainer grads computed separately;
2. the simulated-trainer step with P=1 == a plain single-step update;
3. end-to-end: distributed (4 trainers) reaches the same loss region and
   comparable eval metrics as 1 trainer (Table 3's structure).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    expand_all, pad_partitions, partition_graph,
)
from repro.data import synthetic_fb15k
from repro.models import KGEConfig, RGCNConfig, fullgraph_loss, \
    init_kge_params
from repro.training import (
    KGETrainer, TrainConfig, adam, make_simulated_train_step,
)


def _setup(small_kg, p):
    parts = partition_graph(small_kg, p, "vertex_cut", seed=0)
    exp = expand_all(small_kg, parts, 2)
    pb = pad_partitions(exp)
    cfg = KGEConfig(rgcn=RGCNConfig(
        num_entities=small_kg.num_entities,
        num_relations=small_kg.num_relations,
        hidden_dim=16, num_layers=2, num_bases=2, dropout=0.0))
    params = init_kge_params(jax.random.PRNGKey(0), cfg)
    batch = {f.name: jnp.asarray(getattr(pb, f.name))
             for f in dataclasses.fields(pb)}
    return cfg, params, batch


def test_grad_average_equals_per_trainer_mean(small_kg):
    cfg, params, batch = _setup(small_kg, 4)
    keys = jax.random.split(jax.random.PRNGKey(1), 4)

    def loss_one(p, b, k):
        return fullgraph_loss(p, cfg, b, k, train=False)

    # per-trainer grads, averaged by hand
    gs = []
    for i in range(4):
        b_i = jax.tree_util.tree_map(lambda x: x[i], batch)
        g = jax.grad(lambda p: loss_one(p, b_i, keys[i])[0])(params)
        gs.append(g)
    manual = jax.tree_util.tree_map(
        lambda *x: sum(x) / 4.0, *gs)

    # vmapped (the simulated AllReduce path)
    def grad_one(p, b, k):
        return jax.grad(lambda q: loss_one(q, b, k)[0])(p)
    vg = jax.vmap(grad_one, in_axes=(None, 0, 0))(params, batch, keys)
    auto = jax.tree_util.tree_map(lambda g: jnp.mean(g, 0), vg)

    for a, b in zip(jax.tree_util.tree_leaves(manual),
                    jax.tree_util.tree_leaves(auto)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_single_trainer_step_equals_plain_step(small_kg):
    cfg, params, batch = _setup(small_kg, 1)
    opt = adam(0.01)
    opt_state = opt.init(params)
    key = jax.random.PRNGKey(2)

    def loss_one(p, b, k):
        return fullgraph_loss(p, cfg, b, k, train=False)

    step = make_simulated_train_step(loss_one, opt)
    p_dist, _, m = step(params, opt_state, batch,
                        key[None].repeat(1, axis=0)
                        if key.ndim else jnp.stack([key]))

    # plain non-distributed update
    b0 = jax.tree_util.tree_map(lambda x: x[0], batch)
    (loss, _), g = jax.value_and_grad(
        lambda p: loss_one(p, b0, key), has_aux=True)(params)
    upd, _ = opt.update(g, opt.init(params), params)
    p_plain = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)

    assert float(m["loss"]) == pytest.approx(float(loss), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p_dist),
                    jax.tree_util.tree_leaves(p_plain)):
        # jit-fused vs eager reduction order: tolerate ~1e-4 relative
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


@pytest.mark.slow
def test_end_to_end_accuracy_parity():
    """Table 3 structure at toy scale: 4-trainer distributed training
    matches 1-trainer metrics within tolerance."""
    splits = synthetic_fb15k(scale=0.015, seed=3)
    results = {}
    for p in (1, 4):
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=p, epochs=12, hidden_dim=24, batch_size=None,
            learning_rate=0.05, seed=0))
        tr.fit()
        results[p] = tr.evaluate("test")
    # distributed must stay within 25% relative of non-distributed MRR
    # (paper: identical to 2 decimals at real scale/epochs)
    assert results[4]["test_mrr"] > 0.5 * results[1]["test_mrr"]
    assert results[4]["test_mrr"] > 0.05


def test_trainer_keys_differ_across_trainers():
    from repro.training import split_trainer_keys
    keys = split_trainer_keys(jax.random.PRNGKey(0), 4, step=3)
    assert keys.shape[0] == 4
    assert len({tuple(np.asarray(k).tolist()) for k in keys}) == 4
