"""Partitioning invariants (paper §3.2) — unit + hypothesis property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    expand_all, expand_partition, load_balance, make_synthetic_kg,
    pad_partitions, partition_graph, replication_factor,
    verify_self_sufficiency,
)


def _cover_and_disjoint(kg, parts):
    ids = np.concatenate([p.core_edge_ids for p in parts])
    return (np.unique(ids).shape[0] == kg.num_edges,
            ids.shape[0] == np.unique(ids).shape[0])


class TestVertexCut:
    def test_disjoint_cover(self, small_kg):
        parts = partition_graph(small_kg, 4, "vertex_cut", seed=0)
        cover, disjoint = _cover_and_disjoint(small_kg, parts)
        assert cover and disjoint

    def test_balance(self, small_kg):
        parts = partition_graph(small_kg, 4, "vertex_cut", seed=0)
        assert load_balance(parts) <= 1.06   # hard cap in the partitioner

    def test_rf_beats_random(self, small_kg):
        """Table 5's core claim: vertex-cut replicates fewer vertices than
        random edge assignment."""
        vc = partition_graph(small_kg, 4, "vertex_cut", seed=0)
        rnd = partition_graph(small_kg, 4, "random", seed=0)
        assert replication_factor(small_kg, vc) < \
            replication_factor(small_kg, rnd)

    def test_single_partition_identity(self, small_kg):
        parts = partition_graph(small_kg, 1, "vertex_cut", seed=0)
        assert parts[0].num_core_edges() == small_kg.num_edges


class TestEdgeCut:
    def test_cover_with_replication(self, small_kg):
        parts = partition_graph(small_kg, 4, "edge_cut", seed=0)
        cover, disjoint = _cover_and_disjoint(small_kg, parts)
        assert cover
        # edge-cut REPLICATES cut edges (the paper's Fig. 4b pathology)
        total = sum(p.num_core_edges() for p in parts)
        assert total >= small_kg.num_edges


class TestExpansion:
    def test_self_sufficiency(self, small_kg, partitioned):
        _, expanded = partitioned
        for sp in expanded:
            assert verify_self_sufficiency(small_kg, sp)

    def test_core_vertices_first(self, partitioned):
        _, expanded = partitioned
        for sp in expanded:
            core = sp.local_to_global[: sp.num_core_vertices]
            # core-edge endpoints must all be core vertices (< boundary)
            ce = sp.core_edges_local()
            assert (ce[:, 0] < sp.num_core_vertices).all()
            assert (ce[:, 2] < sp.num_core_vertices).all()
            assert np.unique(core).shape[0] == core.shape[0]

    def test_expansion_superset(self, small_kg):
        parts = partition_graph(small_kg, 4, "vertex_cut", seed=0)
        exp = expand_all(small_kg, parts, num_hops=2)
        for p, sp in zip(parts, exp):
            assert sp.num_core_edges == p.num_core_edges()
            assert sp.num_local_edges >= sp.num_core_edges

    def test_more_hops_more_support(self, small_kg):
        parts = partition_graph(small_kg, 4, "vertex_cut", seed=0)
        e1 = expand_all(small_kg, parts, num_hops=1)
        e2 = expand_all(small_kg, parts, num_hops=2)
        for a, b in zip(e1, e2):
            assert b.num_local_edges >= a.num_local_edges


class TestPadding:
    def test_padded_shapes_aligned(self, partitioned):
        _, expanded = partitioned
        pb = pad_partitions(expanded)
        assert pb.padded_edges % 128 == 0
        assert pb.src.shape == (4, pb.padded_edges)
        # masked-out slots don't count as core
        assert not (pb.core_edge_mask & ~pb.edge_mask).any()

    def test_roundtrip_content(self, partitioned):
        _, expanded = partitioned
        pb = pad_partitions(expanded)
        for i, sp in enumerate(expanded):
            e = sp.num_local_edges
            assert (pb.src[i, :e] == sp.src).all()
            assert (pb.edge_mask[i, e:] == False).all()  # noqa: E712


@settings(max_examples=15, deadline=None)
@given(
    n_ent=st.integers(30, 120),
    n_edges=st.integers(60, 500),
    p=st.integers(1, 5),
    hops=st.integers(1, 3),
    strategy=st.sampled_from(["vertex_cut", "edge_cut", "random"]),
    seed=st.integers(0, 5),
)
def test_property_partition_expand(n_ent, n_edges, p, hops, strategy, seed):
    """Any strategy × any graph: cover holds and expansion is
    self-sufficient — the paper's central invariant."""
    kg = make_synthetic_kg(n_ent, 4, n_edges, seed=seed) \
        .with_inverse_relations()
    parts = partition_graph(kg, p, strategy, seed=seed)
    ids = np.unique(np.concatenate([q.core_edge_ids for q in parts]))
    assert ids.shape[0] == kg.num_edges
    for i, part in enumerate(parts):
        sp = expand_partition(kg, part, hops, partition_id=i)
        assert verify_self_sufficiency(kg, sp)
        # replication-factor sanity: core vertices ⊆ local vertices
        assert sp.num_core_vertices <= sp.num_local_vertices


def test_replication_factor_bounds(small_kg):
    parts = partition_graph(small_kg, 4, "vertex_cut", seed=0)
    rf = replication_factor(small_kg, parts)
    assert 0.8 <= rf <= 4.0
    # RF normalizes by ALL of |V| (paper Eq. 7); isolated vertices make the
    # 1-partition RF slightly below 1.0
    rf1 = replication_factor(
        small_kg, partition_graph(small_kg, 1, "vertex_cut"))
    non_isolated = (small_kg.degrees() > 0).mean()
    assert rf1 == pytest.approx(float(non_isolated))
    assert rf >= rf1
