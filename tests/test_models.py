"""RGCN encoder + decoders: unit correctness against dense math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    RGCNConfig, bce_loss, get_decoder, init_decoder_params,
    init_rgcn_params, message_passing_ref, registered_decoders,
    relation_matrices, score_against_candidates, score_triplets,
)


def _toy(seed=0, v=20, e=60, r=4, d=8):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.normal(size=(v, d)), jnp.float32),
        jnp.asarray(rng.integers(0, v, e), jnp.int32),
        jnp.asarray(rng.integers(0, r, e), jnp.int32),
        jnp.asarray(rng.integers(0, v, e), jnp.int32),
        jnp.asarray(np.ones(e, bool)),
    )


class TestRGCNMessagePassing:
    def test_matches_dense_per_relation(self):
        """Basis-decomposed message passing == materialize W_r then loop."""
        h, src, rel, dst, mask = _toy()
        cfg = RGCNConfig(num_entities=20, num_relations=4, hidden_dim=8,
                         num_bases=2)
        params = init_rgcn_params(jax.random.PRNGKey(0), cfg)
        lp = params["layers"][0]
        got = message_passing_ref(h, src, rel, dst, mask, lp, cfg)

        w = relation_matrices(lp, cfg)           # (R, d, d)
        want = np.zeros((20, 8), np.float32)
        deg = np.zeros(20, np.float32)
        for e in range(src.shape[0]):
            s, r, t = int(src[e]), int(rel[e]), int(dst[e])
            want[s] += np.asarray(h[t] @ w[r])
            deg[s] += 1
        want = want / np.maximum(deg, 1)[:, None]
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4,
                                   atol=2e-5)

    def test_mask_zeroes_messages(self):
        h, src, rel, dst, _ = _toy()
        cfg = RGCNConfig(num_entities=20, num_relations=4, hidden_dim=8,
                         num_bases=2)
        params = init_rgcn_params(jax.random.PRNGKey(0), cfg)
        lp = params["layers"][0]
        none = jnp.zeros(src.shape[0], bool)
        out = message_passing_ref(h, src, rel, dst, none, lp, cfg)
        np.testing.assert_allclose(np.asarray(out), 0.0)

    def test_block_decomposition_shape(self):
        cfg = RGCNConfig(num_entities=20, num_relations=4, hidden_dim=8,
                         decomposition="block", num_blocks=2)
        params = init_rgcn_params(jax.random.PRNGKey(0), cfg)
        w = relation_matrices(params["layers"][0], cfg)
        assert w.shape == (4, 8, 8)
        # off-diagonal blocks are zero
        np.testing.assert_allclose(np.asarray(w[:, :4, 4:]), 0.0)


class TestDecoders:
    def test_distmult_symmetry(self):
        dec = get_decoder("distmult")
        p = init_decoder_params(jax.random.PRNGKey(0), "distmult", 3, 8)
        a = jnp.ones((1, 8))
        b = jnp.full((1, 8), 2.0)
        r = jnp.zeros(1, jnp.int32)
        # DistMult is symmetric in (s, t)
        assert float(dec.score(p, a, r, b)[0]) == pytest.approx(
            float(dec.score(p, b, r, a)[0]), rel=1e-6)

    def test_transe_translation(self):
        dec = get_decoder("transe")
        p = {"rel_vec": jnp.asarray([[1.0, 0.0]])}
        s = jnp.asarray([[0.0, 0.0]])
        t = jnp.asarray([[1.0, 0.0]])
        r = jnp.zeros(1, jnp.int32)
        # perfect translation scores ~0 (max); the safe-norm floor is
        # -sqrt(NORM_EPS), NOT the old 1e-9 shift inside the difference
        assert float(dec.score(p, s, r, t)[0]) == pytest.approx(
            0, abs=1e-4)
        t2 = jnp.asarray([[5.0, 0.0]])
        assert float(dec.score(p, s, r, t2)[0]) < -3.9

    def test_rotate_phase_rotation(self):
        """A relation phase of zero is the identity: RotatE degenerates to
        -‖h - t‖, and a perfect match scores ~0."""
        dec = get_decoder("rotate")
        p = {"rel_phase": jnp.zeros((1, 2))}
        s = jnp.asarray([[0.3, -0.2, 0.5, 0.1]])
        r = jnp.zeros(1, jnp.int32)
        assert float(dec.score(p, s, r, s)[0]) == pytest.approx(0, abs=1e-4)
        # a pi rotation negates the head: score vs -s is ~0, vs s is -2‖s‖
        p_pi = {"rel_phase": jnp.full((1, 2), jnp.pi)}
        assert float(dec.score(p_pi, s, r, -s)[0]) == pytest.approx(
            0, abs=1e-3)
        assert float(dec.score(p_pi, s, r, s)[0]) == pytest.approx(
            -2 * float(jnp.linalg.norm(s)), abs=1e-3)

    def test_complex_antisymmetry_possible(self):
        """ComplEx can score (s,r,t) != (t,r,s) — unlike DistMult."""
        dec = get_decoder("complex")
        rng = np.random.default_rng(0)
        p = {"rel_complex": jnp.asarray(rng.normal(size=(1, 8)),
                                        jnp.float32)}
        s = jnp.asarray(rng.normal(size=(1, 8)), jnp.float32)
        t = jnp.asarray(rng.normal(size=(1, 8)), jnp.float32)
        r = jnp.zeros(1, jnp.int32)
        assert abs(float(dec.score(p, s, r, t)[0]) -
                   float(dec.score(p, t, r, s)[0])) > 1e-6

    @pytest.mark.parametrize("name", registered_decoders())
    def test_candidate_scoring_matches_pointwise(self, name):
        rng = np.random.default_rng(0)
        p = init_decoder_params(jax.random.PRNGKey(0), name, 5, 8)
        h = jnp.asarray(rng.normal(size=(30, 8)), jnp.float32)
        trip = jnp.asarray(
            np.stack([rng.integers(0, 30, 12),
                      rng.integers(0, 5, 12),
                      rng.integers(0, 30, 12)], 1), jnp.int32)
        point = score_triplets(p, name, h, trip)
        cand = score_against_candidates(
            p, name, h[trip[:, 0]], trip[:, 1], h)
        picked = cand[jnp.arange(12), trip[:, 2]]
        np.testing.assert_allclose(np.asarray(point),
                                   np.asarray(picked),
                                   rtol=1e-4, atol=1e-4)

    def test_bce_loss_masking(self):
        scores = jnp.asarray([10.0, -10.0, 99.0])
        labels = jnp.asarray([1.0, 0.0, 0.0])
        mask = jnp.asarray([1.0, 1.0, 0.0])     # third is padding
        loss = bce_loss(scores, labels, mask)
        assert float(loss) < 1e-3               # padded bad example ignored

    def test_bce_loss_stable_extremes(self):
        scores = jnp.asarray([1e4, -1e4])
        labels = jnp.asarray([0.0, 1.0])
        mask = jnp.ones(2)
        assert np.isfinite(float(bce_loss(scores, labels, mask)))
