"""Dry-run entry-point integration test (deliverable e) — runs the real CLI
in a subprocess (it must set XLA_FLAGS before jax import, which cannot
happen in this test process) for one cheap (arch × shape × mesh) combo and
validates the emitted record."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_dryrun_cli_single_combo(tmp_path):
    out = tmp_path / "dry.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "rwkv6-3b", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(out), "--force"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout + proc.stderr

    recs = [json.loads(line) for line in out.read_text().splitlines()]
    assert len(recs) == 1
    r = recs[0]
    assert r["status"] == "ok"
    assert r["chips"] == 256
    assert r["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert r["hlo_flops_per_device"] > 0
    assert r["hlo_bytes_per_device"] > 0
    assert r["collective_bytes_per_device"] >= 0
    assert r["memory"]["argument_bytes"] > 0


@pytest.mark.slow
def test_dryrun_cli_skip_rule(tmp_path):
    """long_500k on a full-attention arch must be a recorded skip."""
    out = tmp_path / "dry.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "glm4-9b", "--shape", "long_500k",
         "--mesh", "single", "--out", str(out), "--force"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=240)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    r = json.loads(out.read_text().splitlines()[0])
    assert r["status"] == "skipped"
    assert "full-attention" in r["note"]
