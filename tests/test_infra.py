"""Optimizer, checkpointing, eval ranking, data pipeline, HLO parser,
sharding rules, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import (
    adam, apply_updates, constant_schedule, latest_checkpoint,
    restore_checkpoint, save_checkpoint, sgd, warmup_cosine_schedule,
)


class TestOptimizer:
    def test_adam_converges_quadratic(self):
        opt = adam(0.1)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_adam_first_step_magnitude(self):
        """Bias-corrected Adam's first update == lr in each coordinate."""
        opt = adam(0.01)
        params = {"w": jnp.asarray([1.0])}
        state = opt.init(params)
        upd, _ = opt.update({"w": jnp.asarray([123.0])}, state, params)
        assert float(upd["w"][0]) == pytest.approx(-0.01, rel=1e-3)

    def test_grad_clip(self):
        opt = adam(1.0, grad_clip_norm=1.0)
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        g = {"w": jnp.full(4, 100.0)}
        upd, _ = opt.update(g, state, params)
        assert np.isfinite(np.asarray(upd["w"])).all()

    def test_sgd_momentum(self):
        opt = sgd(0.1, momentum=0.9)
        params = {"w": jnp.asarray([1.0])}
        state = opt.init(params)
        upd1, state = opt.update({"w": jnp.asarray([1.0])}, state, params)
        upd2, state = opt.update({"w": jnp.asarray([1.0])}, state, params)
        assert float(upd2["w"][0]) == pytest.approx(-0.19, rel=1e-4)

    def test_schedules(self):
        s = warmup_cosine_schedule(1.0, 10, 100)
        assert float(s(jnp.asarray(5))) == pytest.approx(0.5)
        assert float(s(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(s(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
        assert float(constant_schedule(0.3)(jnp.asarray(7))) == \
            pytest.approx(0.3)

    def test_bf16_state_dtype(self):
        opt = adam(0.01, state_dtype=jnp.bfloat16)
        params = {"w": jnp.zeros(4, jnp.float32)}
        state = opt.init(params)
        assert state.mu["w"].dtype == jnp.bfloat16


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": [jnp.ones(4), {"c": jnp.zeros((2, 2))}]}
        path = save_checkpoint(str(tmp_path), 7, tree, {"note": "x"})
        assert latest_checkpoint(str(tmp_path)) == path
        step, restored = restore_checkpoint(path, tree)
        assert step == 7
        for x, y in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_gc_keeps_last(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        for s in range(5):
            save_checkpoint(str(tmp_path), s, tree, keep=2)
        files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
        assert len(files) == 2

    def test_shape_mismatch_raises(self, tmp_path):
        path = save_checkpoint(str(tmp_path), 0, {"a": jnp.zeros(3)})
        with pytest.raises(ValueError):
            restore_checkpoint(path, {"a": jnp.zeros(4)})

    def test_gc_keep_zero_keeps_everything(self, tmp_path):
        """keep<=0 is the documented KEEP-ALL contract (the old
        ``ckpts[:-keep] if keep`` only kept-all for exactly 0; a negative
        keep would have deleted the NEWEST checkpoints)."""
        tree = {"a": jnp.zeros(2)}
        for keep in (0, -1):
            for s in range(4):
                save_checkpoint(str(tmp_path), s, tree, keep=keep)
            files = [f for f in os.listdir(tmp_path)
                     if f.endswith(".npz")]
            assert len(files) == 4

    def test_gc_removes_orphaned_manifests(self, tmp_path):
        """A .json manifest whose .npz payload is gone (crashed save,
        out-of-band cleanup) is pruned on the next save — even with
        keep=0 — so it can never shadow a real checkpoint."""
        tree = {"a": jnp.zeros(2)}
        save_checkpoint(str(tmp_path), 1, tree)
        path2 = save_checkpoint(str(tmp_path), 2, tree)
        os.remove(path2)                       # orphan ckpt_00000002.json
        save_checkpoint(str(tmp_path), 3, tree, keep=0)
        names = sorted(os.listdir(tmp_path))
        assert "ckpt_00000002.json" not in names
        assert {"ckpt_00000001.npz", "ckpt_00000001.json",
                "ckpt_00000003.npz", "ckpt_00000003.json"} <= set(names)

    def test_metadata_roundtrip(self, tmp_path):
        from repro.training.checkpoint import read_metadata
        path = save_checkpoint(str(tmp_path), 5, {"a": jnp.zeros(2)},
                               metadata={"epoch": 5, "key": [1, 2]})
        step, meta = read_metadata(path)
        assert step == 5
        assert meta == {"epoch": 5, "key": [1, 2]}


class TestRankingEval:
    def test_known_ranks(self):
        """Hand-crafted embeddings with known ranking."""
        from repro.eval import ranking_metrics
        # entity i has embedding e_i = onehot(i); rel diag all ones;
        # head 0 scores highest against candidate 0
        n, d = 8, 8
        emb = np.eye(n, d, dtype=np.float32)
        table = np.ones((1, d), np.float32)
        tests = np.array([[0, 0, 0]])          # (s=0, r=0, t=0): rank 1
        m = ranking_metrics(emb, {"rel_diag": table}, tests, {})
        assert m["mrr"] == pytest.approx(1.0)
        assert m["hits@1"] == 1.0

    def test_filter_removes_known_positives(self):
        from repro.eval import ranking_metrics
        n, d = 4, 4
        emb = np.eye(n, d, dtype=np.float32) + 0.5
        table = np.ones((1, d), np.float32)
        # without filtering, entity 1 ties/beats others for head 0
        tests = np.array([[0, 0, 2]])
        fidx = {(0, 0): {1, 2}}     # 1 is a known positive -> filtered
        m = ranking_metrics(emb, {"rel_diag": table}, tests, fidx)
        m_nof = ranking_metrics(emb, {"rel_diag": table}, tests, {})
        assert m["mrr"] >= m_nof["mrr"]

    def test_candidate_mode(self):
        from repro.eval import ranking_metrics
        rng = np.random.default_rng(0)
        n, d = 50, 8
        emb = rng.normal(size=(n, d)).astype(np.float32)
        table = np.ones((2, d), np.float32)
        tests = np.array([[0, 0, 1], [2, 1, 3]])
        cands = rng.integers(0, n, (2, 10))
        m = ranking_metrics(emb, {"rel_diag": table}, tests, {}, candidates=cands)
        assert 0 < m["mrr"] <= 1.0


class TestData:
    def test_fb15k_format_loader(self, tmp_path):
        from repro.data import load_fb15k_format
        for split, rows in (("train", ["a\tr1\tb", "b\tr2\tc"]),
                            ("valid", ["a\tr1\tc"]),
                            ("test", ["c\tr2\ta"])):
            (tmp_path / f"{split}.txt").write_text("\n".join(rows) + "\n")
        splits = load_fb15k_format(str(tmp_path))
        assert splits["train"].num_edges == 2
        assert splits["train"].num_entities == 3
        assert splits["test"].num_relations == 2

    def test_synthetic_shapes(self):
        from repro.data import synthetic_citation2, synthetic_fb15k
        s1 = synthetic_fb15k(scale=0.01)
        assert s1["train"].features is None
        s2 = synthetic_citation2(scale=0.0003)
        assert s2["train"].features.shape[1] == 128

    def test_token_stream_deterministic(self):
        from repro.data import TokenStream
        a = next(iter(TokenStream(100, 2, 8, seed=1)))
        b = next(iter(TokenStream(100, 2, 8, seed=1)))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert a["tokens"].shape == (2, 8)


class TestHLOAnalysis:
    HLO = """
HloModule test

%while_body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
  %d = f32[8,8]{1,0} dot(%ar, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (a: f32[16,4]) -> f32[16,4] {
  %a = f32[16,4]{1,0} parameter(0)
  %ag = f32[16,64]{1,0} all-gather(%a), dimensions={1}
  %w = (s32[], f32[8,8]{1,0}) while(%t), condition=%cond.1, body=%while_body.1
}
"""

    def test_collective_loop_scaling(self):
        from repro.sharding.hlo_analysis import collective_stats
        s1 = collective_stats(self.HLO, loop_trip_count=1)
        s10 = collective_stats(self.HLO, loop_trip_count=10)
        # all-reduce inside body: 8*8*4 bytes * 2 (ring) * trip
        assert s1["all-reduce"]["bytes"] == pytest.approx(512)
        assert s10["all-reduce"]["bytes"] == pytest.approx(5120)
        # all-gather in entry: not scaled
        assert s1["all-gather"]["bytes"] == \
            s10["all-gather"]["bytes"] == 16 * 64 * 4

    def test_dot_flops_loop_scaling(self):
        from repro.sharding.hlo_analysis import analyze_hlo
        r1 = analyze_hlo(self.HLO, loop_trip_count=1)
        r5 = analyze_hlo(self.HLO, loop_trip_count=5)
        # dot: 2 * 64 * 8 flops, inside loop
        assert r1["flops"] == pytest.approx(2 * 64 * 8)
        assert r5["flops"] == pytest.approx(5 * 2 * 64 * 8)


class TestShardingRules:
    def test_param_specs_divisible(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import spec_for_param
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        # divisible dims get sharded (axis size 1 divides everything)
        s = spec_for_param(("layers", "attn", "w_q"), (64, 128), mesh)
        assert s == P("data", "model")
        # unknown names replicate
        assert spec_for_param(("foo",), (64,), mesh) == P()

    def test_indivisible_falls_back(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import spec_for_param
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        # shape smaller than rule arity replicates
        assert spec_for_param(("w_q",), (7,), mesh) == P()

    def test_moe_expert_rule(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import spec_for_param
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        s = spec_for_param(("groups", "0", "moe", "w_in"), (4, 16, 32),
                           mesh)
        assert s == P("model", "data", None)


class TestServing:
    def test_engine_greedy_decode(self):
        from repro.configs import get_arch
        from repro.nn import init_params
        from repro.serving import Request, ServeEngine
        cfg = get_arch("gemma-2b").reduced()
        params = init_params(jax.random.PRNGKey(0), cfg,
                             dtype=jnp.float32)
        eng = ServeEngine(cfg, params, slots=2, max_seq=32)
        reqs = [Request(i, np.array([1 + i, 5, 9], np.int32),
                        max_new_tokens=4) for i in range(3)]
        done = eng.run(reqs)
        assert all(r.done for r in done)
        assert all(len(r.output) == 4 for r in done)
        assert all(0 <= t < cfg.vocab_size
                   for r in done for t in r.output)

    def test_kge_server_topk(self):
        from repro.serving import KGEServer
        rng = np.random.default_rng(0)
        emb = rng.normal(size=(40, 8)).astype(np.float32)
        srv = KGEServer(emb, {"rel_diag": np.ones((2, 8), np.float32)})
        top = srv.topk_tails(np.array([0, 1]), np.array([0, 1]), k=5)
        assert top.shape == (2, 5)
        # top-1 must be the argmax of the exact scores
        want = np.argmax(emb @ emb[:2].T, axis=0)
        assert (top[:, 0] == want).all()

    def test_kge_server_every_decoder(self):
        """The serving path carries every registered decoder: top-1 must be
        the argmax of that decoder's exact XLA scores."""
        from repro.models.decoders import (
            init_decoder_params, registered_decoders,
            score_against_candidates,
        )
        from repro.serving import KGEServer
        rng = np.random.default_rng(1)
        emb = rng.normal(size=(50, 8)).astype(np.float32)
        heads, rels = np.array([0, 3, 7]), np.array([0, 1, 2])
        for name in registered_decoders():
            p = init_decoder_params(jax.random.PRNGKey(0), name, 3, 8)
            srv = KGEServer(emb, p, decoder=name)
            top = srv.topk_tails(heads, rels, k=4)
            want = score_against_candidates(
                p, name, jnp.asarray(emb[heads]), jnp.asarray(rels),
                jnp.asarray(emb))
            assert (top[:, 0] == np.argmax(np.asarray(want), axis=1)).all(), \
                name


class TestHLONesting:
    NESTED = """
HloModule nested

%inner_cond.1 (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(8)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%inner_body.1 (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %d = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%outer_cond.1 (q: (s32[], f32[4,4])) -> pred[] {
  %q = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%q), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%outer_body.1 (q: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %q = (s32[], f32[4,4]{1,0}) parameter(0)
  %w = (s32[], f32[4,4]{1,0}) while(%q), condition=%inner_cond.1, body=%inner_body.1
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  %w = (s32[], f32[4,4]{1,0}) while(%t), condition=%outer_cond.1, body=%outer_body.1
}
"""

    def test_nested_trip_product(self):
        """Inner-loop dots scale by outer×inner trip (3×8=24)."""
        from repro.sharding.hlo_analysis import analyze_hlo
        r = analyze_hlo(self.NESTED)
        # dot: 2 * 16 * 4 = 128 flops, × 24
        assert r["flops"] == pytest.approx(128 * 24)

    def test_trip_from_condition_constant(self):
        """Auto-detected trips override the fallback default."""
        from repro.sharding.hlo_analysis import analyze_hlo
        r_default = analyze_hlo(self.NESTED, loop_trip_count=999)
        assert r_default["flops"] == pytest.approx(128 * 24)

    def test_tuple_collective_with_comments(self):
        """Tuple all-reduce types contain /*index=N*/ comments; bytes must
        still parse (regression for the v2 parser bug)."""
        from repro.sharding.hlo_analysis import collective_stats
        hlo = ("ENTRY %m (a: f32[4]) -> f32[4] {\n"
               "%all-reduce = (f32[2,2]{1,0}, f32[8]{0}, f32[2]{0}, "
               "f32[4]{0}, f32[2]{0}, /*index=5*/f32[2]{0}) "
               "all-reduce(%a, %b)\n}\n")
        st = collective_stats(hlo)
        assert st["all-reduce"]["count"] == 1
        assert st["all-reduce"]["bytes"] == 2 * (4 + 8 + 2 + 4 + 2 + 2) * 4


class TestServingMoreArchs:
    @pytest.mark.parametrize("arch", ["qwen2-vl-7b", "deepseek-v2-lite-16b",
                                      "rwkv6-3b"])
    def test_engine_all_families(self, arch):
        from repro.configs import get_arch
        from repro.nn import init_params
        from repro.serving import Request, ServeEngine
        cfg = get_arch(arch).reduced()
        params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        eng = ServeEngine(cfg, params, slots=2, max_seq=24)
        done = eng.run([Request(0, np.array([1, 2], np.int32),
                                max_new_tokens=3)])
        assert done[0].done and len(done[0].output) == 3
