"""Attention variants: chunked==dense equivalence, decode==prefill, MLA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention as A
from repro.nn.layers import apply_m_rope, apply_rope


def _qkv(rng, b, s, h, hkv, hd):
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    return q, k, v


class TestMEA:
    @pytest.mark.parametrize("window", [None, 64])
    @pytest.mark.parametrize("hkv", [1, 2, 4])
    def test_matches_dense(self, window, hkv):
        rng = np.random.default_rng(0)
        b, s, h, hd = 2, 256, 4, 16
        q, k, v = _qkv(rng, b, s, h, hkv, hd)
        dense = A._sdpa(q, k, v, A.causal_mask(s, s, window))
        mea = A._mea(q, k, v, causal=True, window=window,
                     q_chunk=64, k_chunk=64)
        np.testing.assert_allclose(np.asarray(mea), np.asarray(dense),
                                   rtol=2e-4, atol=2e-5)

    def test_non_causal(self):
        rng = np.random.default_rng(1)
        q, k, v = _qkv(rng, 1, 128, 2, 2, 8)
        dense = A._sdpa(q, k, v, None)
        mea = A._mea(q, k, v, causal=False, window=None,
                     q_chunk=32, k_chunk=32)
        np.testing.assert_allclose(np.asarray(mea), np.asarray(dense),
                                   rtol=2e-4, atol=2e-5)

    def test_mixed_value_dim(self):
        """MLA path: v_dim != head_dim."""
        rng = np.random.default_rng(2)
        b, s, h, hd, vd = 1, 128, 2, 24, 16
        q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, h, vd)), jnp.float32)
        mea = A._mea(q, k, v, causal=True, window=None,
                     q_chunk=32, k_chunk=32)
        # dense reference with value dim vd
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        mask = A.causal_mask(s, s)[0]
        scores = jnp.where(mask, scores, -1e30)
        w = jax.nn.softmax(scores, -1)
        want = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, s, h * vd)
        np.testing.assert_allclose(np.asarray(mea), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)


class TestDecodeConsistency:
    def test_gqa_decode_matches_full(self):
        """Decoding tokens one-by-one with the cache must reproduce the
        full-sequence attention output at every position."""
        rng = np.random.default_rng(3)
        b, s, h, hkv, hd, d = 2, 12, 4, 2, 8, 32
        p = A.attn_params(jax.random.PRNGKey(0), d, h, hkv, hd,
                          qkv_bias=True, qk_norm=True)
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        full = A.attention(p, x, num_heads=h, num_kv_heads=hkv, head_dim=hd,
                           positions=positions)
        cache = {"k": jnp.zeros((b, s, hkv, hd)),
                 "v": jnp.zeros((b, s, hkv, hd))}
        outs = []
        for t in range(s):
            o, cache = A.attention_decode(
                p, x[:, t:t + 1], cache, jnp.full((b,), t, jnp.int32),
                num_heads=h, num_kv_heads=hkv, head_dim=hd)
            outs.append(o)
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=1e-4, atol=1e-5)

    def test_mla_decode_matches_full(self):
        rng = np.random.default_rng(4)
        b, s, h, d = 1, 10, 2, 32
        kw = dict(num_heads=h, kv_lora_rank=16, qk_nope_head_dim=8,
                  qk_rope_head_dim=4, v_head_dim=8)
        p = A.mla_params(jax.random.PRNGKey(1), d, h, kv_lora_rank=16,
                         qk_nope_head_dim=8, qk_rope_head_dim=4,
                         v_head_dim=8)
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        full = A.mla_attention(p, x, positions=positions, **kw)
        cache = {"c_kv": jnp.zeros((b, s, 16)),
                 "k_rope": jnp.zeros((b, s, 4))}
        outs = []
        for t in range(s):
            o, cache = A.mla_decode(
                p, x[:, t:t + 1], cache, jnp.full((b,), t, jnp.int32), **kw)
            outs.append(o)
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=1e-4, atol=1e-5)

    def test_sliding_window_decode(self):
        """Window-limited decode attends to at most `window` positions."""
        rng = np.random.default_rng(5)
        b, s, h, hkv, hd, d = 1, 16, 2, 1, 8, 16
        p = A.attn_params(jax.random.PRNGKey(2), d, h, hkv, hd)
        x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        full = A.attention(p, x, num_heads=h, num_kv_heads=hkv,
                           head_dim=hd, positions=positions, window=4)
        cache = {"k": jnp.zeros((b, s, hkv, hd)),
                 "v": jnp.zeros((b, s, hkv, hd))}
        outs = []
        for t in range(s):
            o, cache = A.attention_decode(
                p, x[:, t:t + 1], cache, jnp.full((b,), t, jnp.int32),
                num_heads=h, num_kv_heads=hkv, head_dim=hd, window=4)
            outs.append(o)
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=1e-4, atol=1e-5)


class TestRope:
    def test_rope_preserves_norm(self):
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
        pos = jnp.arange(8)[None]
        y = apply_rope(x, pos)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)

    def test_rope_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        rng = np.random.default_rng(7)
        q = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)

        def dot_at(i, j):
            qi = apply_rope(q, jnp.asarray([[i]]))
            kj = apply_rope(k, jnp.asarray([[j]]))
            return float(jnp.sum(qi * kj))
        assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)

    def test_m_rope_sections(self):
        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.normal(size=(1, 4, 2, 32)), jnp.float32)
        pos3 = jnp.zeros((1, 4, 3), jnp.int32)
        # all-zero positions == identity
        y = apply_m_rope(x, pos3)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)
        # equal 1-D positions == plain rope
        t = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
        y1 = apply_m_rope(x, jnp.broadcast_to(t[..., None], (1, 4, 3)))
        y2 = apply_rope(x, t)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-6)
