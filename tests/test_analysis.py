"""Tests for ``repro.analysis`` — the shared HLO parsing core and the
SPMD contract auditor.

Three layers:

* parser units pinned against hand-written HLO text (rank-0 shapes,
  nested tuple types, both replica-group syntaxes, donation headers) and
  against a LIVE ``jit(...).lower().compile().as_text()`` module so the
  grammar tracks the real backend;
* hand-written violation modules that must FAIL each audit — a stray
  collective, a dropped donation, a replicated full-table buffer, a
  wire-byte overshoot — plus the green-path module that passes all of
  them (the auditor is tested in both directions);
* the ``repro.launch.audit`` CLI run as a subprocess on a forced
  multi-device CPU mesh over the real production programs.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.analysis import (
    CollectiveRule, CommContract, HloModule,
    audit_hlo, buffer_donors, entry_parameters, format_report_table,
    group_axes, input_output_aliases, iter_collectives,
    parse_instruction, parse_replica_groups, shape_bytes, shape_dims,
    used_parameter_numbers,
)
from repro.sharding.hlo_analysis import collective_stats


# ---------------------------------------------------------------------- #
# shape / instruction grammar
# ---------------------------------------------------------------------- #
class TestShapeGrammar:
    def test_rank0_is_one_element(self):
        # regression: a rank-0 ``f32[]`` is ONE element (4 bytes), not 0
        assert shape_bytes("f32[]") == 4
        assert shape_bytes("s32[]") == 4
        assert shape_bytes("pred[]") == 1
        assert shape_dims("f32[]") == [("f32", ())]

    def test_tuple_sums_members(self):
        assert shape_bytes("(f32[4], s32[])") == 16 + 4
        assert shape_bytes("f32[2,3]") == 24

    def test_nested_tuple_with_layouts(self):
        t = "((f32[2,4]{1,0}, f32[]), s32[4])"
        assert shape_bytes(t) == 32 + 4 + 16
        assert shape_dims(t) == [
            ("f32", (2, 4)), ("f32", ()), ("s32", (4,))]

    def test_parse_instruction_simple(self):
        inst = parse_instruction("  %p.1 = f32[8,16]{1,0} parameter(0)")
        assert inst is not None
        assert (inst.name, inst.op, inst.is_root) == ("p.1", "parameter",
                                                      False)
        assert inst.type_str == "f32[8,16]{1,0}"

    def test_parse_instruction_nested_tuple_root(self):
        # regression: the legacy single-regex parser rejected nested
        # tuple result types entirely
        line = ("  ROOT %t.9 = ((f32[2,4], f32[]), s32[4]) "
                "tuple(%a.1, %b.2)")
        inst = parse_instruction(line)
        assert inst is not None
        assert inst.is_root and inst.op == "tuple"
        assert inst.type_str == "((f32[2,4], f32[]), s32[4])"
        assert shape_bytes(inst.type_str) == 52

    def test_live_lowering_rank0_tuple_root(self):
        # pin the grammar against the real backend: the jitted program
        # returns (scalar, vector); the entry ROOT must parse and its
        # rank-0 member must count 4 bytes
        x = jnp.arange(4, dtype=jnp.float32)
        text = (jax.jit(lambda v: (jnp.sum(v), v))
                .lower(x).compile().as_text())
        mod = HloModule(text)
        roots = [i for i in mod.instructions(mod.entry) if i.is_root]
        assert len(roots) == 1
        assert shape_bytes(roots[0].type_str) == 4 + 16


# ---------------------------------------------------------------------- #
# replica groups and mesh-axis classification
# ---------------------------------------------------------------------- #
MESH_2X2 = (("data", 2), ("model", 2))


class TestReplicaGroups:
    def test_absent_vs_empty(self):
        assert parse_replica_groups("all-reduce(%x)") is None
        assert parse_replica_groups(
            "all-reduce(%x), replica_groups={}") == ()

    def test_explicit(self):
        line = "all-gather(%x), replica_groups={{0,1},{2,3}}, dimensions={1}"
        assert parse_replica_groups(line) == ((0, 1), (2, 3))

    def test_iota_plain(self):
        assert parse_replica_groups(
            "all-reduce(%x), replica_groups=[2,2]<=[4]"
        ) == ((0, 1), (2, 3))

    def test_iota_transposed(self):
        assert parse_replica_groups(
            "all-reduce(%x), replica_groups=[2,2]<=[2,2]T(1,0)"
        ) == ((0, 2), (1, 3))

    def test_group_axes_minor_is_model(self):
        assert group_axes(((0, 1), (2, 3)), MESH_2X2) == {"model"}

    def test_group_axes_major_is_data(self):
        assert group_axes(((0, 2), (1, 3)), MESH_2X2) == {"data"}

    def test_group_axes_flat_spans_all(self):
        assert group_axes(None, MESH_2X2) == {"data", "model"}
        assert group_axes((), MESH_2X2) == {"data", "model"}
        assert group_axes(((0, 1, 2, 3),), MESH_2X2) == {"data", "model"}

    def test_group_axes_singletons_span_none(self):
        # a degenerate collective (all groups of size 1) moves no bytes
        assert group_axes(((0,), (1,), (2,), (3,)), MESH_2X2) \
            == frozenset()


# ---------------------------------------------------------------------- #
# donation headers / entry parameters
# ---------------------------------------------------------------------- #
_HEADER = ("HloModule jit_step, "
           "input_output_alias={ {0}: (3, {}, may-alias), "
           "{1,2}: (5, {1}, must-alias) }, "
           "buffer_donor={ (4, {}), (6, {0}) }, "
           "entry_computation_layout={(f32[4])->f32[4]}")


class TestDonationHeaders:
    def test_aliases_nested_indices(self):
        aliases = input_output_aliases(_HEADER)
        assert len(aliases) == 2
        assert (aliases[0].output_index, aliases[0].param,
                aliases[0].param_index, aliases[0].kind) \
            == ((0,), 3, (), "may-alias")
        assert (aliases[1].output_index, aliases[1].param,
                aliases[1].param_index, aliases[1].kind) \
            == ((1, 2), 5, (1,), "must-alias")

    def test_buffer_donors(self):
        assert buffer_donors(_HEADER) == {(4, ()), (6, (0,))}

    def test_absent(self):
        assert input_output_aliases("HloModule jit_step") == []
        assert buffer_donors("HloModule jit_step") == set()

    def test_entry_parameter_usage(self):
        text = """\
HloModule m

ENTRY %main.5 (p0.1: f32[4], p1.2: f32[4], p2.3: s32[4]) -> f32[4] {
  %p0.1 = f32[4] parameter(0)
  %p1.2 = f32[4] parameter(1)
  %p2.3 = s32[4] parameter(2)
  ROOT %a.4 = f32[4] add(%p0.1, %p1.2)
}
"""
        mod = HloModule(text)
        assert set(entry_parameters(mod)) == {0, 1, 2}
        assert used_parameter_numbers(mod) == {0, 1}  # p2.3 is dead


# ---------------------------------------------------------------------- #
# collective iteration / legacy collective_stats wrapper
# ---------------------------------------------------------------------- #
_ADD_COMP = """\
%add.1 (lhs.2: f32[], rhs.3: f32[]) -> f32[] {
  %lhs.2 = f32[] parameter(0)
  %rhs.3 = f32[] parameter(1)
  ROOT %s.4 = f32[] add(%lhs.2, %rhs.3)
}
"""


def _module(body_lines, header="HloModule jit_step",
            params="p0.1: f32[1,124,8], p1.2: f32[1,248,8], "
                   "p2.3: f32[1,100,8], p3.4: s32[2,248], "
                   "p4.5: s32[2,248]"):
    body = "\n".join(f"  {ln}" for ln in body_lines)
    return f"""\
{header}

{_ADD_COMP}
ENTRY %main.20 ({params}) -> (f32[1,100,8], f32[]) {{
  %p0.1 = f32[1,124,8] parameter(0)
  %p1.2 = f32[1,248,8] parameter(1)
  %p2.3 = f32[1,100,8] parameter(2)
  %p3.4 = s32[2,248] parameter(3)
  %p4.5 = s32[2,248] parameter(4)
{body}
  %loss.10 = f32[] constant(0)
  ROOT %t.19 = (f32[1,100,8], f32[]) tuple(%gar.9, %loss.10)
}}
"""


# the green-path module: one psum_scatter-style exchange on the model
# axis (reduce-scatter + all-gather) plus a gradient all-reduce on the
# data axis, batch buffers donated — exactly what the train contract
# whitelists
_GREEN_BODY = [
    "%rs.6 = f32[1,124,8] reduce-scatter(%p1.2), "
    "replica_groups={{0,1},{2,3}}, dimensions={1}, to_apply=%add.1",
    "%ag.7 = f32[1,248,8] all-gather(%rs.6), "
    "replica_groups={{0,1},{2,3}}, dimensions={1}",
    "%gar.9 = f32[1,100,8] all-reduce(%p2.3), "
    "replica_groups={{0,2},{1,3}}, to_apply=%add.1",
]
_GREEN_HEADER = ("HloModule jit_step, "
                 "input_output_alias={ {0}: (3, {}, may-alias) }, "
                 "buffer_donor={ (4, {}) }")
GREEN = _module(_GREEN_BODY, header=_GREEN_HEADER)


def _contract(**overrides):
    base = dict(
        name="snippet",
        mesh_axes=MESH_2X2,
        rules=(
            CollectiveRule("reduce-scatter", ("model",),
                           expected_bytes=124 * 8 * 4.0),
            CollectiveRule("all-gather", ("model",),
                           expected_bytes=248 * 8 * 4.0),
            CollectiveRule("all-reduce", ("data",),
                           expected_bytes=2.0 * 100 * 8 * 4),
        ),
        forbidden_suffixes=((200, 8),),
        min_donated=2,
    )
    base.update(overrides)
    return CommContract(**base)


class TestCollectiveIteration:
    def test_green_module_collectives(self):
        cs = iter_collectives(HloModule(GREEN))
        assert sorted(c.kind for c in cs) \
            == ["all-gather", "all-reduce", "reduce-scatter"]
        ar = next(c for c in cs if c.kind == "all-reduce")
        assert ar.result_bytes == 100 * 8 * 4
        assert ar.wire_bytes == 2.0 * 100 * 8 * 4  # ring factor

    def test_async_pair_counted_once(self):
        body = list(_GREEN_BODY)
        body[2] = ("%gars.8 = f32[1,100,8] all-reduce-start(%p2.3), "
                   "replica_groups={{0,2},{1,3}}, to_apply=%add.1")
        body.append("%gar.9 = f32[1,100,8] all-reduce-done(%gars.8)")
        cs = iter_collectives(HloModule(_module(body,
                                                header=_GREEN_HEADER)))
        assert len([c for c in cs if c.kind == "all-reduce"]) == 1
        # and the whole contract still audits clean through async forms
        assert audit_hlo(_module(body, header=_GREEN_HEADER),
                         _contract()).ok

    def test_nested_tuple_collective_bytes(self):
        # regression: an all-to-all with a tuple result was invisible to
        # the legacy single-regex parser; the shared core must count
        # every member
        body = list(_GREEN_BODY) + [
            "%a2a.11 = (f32[64,8], f32[64,8]) all-to-all(%p0.1, %p0.1), "
            "replica_groups={{0,1},{2,3}}, dimensions={0}",
        ]
        stats = collective_stats(_module(body, header=_GREEN_HEADER))
        assert stats["all-to-all"]["count"] == 1
        assert stats["all-to-all"]["bytes"] == 2 * 64 * 8 * 4


# ---------------------------------------------------------------------- #
# the audits, both directions
# ---------------------------------------------------------------------- #
class TestAuditGreenPath:
    def test_green_module_passes_every_audit(self):
        report = audit_hlo(GREEN, _contract())
        assert report.ok, report.violations
        assert [r.count for r in report.rule_results] == [1, 1, 1]
        assert report.n_aliased == 1 and report.n_donor == 1

    def test_report_row_shape(self):
        row = audit_hlo(GREEN, _contract()).as_row()
        assert row["ok"] and row["violations"] == []
        assert row["wire_bytes"] == row["expected_bytes"] \
            == 124 * 8 * 4 + 248 * 8 * 4 + 2 * 100 * 8 * 4

    def test_degenerate_collective_ignored(self):
        # all-singleton groups move no bytes: not a stray even with an
        # empty whitelist
        body = ["%gar.9 = f32[1,100,8] all-reduce(%p2.3), "
                "replica_groups={{0},{1},{2},{3}}, to_apply=%add.1"]
        report = audit_hlo(
            _module(body, header=_GREEN_HEADER),
            _contract(rules=(), min_donated=0))
        assert report.ok, report.violations

    def test_format_table(self):
        good = audit_hlo(GREEN, _contract())
        bad = audit_hlo(_module(_GREEN_BODY), _contract())  # no donation
        table = format_report_table([good, bad])
        assert "OK" in table and "FAIL" in table
        assert "!! snippet: donation dropped" in table


class TestAuditViolations:
    def test_stray_all_gather_rejected(self):
        body = list(_GREEN_BODY) + [
            "%sg.12 = f32[1,248,8] all-gather(%p1.2), "
            "replica_groups={{0,2},{1,3}}, dimensions={1}",
        ]
        report = audit_hlo(_module(body, header=_GREEN_HEADER),
                           _contract())
        assert not report.ok
        assert any("stray collective: all-gather" in v
                   and "data" in v for v in report.violations)
        assert len(report.stray) == 1

    def test_count_overflow_rejected(self):
        body = list(_GREEN_BODY) + [
            "%rs2.13 = f32[1,124,8] reduce-scatter(%p1.2), "
            "replica_groups={{0,1},{2,3}}, dimensions={1}, "
            "to_apply=%add.1",
        ]
        report = audit_hlo(_module(body, header=_GREEN_HEADER),
                           _contract())
        assert any("count 2 outside [1, 1]" in v
                   for v in report.violations)

    def test_byte_overshoot_rejected(self):
        # the reduce-scatter result claims the FULL row block instead of
        # the 1/S shard: double the closed-form budget
        body = list(_GREEN_BODY)
        body[0] = body[0].replace("f32[1,124,8] reduce-scatter",
                                  "f32[1,248,8] reduce-scatter")
        report = audit_hlo(_module(body, header=_GREEN_HEADER),
                           _contract())
        assert any("wire bytes 7936 vs closed-form 3968" in v
                   for v in report.violations)

    def test_replicated_table_buffer_rejected(self):
        # a (V, d) = (200, 8) buffer materializing in the entry is the
        # static signature of a replicated table
        body = list(_GREEN_BODY) + [
            "%bad.14 = f32[200,8] broadcast(%loss.10), dimensions={}",
        ]
        report = audit_hlo(_module(body, header=_GREEN_HEADER),
                           _contract())
        assert any("replicated buffer (200, 8)" in v
                   for v in report.violations)

    def test_forbidden_dim_rejected(self):
        body = list(_GREEN_BODY) + [
            "%bad.15 = f32[7,200] broadcast(%loss.10), dimensions={}",
        ]
        report = audit_hlo(
            _module(body, header=_GREEN_HEADER),
            _contract(forbidden_suffixes=(), forbidden_dims=(200,)))
        assert any("replicated buffer (7, 200)" in v
                   for v in report.violations)

    def test_dropped_donation_rejected(self):
        report = audit_hlo(_module(_GREEN_BODY), _contract())
        assert any("donation dropped: 0 entry params" in v
                   for v in report.violations)

    def test_missing_required_collective_rejected(self):
        body = [ln for ln in _GREEN_BODY if "reduce-scatter" not in ln]
        body[0] = body[0].replace("all-gather(%rs.6)",
                                  "all-gather(%p0.1)")
        report = audit_hlo(_module(body, header=_GREEN_HEADER),
                           _contract())
        assert any("reduce-scatter@model: count 0 outside [1, 1]" in v
                   for v in report.violations)


# ---------------------------------------------------------------------- #
# the CLI over the real production programs, forced multi-device CPU
# ---------------------------------------------------------------------- #
def _run_audit_cli(tmp_path, extra_args):
    repo = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    # the CLI module appends --xla_force_host_platform_device_count
    # itself, before importing jax
    out = tmp_path / "audit.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.audit", "--quiet",
         "--json", str(out)] + extra_args,
        cwd=repo, env=env, capture_output=True, text=True, timeout=540)
    payload = json.loads(out.read_text()) if out.exists() else None
    return proc, payload


def test_audit_cli_two_device_mesh(tmp_path):
    # 2 devices: 1x2 data x model mesh — the data axis degenerates and
    # the contracts must still hold exactly
    proc, payload = _run_audit_cli(
        tmp_path, ["--devices", "2", "--exchanges", "psum_scatter"])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert payload["devices"] == 2
    rows = payload["comm_audit"]
    assert [r["program"] for r in rows] == [
        "train[psum_scatter]", "train[psum_scatter,dedup]",
        "train[psum_scatter,int8]", "rank[all-entities]",
        "rank[candidates]", "serve[topk]", "serve[topk,int8]"]
    assert all(r["ok"] for r in rows), rows


def test_audit_cli_full_sweep_four_devices(tmp_path):
    # 4 devices: 2x2 mesh, BOTH axes carry collectives; every layout x
    # dedup, both rank protocols, the serve step, plus the two int8
    # programs (quantized train exchange + quantized serve) — 11 programs
    proc, payload = _run_audit_cli(tmp_path, ["--devices", "4"])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rows = payload["comm_audit"]
    assert len(rows) == 11
    assert all(r["ok"] for r in rows), rows
    # byte budgets are exact closed forms, not just "within tolerance"
    for r in rows:
        if r["program"].startswith("train["):
            assert r["expected_bytes"] > 0
    assert "train[alltoall,dedup]" in proc.stdout
    assert "train[psum_scatter,int8]" in proc.stdout
    assert "serve[topk,int8]" in proc.stdout
    assert "audit ok: 11 programs" in proc.stderr
