"""Scaled evaluation subsystem (PR 3): CSR filter index, tie-aware ranks,
kernel block padding, and candidate-axis-sharded ranking equivalence.

Contracts under test:

* ``CSRFilterIndex`` equals the dict-of-sets ``build_filter_index``
  reference on random graphs — duplicate triplets, absent (s, r) pairs,
  and the true tail never self-filtered;
* ``ranking_metrics`` scores ties with the mean rank
  ``1 + #greater + 0.5·#equal`` in both the all-entities and ogbl
  candidate paths;
* ``kge_score_padded`` handles non-multiple-of-128 B/C (bias ``-inf`` on
  pad rows) and matches ``kge_score_ref``;
* sharded ranking (shard-local Pallas scoring + integer count psum) returns
  EXACTLY the dense metrics — ``==``, not allclose — at 1/2/4 shards,
  including duplicate gather ids, tied scores and padded vocab rows, on the
  simulated mesh, under shard_map, and through the trainer eval seam;
* the streamed partition encoder reproduces the mega-partition encoder.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core.graph import (
    KnowledgeGraph, make_synthetic_kg, split_train_valid_test,
)
from repro.eval import (
    CSRFilterIndex, FILTER_BIAS, build_filter_index,
    evaluate_both_directions, make_sharded_rank_step, ranking_metrics,
    shard_filter_bias_block, sharded_ranking_metrics,
)
from repro.eval.ranking import _filter_bias

SHARD_COUNTS = (1, 2, 4)


def _random_kg(seed: int, n_ent: int, n_rel: int, n_edge: int,
               dup_frac: float = 0.3) -> KnowledgeGraph:
    """Random KG that KEEPS duplicate triplets (make_synthetic_kg dedupes;
    the filter index must tolerate duplicates within and across splits)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_ent, n_edge).astype(np.int32)
    rel = rng.integers(0, n_rel, n_edge).astype(np.int32)
    dst = rng.integers(0, n_ent, n_edge).astype(np.int32)
    n_dup = int(n_edge * dup_frac)
    if n_edge and n_dup:
        take = rng.integers(0, n_edge, n_dup)
        src = np.concatenate([src, src[take]])
        rel = np.concatenate([rel, rel[take]])
        dst = np.concatenate([dst, dst[take]])
    return KnowledgeGraph(src=src, rel=rel, dst=dst, num_entities=n_ent,
                          num_relations=n_rel)


def _assert_csr_equals_dict(graphs, n_ent: int, n_rel: int, seed: int):
    ref = build_filter_index(graphs)
    csr = CSRFilterIndex.build(graphs)
    assert csr.num_pairs == len(ref)
    # per-pair tails (dedup'd) match the dict-of-sets
    for (s, r), tails in ref.items():
        got = csr.tails_of(s, r)
        assert sorted(got.tolist()) == sorted(tails), (s, r)
        assert len(set(got.tolist())) == len(got)      # dedup'd
    # absent pairs resolve to empty, not a neighbor's tails
    rng = np.random.default_rng(seed)
    for _ in range(20):
        s, r = int(rng.integers(0, n_ent)), int(rng.integers(0, n_rel))
        if (s, r) not in ref:
            assert csr.tails_of(s, r).size == 0
    # the (B, N) bias equals the double-loop reference bit for bit,
    # including the never-self-filtered true tail
    queries = np.stack([rng.integers(0, n_ent, 64),
                        rng.integers(0, n_rel, 64),
                        rng.integers(0, n_ent, 64)], axis=1).astype(np.int32)
    b_ref = _filter_bias(ref, queries, n_ent)
    b_csr = _filter_bias(csr, queries, n_ent)
    np.testing.assert_array_equal(b_ref, b_csr)
    assert (b_csr[np.arange(64), queries[:, 2]] == 0.0).all()


class TestCSRFilterIndex:
    @pytest.mark.parametrize("seed", range(4))
    def test_equals_dict_reference(self, seed):
        """Deterministic twin of the property test (runs without
        hypothesis): random graphs with duplicates, across splits."""
        rng = np.random.default_rng(seed)
        n_ent = int(rng.integers(5, 80))
        n_rel = int(rng.integers(1, 8))
        graphs = [_random_kg(seed * 31 + i, n_ent, n_rel,
                             int(rng.integers(0, 300))) for i in range(3)]
        _assert_csr_equals_dict(graphs, n_ent, n_rel, seed)

    @given(st.integers(0, 2 ** 31 - 1), st.integers(2, 60),
           st.integers(1, 7), st.integers(0, 250))
    @settings(max_examples=25, deadline=None)
    def test_equals_dict_reference_property(self, seed, n_ent, n_rel,
                                            n_edge):
        graphs = [_random_kg(seed, n_ent, n_rel, n_edge),
                  _random_kg(seed + 1, n_ent, n_rel, n_edge // 2)]
        _assert_csr_equals_dict(graphs, n_ent, n_rel, seed)

    def test_empty_and_absent(self):
        csr = CSRFilterIndex.build([])
        assert csr.num_pairs == 0
        assert csr.tails_of(0, 0).size == 0
        queries = np.array([[1, 0, 2]], np.int32)
        np.testing.assert_array_equal(csr.bias(queries, 5),
                                      np.zeros((1, 5), np.float32))

    def test_true_tail_never_self_filtered(self):
        g = KnowledgeGraph(src=np.array([0, 0, 0]), rel=np.array([0, 0, 0]),
                           dst=np.array([1, 2, 3]), num_entities=5,
                           num_relations=1)
        csr = CSRFilterIndex.build([g])
        # querying (0, 0, t=2): 1 and 3 filtered, 2 (the true tail) is not
        bias = csr.bias(np.array([[0, 0, 2]]), 5)
        np.testing.assert_array_equal(
            bias[0], [0.0, FILTER_BIAS, 0.0, FILTER_BIAS, 0.0])


# ====================================================================== #
# Column-range filter bias (tentpole: per-shard blocks straight from CSR)
# ====================================================================== #
class TestColumnRangeBias:
    """``CSRFilterIndex.bias(triplets, w, col_start)`` must equal slicing
    the dense bias — including empty ranges, ranges past the vocabulary,
    queries with no known tails, and the ragged last shard block."""

    def _setup(self, seed, n_ent=97, n_rel=5):
        rng = np.random.default_rng(seed)
        graphs = [_random_kg(seed * 7 + i, n_ent, n_rel,
                             int(rng.integers(0, 400))) for i in range(2)]
        csr = CSRFilterIndex.build(graphs)
        ref = build_filter_index(graphs)
        queries = np.stack([rng.integers(0, n_ent, 48),
                            rng.integers(0, n_rel, 48),
                            rng.integers(0, n_ent, 48)],
                           axis=1).astype(np.int32)
        return csr, ref, queries, n_ent

    @pytest.mark.parametrize("seed", range(3))
    def test_equals_dense_slice(self, seed):
        csr, ref, queries, n = self._setup(seed)
        dense = csr.bias(queries, n)
        rng = np.random.default_rng(seed + 100)
        ranges = [(0, n), (0, 0), (n, 0), (0, 1), (n - 1, 1),
                  (n // 3, n // 2)]
        for _ in range(10):
            lo, hi = sorted(rng.integers(0, n + 1, 2))
            ranges.append((int(lo), int(hi - lo)))
        for lo, w in ranges:
            got = csr.bias(queries, w, col_start=lo)
            assert got.shape == (48, w)
            np.testing.assert_array_equal(got, dense[:, lo: lo + w])
            # the dict-of-sets loop reference agrees on the same range
            np.testing.assert_array_equal(
                got, _filter_bias(ref, queries, w, col_start=lo))

    @given(st.integers(0, 2 ** 31 - 1), st.integers(2, 50),
           st.integers(1, 6), st.integers(0, 200))
    @settings(max_examples=20, deadline=None)
    def test_equals_dense_slice_property(self, seed, n_ent, n_rel, n_edge):
        graphs = [_random_kg(seed, n_ent, n_rel, n_edge)]
        csr = CSRFilterIndex.build(graphs)
        rng = np.random.default_rng(seed)
        queries = np.stack([rng.integers(0, n_ent, 16),
                            rng.integers(0, n_rel, 16),
                            rng.integers(0, n_ent, 16)],
                           axis=1).astype(np.int32)
        dense = csr.bias(queries, n_ent)
        lo = int(rng.integers(0, n_ent + 1))
        w = int(rng.integers(0, n_ent + 1 - lo))
        np.testing.assert_array_equal(
            csr.bias(queries, w, col_start=lo), dense[:, lo: lo + w])

    def test_queries_with_no_known_tails(self):
        """Absent (s, r) pairs produce an all-zero block in every range
        (except the true-tail column, which is zero anyway)."""
        g = KnowledgeGraph(src=np.array([0]), rel=np.array([0]),
                           dst=np.array([1]), num_entities=50,
                           num_relations=3)
        csr = CSRFilterIndex.build([g])
        # (s=5, r=2) was never seen: no tails anywhere
        q = np.array([[5, 2, 7]], np.int32)
        for lo, w in [(0, 50), (0, 10), (20, 17), (49, 1), (10, 0)]:
            np.testing.assert_array_equal(
                csr.bias(q, w, col_start=lo), np.zeros((1, w), np.float32))

    def test_true_tail_zero_only_in_owning_range(self):
        g = KnowledgeGraph(src=np.array([0, 0, 0]), rel=np.array([0, 0, 0]),
                           dst=np.array([1, 2, 3]), num_entities=6,
                           num_relations=1)
        csr = CSRFilterIndex.build([g])
        q = np.array([[0, 0, 2]], np.int32)
        # range [0, 3): tails 1, 2 fall inside; 2 is the true tail -> zero
        np.testing.assert_array_equal(
            csr.bias(q, 3)[0], [0.0, FILTER_BIAS, 0.0])
        # range [3, 6): known tail 3 filtered, true tail not in range
        np.testing.assert_array_equal(
            csr.bias(q, 3, col_start=3)[0], [FILTER_BIAS, 0.0, 0.0])

    @pytest.mark.parametrize("num_shards", [1, 2, 4, 7])
    def test_shard_block_equals_dense_reference(self, num_shards):
        """shard_filter_bias_block == shard_bias_blocks(dense)[s] for every
        shard, including the ragged last shard (layout padding -inf)."""
        from repro.sharding.embedding import (
            ShardedTableLayout, shard_bias_blocks,
        )
        csr, ref, queries, n = self._setup(seed=11)
        layout = ShardedTableLayout(n, num_shards)
        dense_blocks = shard_bias_blocks(csr.bias(queries, n), layout)
        for s in range(num_shards):
            got = shard_filter_bias_block(csr, queries, layout, s)
            np.testing.assert_array_equal(got, dense_blocks[s])
            # the dict reference index builds the identical block
            np.testing.assert_array_equal(
                got, shard_filter_bias_block(ref, queries, layout, s))

    def test_empty_batch(self):
        csr = CSRFilterIndex.build([])
        assert csr.bias(np.zeros((0, 3), np.int32), 5,
                        col_start=2).shape == (0, 5)


class TestPerShardTwins:
    """The per-shard block builders the multi-host mesh path uses must
    reproduce their full-stack twins bit-for-bit (stacking blocks over
    shards == the full build)."""

    @pytest.mark.parametrize("n,s", [(100, 4), (101, 4), (7, 3), (16, 1)])
    def test_shard_table_block(self, n, s):
        from repro.sharding.embedding import (
            ShardedTableLayout, shard_table, shard_table_block,
        )
        rng = np.random.default_rng(n * 10 + s)
        table = rng.normal(size=(n, 6)).astype(np.float32)
        layout = ShardedTableLayout(n, s)
        full = shard_table(table, layout)
        for i in range(s):
            np.testing.assert_array_equal(
                full[i], shard_table_block(table, layout, i))
        with pytest.raises(ValueError, match="rows"):
            shard_table_block(table[:-1], layout, 0)

    @pytest.mark.parametrize("s", [1, 2, 4])
    def test_plan_local_gather_block(self, s):
        from repro.sharding.embedding import (
            ShardedTableLayout, plan_local_gather, plan_local_gather_block,
        )
        rng = np.random.default_rng(s)
        layout = ShardedTableLayout(101, s)
        ids = rng.integers(0, 101, size=(12, 7))
        full_local, full_owned = plan_local_gather(layout, ids)
        for i in range(s):
            li, ow = plan_local_gather_block(layout, ids, i)
            assert li.dtype == full_local.dtype
            assert ow.dtype == full_owned.dtype
            np.testing.assert_array_equal(li, full_local[i])
            np.testing.assert_array_equal(ow, full_owned[i])


class TestNoDenseBiasOnShardedPath:
    def test_peak_host_alloc_below_dense_bias(self):
        """The acceptance bound: the sharded path builds per-shard bias
        blocks straight from CSR, so peak host allocation during ranking
        stays well under the dense (B, N) bias it used to materialize
        (the dense path is measured too, proving the tracker would catch
        a regression)."""
        import tracemalloc
        n, d, b, s = 6000, 8, 256, 8
        rng = np.random.default_rng(0)
        emb = rng.normal(size=(n, d)).astype(np.float32)
        dparams = {"rel_diag": rng.normal(size=(48, d)).astype(np.float32)}
        kg = make_synthetic_kg(n, 48, 30_000, seed=0)
        fidx = CSRFilterIndex.build([kg])
        tests = kg.triplets()[:b]
        dense_bias_bytes = b * n * 4

        # warm both jit caches OUTSIDE the traced window (compilation
        # allocates host memory that has nothing to do with the bias path)
        sharded_ranking_metrics(emb, dparams, tests, fidx, s, batch_size=b)
        ranking_metrics(emb, dparams, tests, fidx, batch_size=b)

        import gc
        gc.collect()
        tracemalloc.start()
        m_sh = sharded_ranking_metrics(emb, dparams, tests, fidx, s,
                                       batch_size=b)
        _, peak_sharded = tracemalloc.get_traced_memory()
        tracemalloc.stop()

        gc.collect()
        tracemalloc.start()
        m_dense = ranking_metrics(emb, dparams, tests, fidx, batch_size=b)
        _, peak_dense = tracemalloc.get_traced_memory()
        tracemalloc.stop()

        assert m_sh == m_dense
        # dense really does materialize (B, N) on host ...
        assert peak_dense >= dense_bias_bytes
        # ... and the sharded path never does (one (B, rows/S) block plus
        # change; 0.5x leaves slack for scatter temporaries)
        assert peak_sharded < 0.5 * dense_bias_bytes, (
            f"sharded eval peak host alloc {peak_sharded} vs dense bias "
            f"{dense_bias_bytes} — a (B, N) bias is being materialized")


# ====================================================================== #
# Tie-aware mean rank (satellite: regression with exact ties)
# ====================================================================== #
class TestTieHandling:
    """emb[0] is the head; with the DistMult diagonal == 1 the candidate
    scores are emb[c][0]: c0=1.0 (head), c1=0.5 (TRUE), c2=0.5 (tie),
    c3=0.9, c4=0.1, c5=0.5 (tie)."""

    def _emb(self):
        n, d = 6, 4
        emb = np.zeros((n, d), np.float32)
        emb[:, 0] = [1.0, 0.5, 0.5, 0.9, 0.1, 0.5]
        emb[0] = 0.0
        emb[0, 0] = 1.0
        return emb, {"rel_diag": np.ones((1, d), np.float32)}

    def test_all_entities_path_mean_rank(self):
        emb, table = self._emb()
        tests = np.array([[0, 0, 1]])
        # greater: c0, c3; ties (besides self): c2, c5 -> rank 1+2+0.5*2 = 4
        m = ranking_metrics(emb, table, tests, {})
        assert m["mrr"] == pytest.approx(1.0 / 4.0)
        assert m["hits@3"] == 0.0 and m["hits@10"] == 1.0

    def test_filtered_tie_discounted(self):
        emb, table = self._emb()
        tests = np.array([[0, 0, 1]])
        # c5 is a known positive -> filtered; remaining tie c2 only:
        # rank = 1 + 2 + 0.5*1 = 3.5
        m = ranking_metrics(emb, table, tests, {(0, 0): {5, 1}})
        assert m["mrr"] == pytest.approx(1.0 / 3.5)

    def test_candidate_path_mean_rank(self):
        emb, table = self._emb()
        tests = np.array([[0, 0, 1]])
        cands = np.array([[2, 3, 4, 5]])
        # greater: c3; ties: c2, c5 -> rank = 1 + 1 + 0.5*2 = 3
        m = ranking_metrics(emb, table, tests, {}, candidates=cands)
        assert m["mrr"] == pytest.approx(1.0 / 3.0)
        assert m["hits@3"] == 1.0

    def test_no_ties_matches_strict_rank(self):
        """Without ties the mean rank degenerates to the strict
        1 + #greater — the pre-PR-3 convention."""
        rng = np.random.default_rng(0)
        emb = rng.normal(size=(40, 8)).astype(np.float32)
        table = rng.normal(size=(2, 8)).astype(np.float32)
        tests = np.stack([rng.integers(0, 40, 16), rng.integers(0, 2, 16),
                          rng.integers(0, 40, 16)], 1).astype(np.int32)
        m = ranking_metrics(emb, {"rel_diag": table}, tests, {})
        scores = (emb[tests[:, 0]] * table[tests[:, 1]]) @ emb.T
        true = scores[np.arange(16), tests[:, 2]]
        strict = 1 + (scores > true[:, None]).sum(1)
        assert m["mrr"] == pytest.approx(float(np.mean(1.0 / strict)))


# ====================================================================== #
# kge_score block-padding wrapper (satellite)
# ====================================================================== #
class TestKgeScorePadding:
    @pytest.mark.parametrize("b,c", [(5, 37), (130, 200), (128, 128),
                                     (1, 129), (257, 1)])
    @pytest.mark.parametrize("epilogue", ("bilinear", "neg_l2"))
    def test_ragged_shapes_match_ref(self, b, c, epilogue):
        from repro.kernels import ref
        from repro.kernels.ops import kge_score_padded
        rng = np.random.default_rng(b * 1000 + c)
        d = 16
        q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
        cand = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
        qb = jnp.asarray(rng.normal(size=(b,)).astype(np.float32) ** 2)
        cb = jnp.asarray(rng.normal(size=(c,)).astype(np.float32) ** 2)
        bias = jnp.asarray(
            np.where(rng.random((b, c)) < 0.2, FILTER_BIAS, 0.0)
            .astype(np.float32))
        got = kge_score_padded(q, cand, bias, qb, cb, epilogue=epilogue)
        assert got.shape == (b, c)
        np.testing.assert_allclose(
            np.asarray(got),
            np.asarray(ref.kge_score_ref(q, cand, bias, qb, cb,
                                         epilogue=epilogue)),
            rtol=1e-5, atol=1e-5)
        # bias-less call too (zero pre-epilogue biases)
        got_nb = kge_score_padded(q, cand, epilogue=epilogue)
        np.testing.assert_allclose(
            np.asarray(got_nb),
            np.asarray(ref.kge_score_ref(q, cand, epilogue=epilogue)),
            rtol=1e-5, atol=1e-5)

    def test_raw_kernel_rejects_ragged(self):
        from repro.kernels.kge_score import kge_score
        q = jnp.zeros((5, 8))
        with pytest.raises(AssertionError, match="kge_score_padded"):
            kge_score(q, jnp.zeros((37, 8)), jnp.zeros((5, 37)),
                      jnp.zeros((5, 1)), jnp.zeros((1, 37)))

    def test_ranking_metrics_accepts_ragged_last_batch(self):
        """T % batch_size != 0 and N % 128 != 0 go through the wrapper."""
        rng = np.random.default_rng(3)
        emb = rng.normal(size=(150, 8)).astype(np.float32)
        table = rng.normal(size=(4, 8)).astype(np.float32)
        tests = np.stack([rng.integers(0, 150, 70), rng.integers(0, 4, 70),
                          rng.integers(0, 150, 70)], 1).astype(np.int32)
        m = ranking_metrics(emb, {"rel_diag": table}, tests, {},
                            batch_size=32)
        assert 0.0 < m["mrr"] <= 1.0


# ====================================================================== #
# Candidate-axis-sharded ranking == dense (the tentpole contract)
# ====================================================================== #
def _tied_eval_setup(seed=0, n=301, d=24, n_rel=8, n_test=120):
    """Embeddings with exact duplicate rows (ties), a non-multiple-of-128
    (and of-shard-count) vocab, and duplicate test triplets (duplicate
    gather ids)."""
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(n, d)).astype(np.float32)
    emb[7] = emb[3]
    emb[n - 1] = emb[11]            # tie across shard boundaries
    dparams = {"rel_diag":
               rng.normal(size=(2 * n_rel, d)).astype(np.float32)}
    kg = make_synthetic_kg(n, n_rel, 2200, seed=seed)
    splits = split_train_valid_test(kg)
    fidx = CSRFilterIndex.build(
        [g.with_inverse_relations() for g in splits.values()])
    tests = splits["test"].with_inverse_relations().triplets()[:n_test]
    tests = np.concatenate([tests, tests[:7]])   # duplicate gather ids
    return emb, dparams, tests, fidx, splits


class TestShardedRankingEquivalence:
    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_exactly_equals_dense(self, s):
        emb, dparams, tests, fidx, _ = _tied_eval_setup()
        m_dense = ranking_metrics(emb, dparams, tests, fidx)
        m_sh = sharded_ranking_metrics(emb, dparams, tests, fidx, s)
        assert m_sh == m_dense                 # exact, not allclose

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_dispatch_through_ranking_metrics(self, s):
        emb, dparams, tests, fidx, _ = _tied_eval_setup(seed=1)
        m_dense = ranking_metrics(emb, dparams, tests, fidx)
        m_sh = ranking_metrics(emb, dparams, tests, fidx, num_shards=s)
        assert m_sh == m_dense

    def test_both_directions_sharded(self):
        emb, dparams, _, _, splits = _tied_eval_setup(seed=2)
        args = (emb, dparams, splits["valid"],
                [splits["train"], splits["valid"], splits["test"]])
        m1 = evaluate_both_directions(*args, num_relations_base=8)
        m2 = evaluate_both_directions(*args, num_relations_base=8,
                                      num_shards=2)
        assert m1 == m2

    def test_shard_map_step_matches_simulation(self):
        """1×1 host mesh smoke for the real shard_map + psum path (a
        multi-device model axis changes only the axis size — the 2-device
        subprocess test drives the real exchange)."""
        from repro.launch.mesh import make_host_mesh
        emb, dparams, tests, fidx, _ = _tied_eval_setup(seed=3, n_test=64)
        step = make_sharded_rank_step(make_host_mesh(1, 1))
        m_spmd = sharded_ranking_metrics(emb, dparams, tests, fidx, 1,
                                         rank_step=step)
        assert m_spmd == ranking_metrics(emb, dparams, tests, fidx)

    def test_dict_filter_also_supported(self):
        """The sharded path accepts the dict reference index too."""
        emb, dparams, tests, _, splits = _tied_eval_setup(seed=4, n_test=40)
        ref = build_filter_index(
            [g.with_inverse_relations() for g in splits.values()])
        assert sharded_ranking_metrics(emb, dparams, tests, ref, 2) == \
            ranking_metrics(emb, dparams, tests, ref)


# ====================================================================== #
# ogbl candidate-list protocol routed through the sharded path (tentpole)
# ====================================================================== #
def _candidate_setup(seed=0, n_cand=40):
    """Per-row candidate lists that cross shard boundaries, contain exact
    score ties (duplicate embedding rows 3/7 and 11/n-1) and duplicate
    candidate ids within a row."""
    emb, dparams, tests, fidx, _ = _tied_eval_setup(seed=seed)
    rng = np.random.default_rng(seed + 500)
    n = emb.shape[0]
    cands = rng.integers(0, n, size=(tests.shape[0], n_cand)).astype(
        np.int32)
    cands[:, 0] = 3                      # tie partners in every row ...
    cands[:, 1] = 7
    cands[:, 2] = 11
    cands[:, 3] = n - 1                  # ... across shard boundaries
    cands[:, 4] = cands[:, 5]            # duplicate candidate id in-row
    return emb, dparams, tests, fidx, cands


class TestShardedCandidateProtocol:
    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_exactly_equals_dense(self, s):
        emb, dparams, tests, fidx, cands = _candidate_setup()
        m_dense = ranking_metrics(emb, dparams, tests, fidx,
                                  candidates=cands)
        m_sh = sharded_ranking_metrics(emb, dparams, tests, fidx, s,
                                       candidates=cands)
        assert m_sh == m_dense                 # exact, not allclose

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_dispatch_through_ranking_metrics(self, s):
        """num_shards > 1 + candidates routes through the sharded path
        (it used to silently fall back to dense)."""
        emb, dparams, tests, fidx, cands = _candidate_setup(seed=1)
        m_dense = ranking_metrics(emb, dparams, tests, fidx,
                                  candidates=cands)
        m_sh = ranking_metrics(emb, dparams, tests, fidx, candidates=cands,
                               num_shards=s)
        assert m_sh == m_dense

    def test_shard_map_candidate_step(self):
        """1×1 host mesh smoke for the shard_map + psum candidate path."""
        from repro.launch.mesh import make_host_mesh
        emb, dparams, tests, fidx, cands = _candidate_setup(seed=2)
        step = make_sharded_rank_step(make_host_mesh(1, 1),
                                      protocol="candidates")
        m_spmd = sharded_ranking_metrics(emb, dparams, tests, fidx, 1,
                                         rank_step=step, candidates=cands)
        assert m_spmd == ranking_metrics(emb, dparams, tests, fidx,
                                         candidates=cands)

    def test_protocol_mismatch_fails_fast(self):
        from repro.launch.mesh import make_host_mesh
        emb, dparams, tests, fidx, cands = _candidate_setup(seed=3)
        all_step = make_sharded_rank_step(make_host_mesh(1, 1))
        with pytest.raises(ValueError, match="protocol"):
            sharded_ranking_metrics(emb, dparams, tests, fidx, 1,
                                    rank_step=all_step, candidates=cands)
        cand_step = make_sharded_rank_step(make_host_mesh(1, 1),
                                           protocol="candidates")
        with pytest.raises(ValueError, match="protocol"):
            sharded_ranking_metrics(emb, dparams, tests, fidx, 1,
                                    rank_step=cand_step)
        with pytest.raises(ValueError, match="unknown protocol"):
            make_sharded_rank_step(make_host_mesh(1, 1), protocol="nope")

    @pytest.mark.parametrize("decoder", ["transe", "rotate"])
    def test_neg_l2_decoders_too(self, decoder):
        """The routed candidate path carries every epilogue family, not
        just the bilinear paper decoder."""
        from repro.models.decoders import init_decoder_params
        emb, _, tests, fidx, cands = _candidate_setup(seed=4)
        d = emb.shape[1]
        dparams = jax.tree_util.tree_map(np.asarray, init_decoder_params(
            jax.random.PRNGKey(0), decoder, 16, d))
        m_dense = ranking_metrics(emb, dparams, tests, fidx,
                                  candidates=cands, decoder=decoder)
        for s in (2, 4):
            m_sh = sharded_ranking_metrics(
                emb, dparams, tests, fidx, s, candidates=cands,
                decoder=decoder)
            assert m_sh == m_dense


# ====================================================================== #
# Streamed partition encoder (tentpole part 2)
# ====================================================================== #
class TestStreamedEncoder:
    def test_streamed_equals_mega_partition(self, small_kg, partitioned):
        """Core vertices carry their full receptive field per partition, so
        streaming over 4 training partitions reproduces the full-graph
        mega-partition encode (same in-edge summation order — bitwise)."""
        from repro.models import KGEConfig, RGCNConfig, init_kge_params
        from repro.training.evaluation import encode_all_entities
        parts, expanded = partitioned
        cfg = KGEConfig(rgcn=RGCNConfig(
            num_entities=small_kg.num_entities,
            num_relations=small_kg.num_relations,
            hidden_dim=16, num_layers=2, num_bases=2, dropout=0.0))
        params = init_kge_params(jax.random.PRNGKey(0), cfg)
        e_mega = encode_all_entities(params, cfg, small_kg, 2)
        e_stream = encode_all_entities(params, cfg, small_kg, 2,
                                       partitions=expanded)
        np.testing.assert_array_equal(e_stream, e_mega)

    def test_streamed_sharded_table_with_host_plans(self, small_kg,
                                                    partitioned):
        """Row-sharded table: the streamed encoder ships host-precomputed
        ShardedGatherPlans per partition — same embeddings as dense."""
        from repro.models import KGEConfig, RGCNConfig, init_kge_params
        from repro.sharding import ShardedTableLayout, shard_table
        from repro.training.evaluation import encode_all_entities
        _, expanded = partitioned
        base = dict(num_entities=small_kg.num_entities,
                    num_relations=small_kg.num_relations,
                    hidden_dim=16, num_layers=2, num_bases=2, dropout=0.0)
        cfg_d = KGEConfig(rgcn=RGCNConfig(**base))
        cfg_s = KGEConfig(rgcn=RGCNConfig(**base, num_table_shards=2))
        params = init_kge_params(jax.random.PRNGKey(0), cfg_d)
        p_shard = dict(params)
        p_shard["entity_embedding"] = shard_table(
            params["entity_embedding"],
            ShardedTableLayout(small_kg.num_entities, 2))
        e_d = encode_all_entities(params, cfg_d, small_kg, 2,
                                  partitions=expanded)
        e_s = encode_all_entities(p_shard, cfg_s, small_kg, 2,
                                  partitions=expanded)
        np.testing.assert_array_equal(e_d, e_s)


# ====================================================================== #
# Trainer eval seam + tier-1 smoke (satellite: never regress silently)
# ====================================================================== #
class TestTrainerEvalSeam:
    def test_eval_smoke_and_shard_equivalence(self):
        """Tier-1 guard on the whole filtered-metrics path: a short
        full-graph run must produce sane filtered metrics, and the 2-shard
        trainer (sharded table + sharded ranking + streamed encoder) must
        return EXACTLY the dense trainer's metrics."""
        from repro.data import synthetic_fb15k
        from repro.training import KGETrainer, TrainConfig
        splits = synthetic_fb15k(scale=0.01, seed=5)
        metrics = {}
        for s in (1, 2):
            tr = KGETrainer(splits, TrainConfig(
                num_trainers=2, epochs=2, hidden_dim=16, batch_size=None,
                learning_rate=0.05, seed=0, num_table_shards=s))
            tr.fit()
            metrics[s] = tr.evaluate("valid")
            tr.close()
        m = metrics[1]
        assert set(m) == {"valid_mrr", "valid_hits@1", "valid_hits@3",
                          "valid_hits@10"}
        assert 0.0 < m["valid_mrr"] <= 1.0
        assert m["valid_hits@1"] <= m["valid_hits@3"] <= m["valid_hits@10"]
        assert metrics[2] == metrics[1]

    @pytest.mark.slow
    def test_multi_shard_eval_sweep(self):
        """The full 1/2/4-shard trainer sweep: training losses AND filtered
        eval metrics identical across table shard counts."""
        from repro.data import synthetic_fb15k
        from repro.training import KGETrainer, TrainConfig
        splits = synthetic_fb15k(scale=0.015, seed=6)
        out = {}
        for s in SHARD_COUNTS:
            tr = KGETrainer(splits, TrainConfig(
                num_trainers=2, epochs=3, hidden_dim=16, batch_size=None,
                learning_rate=0.05, seed=0, num_table_shards=s))
            losses = [h["loss"] for h in tr.fit()]
            out[s] = (losses, tr.evaluate("test"))
            tr.close()
        assert out[1] == out[2] == out[4]


# ====================================================================== #
# Real 2-device model axis: integer count psum == dense metrics, exactly
# ====================================================================== #
_TWO_DEVICE_EVAL_SCRIPT = """
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 2, jax.devices()
from repro.core.graph import make_synthetic_kg, split_train_valid_test
from repro.eval import CSRFilterIndex, make_sharded_rank_step, \\
    ranking_metrics, sharded_ranking_metrics
from repro.launch.mesh import make_host_mesh

n, d = 301, 16
rng = np.random.default_rng(0)
emb = rng.normal(size=(n, d)).astype(np.float32)
emb[7] = emb[3]                      # exact ties survive the psum exchange
dparams = {"rel_diag": rng.normal(size=(12, d)).astype(np.float32)}
kg = make_synthetic_kg(n, 6, 1800, seed=1)
splits = split_train_valid_test(kg)
fidx = CSRFilterIndex.build(
    [g.with_inverse_relations() for g in splits.values()])
tests = splits["test"].with_inverse_relations().triplets()[:96]

mesh = make_host_mesh(1, 2)          # data=1 x model=2: one row block each
step = make_sharded_rank_step(mesh)
m_spmd = sharded_ranking_metrics(emb, dparams, tests, fidx, 2,
                                 rank_step=step)
m_dense = ranking_metrics(emb, dparams, tests, fidx)
# greater/equal partials are integers and the true score is one real value
# + zeros, so the psum is order-free: EXACT equality, unlike the training
# gradient exchange
assert m_spmd == m_dense, (m_spmd, m_dense)

# ogbl candidate protocol through the same 2-device psum exchange, with
# candidate ids scattered by owning row block (incl. the tied rows 3/7)
cands = rng.integers(0, n, size=(tests.shape[0], 32)).astype(np.int32)
cands[:, 0] = 3
cands[:, 1] = 7
cstep = make_sharded_rank_step(mesh, protocol="candidates")
m_cand_spmd = sharded_ranking_metrics(emb, dparams, tests, fidx, 2,
                                      rank_step=cstep, candidates=cands)
m_cand_dense = ranking_metrics(emb, dparams, tests, fidx, candidates=cands)
assert m_cand_spmd == m_cand_dense, (m_cand_spmd, m_cand_dense)
print("TWO_DEVICE_EVAL_OK")
"""


@pytest.mark.slow
def test_two_device_sharded_ranking_exact():
    """Drive the REAL candidate-count psum: 2 forced host devices, table
    and bias blocks sharded P('model'); metrics must EXACTLY equal the
    dense single-device reference (integer partials — no float
    reduction-order slack)."""
    import os
    import subprocess
    import sys
    repo = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    proc = subprocess.run(
        [sys.executable, "-c", _TWO_DEVICE_EVAL_SCRIPT], cwd=repo, env=env,
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "TWO_DEVICE_EVAL_OK" in proc.stdout
