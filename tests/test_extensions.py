"""Extension coverage: decoder-agnostic training/eval (the paper's §6
claim), the RGAT alternative encoder, and the communication-volume
analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    expand_all, make_synthetic_kg, pad_partitions, partition_graph,
)
from repro.data import synthetic_fb15k
from repro.models.rgat import RGATConfig, init_rgat_params, rgat_encode
from repro.models.rgcn import RGCNConfig
from repro.training import KGETrainer, TrainConfig


class TestDecoderAgnostic:
    """§6: "agnostic to the used knowledge graph embedding model"."""

    @pytest.mark.parametrize("decoder", ["distmult", "transe", "complex"])
    def test_train_and_eval(self, decoder):
        splits = synthetic_fb15k(scale=0.01, seed=11)
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=2, epochs=4, hidden_dim=16,
            learning_rate=0.05, decoder=decoder))
        hist = tr.fit()
        assert hist[-1]["loss"] < hist[0]["loss"] + 1e-6
        m = tr.evaluate("valid")
        assert 0.0 <= m["valid_mrr"] <= 1.0
        assert np.isfinite(m["valid_mrr"])


class TestRGAT:
    def _setup(self):
        kg = make_synthetic_kg(150, 5, 900, seed=5).with_inverse_relations()
        pb = pad_partitions(
            expand_all(kg, partition_graph(kg, 2, "vertex_cut"), 2))
        base = RGCNConfig(num_entities=kg.num_entities,
                          num_relations=kg.num_relations,
                          hidden_dim=16, num_layers=2)
        cfg = RGATConfig(base=base)
        params = init_rgat_params(jax.random.PRNGKey(0), cfg)
        return cfg, params, pb

    def test_forward_shapes_finite(self):
        cfg, params, pb = self._setup()
        x = params["entity_embedding"][jnp.asarray(pb.local_to_global[0])]
        h = rgat_encode(params, cfg, x, jnp.asarray(pb.src[0]),
                        jnp.asarray(pb.rel[0]), jnp.asarray(pb.dst[0]),
                        jnp.asarray(pb.edge_mask[0]))
        assert h.shape == (pb.padded_vertices, 16)
        assert bool(jnp.isfinite(h).all())

    def test_attention_normalizes(self):
        """Segment softmax over in-edges sums to 1 for vertices with
        unmasked in-edges."""
        from repro.models.rgat import _segment_softmax
        logits = jnp.asarray([0.5, 1.0, -2.0, 3.0])
        seg = jnp.asarray([0, 0, 1, 1])
        mask = jnp.asarray([True, True, True, False])
        a = _segment_softmax(logits, seg, mask, 3)
        assert float(a[0] + a[1]) == pytest.approx(1.0, rel=1e-5)
        assert float(a[2]) == pytest.approx(1.0, rel=1e-5)   # only unmasked
        assert float(a[3]) == 0.0

    def test_mask_blocks_influence(self):
        cfg, params, pb = self._setup()
        x = params["entity_embedding"][jnp.asarray(pb.local_to_global[0])]
        none = jnp.zeros_like(jnp.asarray(pb.edge_mask[0]))
        h = rgat_encode(params, cfg, x, jnp.asarray(pb.src[0]),
                        jnp.asarray(pb.rel[0]), jnp.asarray(pb.dst[0]),
                        none)
        # with all edges masked, output = self-loop path only
        want = jax.nn.relu(
            x @ params["layers"][0]["self_weight"]) @ \
            params["layers"][1]["self_weight"]
        np.testing.assert_allclose(np.asarray(h), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


def test_comm_analysis_scaling():
    """Remote-fetch volume grows with P while gradient volume is constant —
    the quantified version of the paper's central claim."""
    from benchmarks.comm_analysis import run
    rows = run(quick=True)
    fetch = [r["remote_fetch_MB_per_epoch"] for r in rows]
    grad = [r["paper_gradient_MB_per_epoch"] for r in rows]
    assert fetch[0] < fetch[1] < fetch[2]
    assert grad[0] == grad[1] == grad[2]
    assert all(r["per_epoch_saving_x"] > 1 for r in rows)
