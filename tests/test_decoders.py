"""Decoder API (PR 4): the registered query-form protocol.

Registry-driven — every test parametrizes over ``registered_decoders()``,
so a newly registered decoder is swept automatically.  Contracts:

* ``Decoder.score`` IS the query form: composing ``prepare_query`` /
  ``prepare_candidates`` with the epilogue row-wise reproduces the direct
  score BITWISE (one stabilization, no second formula to drift) — including
  exact-duplicate (tied) entities and zero (pad-style) rows;
* the Pallas kernel path (``Decoder.rank_scores``) matches the XLA oracle
  (``score_against_candidates``) for every decoder, ragged shapes included;
* candidate-axis-sharded ranking == dense ``ranking_metrics`` EXACTLY
  (``==``, not allclose) at 1/2/4 shards for every decoder, with ties and
  duplicate gather ids, through the direct entry point, the
  ``ranking_metrics(num_shards=...)`` dispatch and the shard_map step;
* the safe-norm epilogue: TransE's old ``+1e-9``-inside-the-difference
  shift is gone (regression pinned);
* registry hygiene: unknown names raise, instances pass through.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.graph import make_synthetic_kg, split_train_valid_test
from repro.eval import (
    CSRFilterIndex, make_sharded_rank_step, ranking_metrics,
    sharded_ranking_metrics,
)
from repro.kernels.kge_score import NORM_EPS, apply_epilogue
from repro.models.decoders import (
    Decoder, get_decoder, init_decoder_params, registered_decoders,
    score_against_candidates, score_triplets,
)

DECODERS = registered_decoders()
SHARD_COUNTS = (1, 2, 4)
D = 16   # even: complex / rotate need re+im halves


def _params(name, n_rel=12, d=D, seed=0):
    return jax.tree_util.tree_map(
        np.asarray,
        init_decoder_params(jax.random.PRNGKey(seed), name, n_rel, d))


def _states(seed=0, v=40, d=D):
    """Vertex states with exact duplicates (ties) and an all-zero row (the
    padded-row shape a masked batch produces)."""
    rng = np.random.default_rng(seed)
    h = rng.normal(size=(v, d)).astype(np.float32)
    h[5] = h[2]          # duplicate → exact score ties
    h[v - 1] = 0.0       # zero (pad-style) row
    return h


class TestRegistry:
    def test_known_names(self):
        assert set(DECODERS) >= {"distmult", "transe", "complex", "rotate"}

    def test_unknown_raises(self):
        with pytest.raises(ValueError, match="unknown decoder"):
            get_decoder("holographic")

    def test_instance_passthrough(self):
        dec = get_decoder("transe")
        assert get_decoder(dec) is dec

    @pytest.mark.parametrize("name", DECODERS)
    def test_static_hashable(self, name):
        """Decoder singletons are frozen + hashable — safe jit statics."""
        dec = get_decoder(name)
        assert hash(dec) == hash(get_decoder(name))
        assert dec == get_decoder(name)


class TestQueryFormConsistency:
    @pytest.mark.parametrize("name", DECODERS)
    def test_score_is_the_query_form_bitwise(self, name):
        """Direct score == epilogue(q·c + q_bias + c_bias) composed from the
        prepare functions — EXACT equality, ties and zero rows included."""
        dec = get_decoder(name)
        p = _params(name)
        h = _states()
        rng = np.random.default_rng(1)
        trip = np.stack([rng.integers(0, 40, 64), rng.integers(0, 12, 64),
                         rng.integers(0, 40, 64)], 1).astype(np.int32)
        # force tied + zero-row triplets into the batch
        trip[0], trip[1] = (2, 0, 5), (5, 0, 2)
        trip[2] = (39, 1, 39)
        h_s, rel, h_t = jnp.asarray(h[trip[:, 0]]), \
            jnp.asarray(trip[:, 1]), jnp.asarray(h[trip[:, 2]])
        q, qb = dec.prepare_query(p, h_s, rel)
        c, cb = dec.prepare_candidates(p, h_t)
        composed = apply_epilogue(jnp.sum(q * c, axis=-1) + qb + cb,
                                  dec.epilogue)
        direct = dec.score(p, h_s, rel, h_t)
        np.testing.assert_array_equal(np.asarray(direct),
                                      np.asarray(composed))
        # score_triplets (the training path) is the same function
        np.testing.assert_array_equal(
            np.asarray(score_triplets(p, name, jnp.asarray(h),
                                      jnp.asarray(trip))),
            np.asarray(direct))

    @pytest.mark.parametrize("name", DECODERS)
    def test_prepare_candidates_is_row_local(self, name):
        """Any row subset prepares identically to its slice of the full
        preparation — the property per-shard candidate blocks rely on."""
        dec = get_decoder(name)
        p = _params(name)
        h = jnp.asarray(_states(seed=2))
        full_c, full_cb = dec.prepare_candidates(p, h)
        idx = jnp.asarray([3, 0, 39, 5, 2, 17])
        sub_c, sub_cb = dec.prepare_candidates(p, h[idx])
        np.testing.assert_array_equal(np.asarray(sub_c),
                                      np.asarray(full_c[idx]))
        np.testing.assert_array_equal(np.asarray(sub_cb),
                                      np.asarray(full_cb[idx]))

    @pytest.mark.parametrize("name", DECODERS)
    @pytest.mark.parametrize("b,c", [(5, 37), (64, 301)])
    def test_kernel_matches_xla_ragged(self, name, b, c):
        """rank_scores (Pallas, block-padded) vs the XLA oracle on ragged
        shapes with a filter mask."""
        dec = get_decoder(name)
        p = _params(name)
        rng = np.random.default_rng(b * c)
        h_s = jnp.asarray(rng.normal(size=(b, D)).astype(np.float32))
        rel = jnp.asarray(rng.integers(0, 12, b).astype(np.int32))
        cand = jnp.asarray(rng.normal(size=(c, D)).astype(np.float32))
        bias = jnp.asarray(np.where(rng.random((b, c)) < 0.2, -1e9, 0.0)
                           .astype(np.float32))
        got = dec.rank_scores(p, h_s, rel, cand, bias)
        want = score_against_candidates(p, name, h_s, rel, cand, bias)
        assert got.shape == (b, c)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestSafeNorm:
    def test_transe_exact_translation_is_norm_eps_floor(self):
        """h + r == t scores exactly -sqrt(NORM_EPS) — the old
        ``+1e-9``-inside-the-difference shifted every score instead."""
        dec = get_decoder("transe")
        p = {"rel_vec": jnp.asarray([[0.5, -1.0, 0.0, 2.0]])}
        s = jnp.asarray([[1.0, 1.0, 1.0, 1.0]])
        t = s + p["rel_vec"]
        got = float(dec.score(p, s, jnp.zeros(1, jnp.int32), t)[0])
        assert got == pytest.approx(-np.sqrt(NORM_EPS), rel=1e-6)

    def test_neg_l2_direct_vs_candidate_identical_stabilization(self):
        """The same triplet scored directly and as a candidate row uses ONE
        stabilization: kernel/XLA column equals the direct score to float
        tolerance, with no constant offset."""
        for name in ("transe", "rotate"):
            dec = get_decoder(name)
            p = _params(name, n_rel=4)
            h = _states(seed=3, v=20)
            h_s = jnp.asarray(h[:8])
            rel = jnp.asarray(np.arange(8) % 4)
            direct = dec.score(p, h_s, rel, jnp.asarray(h[8:16]))
            col = score_against_candidates(
                p, name, h_s, rel, jnp.asarray(h))[np.arange(8),
                                                   np.arange(8, 16)]
            np.testing.assert_allclose(np.asarray(direct), np.asarray(col),
                                       rtol=1e-5, atol=1e-5)

    def test_neg_l2_epilogue_matches_norm(self):
        """Away from the eps floor the expansion equals the plain norm."""
        rng = np.random.default_rng(4)
        u = rng.normal(size=(32, D)).astype(np.float32)
        c = rng.normal(size=(32, D)).astype(np.float32)
        x = np.sum(u * u, 1) + np.sum(c * c, 1) - 2 * np.sum(u * c, 1)
        got = np.asarray(apply_epilogue(jnp.asarray(x), "neg_l2"))
        want = -np.linalg.norm(u - c, axis=1)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def _ranking_setup(name, seed=0, n=203, n_rel=6, n_test=60):
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(n, D)).astype(np.float32)
    emb[7] = emb[3]                    # ties across shard boundaries
    emb[n - 1] = emb[11]
    p = _params(name, n_rel=2 * n_rel, seed=seed)
    kg = make_synthetic_kg(n, n_rel, 1400, seed=seed)
    splits = split_train_valid_test(kg)
    fidx = CSRFilterIndex.build(
        [g.with_inverse_relations() for g in splits.values()])
    tests = splits["test"].with_inverse_relations().triplets()[:n_test]
    tests = np.concatenate([tests, tests[:5]])   # duplicate gather ids
    return emb, p, tests, fidx


class TestShardedEqualsDenseEveryDecoder:
    """The tentpole acceptance: with ``num_shards > 1`` EVERY registered
    decoder ranks candidate-axis-sharded and lands EXACTLY on its dense
    reference."""

    @pytest.mark.parametrize("name", DECODERS)
    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_exactly_equals_dense(self, name, s):
        emb, p, tests, fidx = _ranking_setup(name)
        m_dense = ranking_metrics(emb, p, tests, fidx, decoder=name)
        m_sh = sharded_ranking_metrics(emb, p, tests, fidx, s, decoder=name)
        assert m_sh == m_dense                 # exact, not allclose

    @pytest.mark.parametrize("name", DECODERS)
    def test_dispatch_through_ranking_metrics(self, name):
        emb, p, tests, fidx = _ranking_setup(name, seed=1)
        m_dense = ranking_metrics(emb, p, tests, fidx, decoder=name)
        m_sh = ranking_metrics(emb, p, tests, fidx, decoder=name,
                               num_shards=2)
        assert m_sh == m_dense

    @pytest.mark.parametrize("name", DECODERS)
    def test_shard_map_step_matches_dense(self, name):
        """1×1 host mesh smoke of the shard_map + psum path per decoder
        (the 2-device subprocess sweep is slow-marked)."""
        from repro.launch.mesh import make_host_mesh
        emb, p, tests, fidx = _ranking_setup(name, seed=2, n_test=40)
        step = make_sharded_rank_step(make_host_mesh(1, 1), decoder=name)
        m_spmd = sharded_ranking_metrics(emb, p, tests, fidx, 1,
                                         decoder=name, rank_step=step)
        assert m_spmd == ranking_metrics(emb, p, tests, fidx, decoder=name)

    def test_mismatched_rank_step_fails_fast(self):
        """A shard_map step built for one decoder must be rejected when
        ranking runs another — mismatched scores would be silently wrong."""
        from repro.launch.mesh import make_host_mesh
        emb, p, tests, fidx = _ranking_setup("transe", seed=4, n_test=10)
        step = make_sharded_rank_step(make_host_mesh(1, 1),
                                      decoder="distmult")
        with pytest.raises(ValueError, match="rank_step was built"):
            sharded_ranking_metrics(emb, p, tests, fidx, 1,
                                    decoder="transe", rank_step=step)

    @pytest.mark.parametrize("name", DECODERS)
    def test_ogbl_candidate_path(self, name):
        """The per-test candidate-list protocol rides the query form for
        every decoder; metrics stay sane and the true tail never competes
        against itself."""
        emb, p, tests, _ = _ranking_setup(name, seed=3, n_test=40)
        rng = np.random.default_rng(7)
        cands = rng.integers(0, emb.shape[0],
                             (tests.shape[0], 20)).astype(np.int32)
        m = ranking_metrics(emb, p, tests, {}, candidates=cands,
                            decoder=name)
        assert 0.0 < m["mrr"] <= 1.0
        assert m["hits@1"] <= m["hits@3"] <= m["hits@10"]


class TestDecoderInstanceThreading:
    def test_config_accepts_instance(self):
        """KGEConfig carries a Decoder instance end to end (strings resolve
        only inside the registry)."""
        from repro.models import KGEConfig, RGCNConfig
        dec = get_decoder("rotate")
        cfg = KGEConfig(rgcn=RGCNConfig(num_entities=10, num_relations=2,
                                        hidden_dim=D), decoder=dec)
        assert cfg.decoder_impl is dec
        assert isinstance(cfg.decoder_impl, Decoder)

    @pytest.mark.slow
    @pytest.mark.parametrize("name", [d for d in DECODERS
                                      if d != "distmult"])
    def test_trainer_sharded_eval_every_decoder(self, name):
        """Short full-graph training per non-default decoder: 2-shard
        trainer metrics EXACTLY equal the dense trainer's (the distmult
        twin runs in tier-1 via test_eval_ranking)."""
        from repro.data import synthetic_fb15k
        from repro.training import KGETrainer, TrainConfig
        splits = synthetic_fb15k(scale=0.01, seed=5)
        metrics = {}
        for s in (1, 2):
            tr = KGETrainer(splits, TrainConfig(
                num_trainers=2, epochs=2, hidden_dim=D, batch_size=None,
                learning_rate=0.05, seed=0, decoder=name,
                num_table_shards=s))
            tr.fit()
            metrics[s] = tr.evaluate("valid")
            tr.close()
        assert metrics[2] == metrics[1]
        assert 0.0 < metrics[1]["valid_mrr"] <= 1.0
