"""Constraint-based negative sampling (§3.3.1) + edge mini-batch (§3.3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    build_comp_graph, build_edge_minibatch,
    constraint_based_negatives, global_closed_world_negatives,
    iterate_edge_minibatches, mix_pos_neg, plan_budgets,
    sample_epoch_negatives, stack_minibatches,
)


class TestConstraintNegatives:
    def test_locality_invariant(self, partitioned):
        """THE paper property: every corrupted entity is a core vertex of
        the local partition — zero cross-partition references."""
        _, expanded = partitioned
        for sp in expanded:
            rng = np.random.default_rng(0)
            neg = sample_epoch_negatives(rng, sp, num_negatives=3)
            assert (neg[:, 0] < sp.num_core_vertices).all()
            assert (neg[:, 2] < sp.num_core_vertices).all()

    def test_device_sampler_locality(self):
        key = jax.random.PRNGKey(0)
        pos = jnp.asarray(
            np.stack([np.arange(50), np.zeros(50), np.arange(50) + 1],
                     axis=1), jnp.int32)
        neg, is_head = constraint_based_negatives(
            key, pos, 4, jnp.int32(13))
        assert neg.shape == (200, 3)
        corrupted = jnp.where(is_head, neg[:, 0], neg[:, 2])
        assert bool((corrupted < 13).all())
        # uncorrupted side is preserved
        kept = jnp.where(is_head, neg[:, 2], neg[:, 0])
        orig = jnp.repeat(pos, 4, axis=0)
        orig_kept = jnp.where(is_head, orig[:, 2], orig[:, 0])
        assert bool((kept == orig_kept).all())

    def test_global_sampler_range(self):
        key = jax.random.PRNGKey(1)
        pos = jnp.zeros((10, 3), jnp.int32)
        neg, _ = global_closed_world_negatives(key, pos, 2, 1000)
        assert bool((neg < 1000).all())

    def test_mix_labels(self):
        pos = jnp.zeros((5, 3), jnp.int32)
        neg = jnp.ones((10, 3), jnp.int32)
        trip, labels = mix_pos_neg(pos, neg)
        assert trip.shape == (15, 3)
        assert float(labels[:5].sum()) == 5.0
        assert float(labels[5:].sum()) == 0.0

    @settings(max_examples=20, deadline=None)
    @given(limit=st.integers(1, 64), s=st.integers(1, 8),
           seed=st.integers(0, 100))
    def test_property_candidate_range(self, limit, s, seed):
        key = jax.random.PRNGKey(seed)
        pos = jnp.asarray(
            np.random.default_rng(seed).integers(0, 100, (17, 3)),
            jnp.int32)
        neg, is_head = constraint_based_negatives(
            key, pos, s, jnp.int32(limit))
        corrupted = jnp.where(is_head, neg[:, 0], neg[:, 2])
        assert bool((corrupted >= 0).all()) and \
            bool((corrupted < limit).all())


class TestCompGraph:
    def test_seeds_covered(self, partitioned):
        _, expanded = partitioned
        sp = expanded[0]
        seeds = np.unique(sp.core_edges_local()[:20, [0, 2]].reshape(-1))
        verts, eids = build_comp_graph(sp, seeds, num_hops=2)
        assert np.isin(seeds, verts).all()

    def test_hop_closure(self, partitioned):
        """Every in-edge of a seed must be in the 1-hop comp graph."""
        _, expanded = partitioned
        sp = expanded[0]
        seeds = np.array([0, 1, 2])
        verts, eids = build_comp_graph(sp, seeds, num_hops=1)
        in_seed = np.isin(sp.src, seeds)
        assert np.isin(np.nonzero(in_seed)[0], eids).all()

    def test_budget_enforced(self, partitioned):
        _, expanded = partitioned
        sp = expanded[0]
        pos = sp.core_edges_local()[:8]
        labels = np.ones(8, np.float32)
        with pytest.raises(ValueError):
            build_edge_minibatch(sp, pos, labels, 2, max_vertices=2,
                                 max_edges=2, max_triplets=128)

    def test_minibatch_shapes_and_masks(self, partitioned):
        _, expanded = partitioned
        budget = plan_budgets(expanded, 32, 2, 2)
        rng = np.random.default_rng(0)
        mbs = [next(iterate_edge_minibatches(rng, sp, 32, 2, 2, budget))
               for sp in expanded]
        st_ = stack_minibatches(mbs)
        assert st_.gather_ids.shape == (4, budget.max_vertices)
        assert st_.comp_src.shape == (4, budget.max_edges)
        # batch-local triplet ids must be inside the comp graph vertex set
        for i, mb in enumerate(mbs):
            nt = int(mb.triplet_mask.sum())
            nv = int(mb.vertex_mask.sum())
            assert (mb.triplets[:nt, [0, 2]] < nv).all()
            # gather_global consistency
            assert (mb.gather_global[:nv] ==
                    expanded[i].local_to_global[
                        mb.gather_ids[:nv]]).all()

    def test_epoch_covers_all_positives(self, partitioned):
        _, expanded = partitioned
        sp = expanded[0]
        budget = plan_budgets([sp], 64, 1, 2)
        rng = np.random.default_rng(1)
        seen = 0
        for mb in iterate_edge_minibatches(rng, sp, 64, 1, 2, budget):
            seen += int((mb.labels[mb.triplet_mask] > 0.5).sum())
        assert seen == sp.num_core_edges
