import os
import sys

# src layout import without install (+ repo root for benchmarks.*)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest

from repro.core import (
    KnowledgeGraph, make_synthetic_kg, expand_all, partition_graph,
)

# fixed-seed hypothesis profile for CI: derandomized (reproducible
# failures, no flaky shrink paths in the tier-1 gate) with a bounded
# example budget; select with --hypothesis-profile=ci
try:
    from hypothesis import settings

    settings.register_profile(
        "ci", settings(derandomize=True, max_examples=50, deadline=None))
except ImportError:                      # shim path — profile is a no-op
    pass


@pytest.fixture(scope="session")
def small_kg() -> KnowledgeGraph:
    return make_synthetic_kg(300, 10, 2500, seed=7).with_inverse_relations()


@pytest.fixture(scope="session")
def partitioned(small_kg):
    parts = partition_graph(small_kg, 4, "vertex_cut", seed=0)
    return parts, expand_all(small_kg, parts, num_hops=2)
