"""Sharded-entity-table equivalence suite (repro.sharding.embedding).

The contract under test: row-sharding the entity embedding table over the
``model`` axis — shard-local gather + exchange, driven by host-precomputed
``ShardedGatherPlan``s or the identical in-jit plan — is BITWISE equal to
the replicated dense gather for forward, loss and gradients, at 1, 2 and 4
shards on the simulated mesh, including out-of-order and duplicate gather
indices.  Exactly one shard owns each row, so every output element is one
real value plus zeros, and the transpose scatter-adds the same cotangents
per row.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import expand_all, pad_partitions, partition_graph, \
    plan_budgets
from repro.data.pipeline import SerialMinibatchPipeline
from repro.models import (
    KGEConfig, RGCNConfig, fullgraph_loss, init_kge_params, minibatch_loss,
)
from repro.sharding.embedding import (
    ShardedGatherPlan, ShardedTableLayout, convert_table_layout,
    dequantize_rows, plan_local_gather, plan_local_gather_device,
    quantize_rows, shard_table, sharded_gather, unshard_table,
)

SHARD_COUNTS = (1, 2, 4)


def _tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ====================================================================== #
# Layout + plans
# ====================================================================== #
class TestLayout:
    @pytest.mark.parametrize("v,s", [(300, 1), (300, 2), (301, 4), (7, 4)])
    def test_shard_unshard_roundtrip(self, v, s):
        lay = ShardedTableLayout(v, s)
        table = np.random.default_rng(0).normal(
            size=(v, 8)).astype(np.float32)
        sh = shard_table(table, lay)
        assert sh.shape == (s, lay.rows_per_shard, 8)
        assert lay.padded_rows >= v
        np.testing.assert_array_equal(unshard_table(sh, v), table)

    def test_bytes_per_device_shrink_inverse_in_shards(self):
        lay1 = ShardedTableLayout(4096, 1)
        for s in (2, 4, 8):
            lays = ShardedTableLayout(4096, s)
            assert lays.bytes_per_shard(64) * s == lay1.bytes_per_shard(64)

    def test_invalid_layout_rejected(self):
        with pytest.raises(ValueError, match="invalid layout"):
            ShardedTableLayout(0, 2)

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_host_plan_matches_device_plan(self, s):
        lay = ShardedTableLayout(301, s)
        rng = np.random.default_rng(1)
        ids = rng.integers(0, 301, size=64).astype(np.int32)
        li, ow = plan_local_gather(lay, ids)
        li_d, ow_d = plan_local_gather_device(
            s, lay.rows_per_shard, jnp.asarray(ids))
        np.testing.assert_array_equal(li, np.asarray(li_d))
        np.testing.assert_array_equal(ow, np.asarray(ow_d))
        # exactly one shard owns every id; local ids stay in range
        np.testing.assert_array_equal(ow.sum(axis=0), np.ones(64))
        assert li.min() >= 0 and li.max() < lay.rows_per_shard

    def test_stacked_plan_layout(self):
        lay = ShardedTableLayout(100, 4)
        g = np.arange(12, dtype=np.int32).reshape(3, 4) * 7  # (P=3, V=4)
        plan = ShardedGatherPlan.for_stacked(lay, g)
        assert plan.local_ids.shape == plan.owned.shape == (3, 4, 4)
        for p in range(3):
            li, ow = plan_local_gather(lay, g[p])
            np.testing.assert_array_equal(plan.local_ids[p], li)
            np.testing.assert_array_equal(plan.owned[p], ow)


# ====================================================================== #
# Gather: forward + gradient bitwise vs dense, dup/out-of-order indices
# ====================================================================== #
class TestShardedGatherBitwise:
    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_forward_and_grad_match_dense(self, s):
        v, d = 301, 16
        table = jax.random.normal(jax.random.PRNGKey(0), (v, d))
        # out-of-order, duplicated, boundary-hitting gather indices
        ids = np.array([5, 3, 5, 0, v - 1, 3, 299, 150, 150, 7, 0, v - 1],
                       np.int32)
        lay = ShardedTableLayout(v, s)
        shards = shard_table(table, lay)
        li, ow = plan_local_gather(lay, ids)
        li, ow = jnp.asarray(li), jnp.asarray(ow)

        dense = np.asarray(table[ids])
        got = np.asarray(sharded_gather(shards, li, ow))
        np.testing.assert_array_equal(got, dense)

        w = jnp.arange(1.0, d + 1)

        def loss_dense(t):
            return jnp.sum(jnp.tanh(t[ids]) * w)

        def loss_sharded(t):
            return jnp.sum(jnp.tanh(sharded_gather(t, li, ow)) * w)

        g_dense = np.asarray(jax.grad(loss_dense)(table))
        g_sh = jax.grad(loss_sharded)(shards)
        np.testing.assert_array_equal(
            np.asarray(unshard_table(g_sh, v)), g_dense)
        # padding rows are never gathered -> exactly zero gradient
        pad = np.asarray(g_sh).reshape(-1, d)[v:]
        assert (pad == 0).all()

    def test_shard_map_branch_rejects_replicated_table(self):
        """Passing a full (S>1, rows, d) stack with an axis_name (i.e. a
        replicated table inside shard_map — param_specs forgotten) must
        fail at trace time, not psum S wrong-row gathers."""
        lay = ShardedTableLayout(40, 2)
        shards = shard_table(jnp.ones((40, 4)), lay)
        li, ow = plan_local_gather(lay, np.arange(8))
        with pytest.raises(ValueError, match="row block"):
            sharded_gather(shards, jnp.asarray(li), jnp.asarray(ow),
                           axis_name="model")

    @pytest.mark.parametrize("s", SHARD_COUNTS + (8,))
    def test_fused_matches_chain_exchange(self, s):
        """The fused flat-index default is bitwise the original
        take -> mask -> sum chain, forward and grad."""
        v, d = 301, 16
        table = jax.random.normal(jax.random.PRNGKey(1), (v, d))
        ids = np.array([5, 3, 5, 0, v - 1, 3, 299, 150, 150, 7, 0, v - 1],
                       np.int32)
        lay = ShardedTableLayout(v, s)
        shards = shard_table(table, lay)
        li, ow = plan_local_gather(lay, ids)
        li, ow = jnp.asarray(li), jnp.asarray(ow)
        np.testing.assert_array_equal(
            np.asarray(sharded_gather(shards, li, ow, exchange="fused")),
            np.asarray(sharded_gather(shards, li, ow,
                                      exchange="masked_sum")))
        w = jnp.arange(1.0, d + 1)
        g_f = jax.grad(lambda t: jnp.sum(jnp.tanh(sharded_gather(
            t, li, ow, exchange="fused")) * w))(shards)
        g_c = jax.grad(lambda t: jnp.sum(jnp.tanh(sharded_gather(
            t, li, ow, exchange="masked_sum")) * w))(shards)
        np.testing.assert_array_equal(np.asarray(g_f), np.asarray(g_c))

    def test_unknown_exchange_rejected(self):
        lay = ShardedTableLayout(40, 2)
        shards = shard_table(jnp.ones((40, 4)), lay)
        li, ow = plan_local_gather(lay, np.arange(8))
        li, ow = jnp.asarray(li), jnp.asarray(ow)
        with pytest.raises(ValueError, match="unknown sim exchange"):
            sharded_gather(shards, li, ow, exchange="psum")
        with pytest.raises(ValueError, match="unknown shard_map exchange"):
            jax.vmap(lambda t: sharded_gather(
                t[None], li, ow, axis_name="model", exchange="fused"),
                axis_name="model")(shards)


# ====================================================================== #
# Exchange layouts under a named axis: psum / psum_scatter / alltoall
# ====================================================================== #
class TestExchangeLayouts:
    """``jax.vmap(axis_name=...)`` drives the shard_map code path (same
    collectives, rank-1 mesh semantics) cheaply on one device: every
    exchange layout must be bitwise equal to the dense gather, including
    a V that is NOT a multiple of S (the pad-around-collective path)."""

    @pytest.mark.parametrize("s", SHARD_COUNTS + (8,))
    @pytest.mark.parametrize("exchange",
                             ("psum", "psum_scatter", "alltoall"))
    def test_exchange_bitwise_vs_dense(self, s, exchange):
        v, d, nids = 301, 16, 41        # 41 % s != 0 for s in (2, 4, 8)
        rng = np.random.default_rng(s)
        table = jax.random.normal(jax.random.PRNGKey(2), (v, d))
        ids = np.concatenate([rng.integers(0, v, nids - 4),
                              [0, v - 1, 5, 5]]).astype(np.int32)
        lay = ShardedTableLayout(v, s)
        shards = shard_table(table, lay)
        li, ow = plan_local_gather(lay, ids)
        li, ow = jnp.asarray(li), jnp.asarray(ow)
        out = jax.vmap(lambda t: sharded_gather(
            t[None], li, ow, axis_name="model", exchange=exchange),
            axis_name="model")(shards)
        dense = np.asarray(table[ids])
        for shard in range(s):          # exchange output is replicated
            np.testing.assert_array_equal(np.asarray(out[shard]), dense)

    @pytest.mark.parametrize("exchange",
                             ("psum", "psum_scatter", "alltoall"))
    def test_exchange_grads_bitwise_vs_dense(self, exchange):
        v, d, s = 201, 8, 4
        table = jax.random.normal(jax.random.PRNGKey(3), (v, d))
        ids = np.array([7, 7, 0, v - 1, 50, 50, 50, 3, 9], np.int32)
        lay = ShardedTableLayout(v, s)
        shards = shard_table(table, lay)
        li, ow = plan_local_gather(lay, ids)
        li, ow = jnp.asarray(li), jnp.asarray(ow)
        w = jnp.arange(1.0, d + 1)

        def loss(stack):
            out = jax.vmap(lambda t: sharded_gather(
                t[None], li, ow, axis_name="model", exchange=exchange),
                axis_name="model")(stack)
            # vmap inlines the exchange's custom VJP (jax 0.4 batching), so
            # this path exercises the COLLECTIVE-TRANSPOSE backward: the
            # loss must consume the replicated output exactly once (shard
            # 0's copy) for the broadcast cotangent to match dense.  The
            # real shard_map path instead computes the loss replicated on
            # every device and uses the identity backward — gated bitwise
            # by the 2-device subprocess tests (test_sharded_embedding /
            # test_distributed slow tier).
            return jnp.sum(jnp.tanh(out[0]) * w)

        g_sh = jax.grad(loss)(shards)
        g_d = jax.grad(lambda t: jnp.sum(jnp.tanh(t[ids]) * w))(table)
        np.testing.assert_array_equal(
            np.asarray(unshard_table(g_sh, v)), np.asarray(g_d))


# ====================================================================== #
# Plan dedup: unique-id gather + on-device inverse expansion
# ====================================================================== #
class TestDedupPlans:
    def _check(self, lay, table, dense, ids, pad_multiple=8):
        from repro.sharding.embedding import plan_unique_gather
        li, ow, inv = plan_unique_gather(lay, ids,
                                         pad_multiple=pad_multiple)
        u = len(np.unique(ids))
        assert li.shape[1] % pad_multiple == 0 and li.shape[1] >= u
        # padding slots are owned by NO shard -> exact zero rows
        np.testing.assert_array_equal(ow.sum(axis=0)[:u], np.ones(u))
        np.testing.assert_array_equal(ow.sum(axis=0)[u:],
                                      np.zeros(li.shape[1] - u))
        out = sharded_gather(table, jnp.asarray(li), jnp.asarray(ow),
                             inverse=jnp.asarray(inv))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(dense[ids]))
        return li, ow, inv

    @pytest.mark.parametrize("s", SHARD_COUNTS + (8,))
    def test_dedup_bitwise_vs_dense(self, s):
        v, d = 301, 16
        dense = jax.random.normal(jax.random.PRNGKey(4), (v, d))
        lay = ShardedTableLayout(v, s)
        table = shard_table(dense, lay)
        ids = np.array([5, 3, 5, 0, v - 1, 3, 299, 150, 150, 7, 0, v - 1],
                       np.int32)
        li, _, _ = self._check(lay, table, dense, ids)
        assert li.shape[1] < len(ids) + 8   # it actually deduped

    def test_all_duplicate_batch(self):
        """Every slot the same id: one exchanged row, V-way expansion, and
        the gradient accumulates V cotangents into ONE row — bitwise equal
        to the dense gather's scatter-add."""
        v, d, s = 120, 8, 4
        dense = jax.random.normal(jax.random.PRNGKey(5), (v, d))
        lay = ShardedTableLayout(v, s)
        table = shard_table(dense, lay)
        ids = np.full(17, 42, np.int32)
        li, ow, inv = self._check(lay, table, dense, ids)
        assert ow.sum() == 1                      # one owned slot total
        w = jnp.arange(1.0, d + 1)
        g_sh = jax.grad(lambda t: jnp.sum(jnp.tanh(sharded_gather(
            t, jnp.asarray(li), jnp.asarray(ow),
            inverse=jnp.asarray(inv))) * w))(table)
        g_d = jax.grad(
            lambda t: jnp.sum(jnp.tanh(t[ids]) * w))(dense)
        np.testing.assert_array_equal(
            np.asarray(unshard_table(g_sh, v)), np.asarray(g_d))

    def test_single_shard_batch(self):
        """A batch whose ids all live on one shard: the other shards own
        nothing and contribute exact zeros."""
        v, d, s = 200, 8, 4
        dense = jax.random.normal(jax.random.PRNGKey(6), (v, d))
        lay = ShardedTableLayout(v, s)
        table = shard_table(dense, lay)
        rows = lay.rows_per_shard
        ids = np.arange(2 * rows, 2 * rows + 10, dtype=np.int32)  # shard 2
        li, ow, _ = self._check(lay, table, dense, ids)
        assert (ow[[0, 1, 3]] == 0).all() and ow[2].sum() == 10

    def test_empty_shards_on_ragged_block(self):
        """Ragged last shard (301 rows / 4 shards -> 3 pad rows): ids
        clustered at the front leave the tail shard completely unowned,
        and the layout's zero-padded tail rows are never touched."""
        v, d, s = 301, 8, 4
        dense = jax.random.normal(jax.random.PRNGKey(7), (v, d))
        lay = ShardedTableLayout(v, s)
        assert lay.padded_rows > v   # genuinely ragged
        table = shard_table(dense, lay)
        ids = np.array([0, 1, 2, 1, 0, 2, 2], np.int32)
        li, ow, inv = self._check(lay, table, dense, ids)
        assert ow[-1].sum() == 0     # tail shard owns nothing
        g_sh = jax.grad(lambda t: jnp.sum(sharded_gather(
            t, jnp.asarray(li), jnp.asarray(ow),
            inverse=jnp.asarray(inv)) ** 2))(table)
        pad = np.asarray(g_sh).reshape(-1, d)[v:]
        assert (pad == 0).all()      # padding rows get exactly zero grad

    def test_grad_accumulation_head_and_tail_dup(self):
        """One id in both the first and last slot: the inverse expansion's
        transpose must accumulate both slots' cotangents into the single
        exchanged row — bitwise vs dense (same scatter-add order)."""
        v, d, s = 150, 8, 2
        dense = jax.random.normal(jax.random.PRNGKey(8), (v, d))
        lay = ShardedTableLayout(v, s)
        table = shard_table(dense, lay)
        ids = np.array([99] + list(range(10, 20)) + [99], np.int32)
        from repro.sharding.embedding import plan_unique_gather
        li, ow, inv = plan_unique_gather(lay, ids, pad_multiple=8)
        li, ow, inv = jnp.asarray(li), jnp.asarray(ow), jnp.asarray(inv)
        # distinct per-slot weights so head/tail cotangents differ
        w = jnp.arange(1.0, len(ids) + 1)[:, None] * jnp.arange(1.0, d + 1)
        g_sh = jax.grad(lambda t: jnp.sum(jnp.tanh(sharded_gather(
            t, li, ow, inverse=inv)) * w))(table)
        g_d = jax.grad(lambda t: jnp.sum(jnp.tanh(t[ids]) * w))(dense)
        np.testing.assert_array_equal(
            np.asarray(unshard_table(g_sh, v)), np.asarray(g_d))

    def test_stacked_dedup_plan(self):
        """for_stacked(dedup=True): per-row uniques share one bucket, and
        every row's inverse expansion reproduces its dense gather."""
        lay = ShardedTableLayout(100, 4)
        g = np.array([[7, 7, 7, 7, 7, 7],          # 1 unique
                      [0, 99, 0, 99, 50, 50],      # 3 uniques
                      [1, 2, 3, 4, 5, 6]], np.int32)   # 6 uniques
        plan = ShardedGatherPlan.for_stacked(lay, g, dedup=True,
                                             pad_multiple=4)
        assert plan.local_ids.shape == (3, 4, 8)   # bucket = ceil(6/4)*4
        assert plan.inverse.shape == g.shape
        dense = jax.random.normal(jax.random.PRNGKey(9), (100, 8))
        table = shard_table(dense, lay)
        for p in range(3):
            out = sharded_gather(
                table, jnp.asarray(plan.local_ids[p]),
                jnp.asarray(plan.owned[p]),
                inverse=jnp.asarray(plan.inverse[p]))
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(dense[g[p]]))

    def test_plan_unique_rejects_stacked_input(self):
        from repro.sharding.embedding import plan_unique_gather
        with pytest.raises(ValueError, match="expects"):
            plan_unique_gather(ShardedTableLayout(10, 2),
                               np.zeros((2, 3), np.int32))


# ====================================================================== #
# Model-level equivalence: vertex_input / losses / gradients
# ====================================================================== #
def _configs(kg, s):
    rgcn = dict(num_entities=kg.num_entities, num_relations=kg.num_relations,
                hidden_dim=16, num_layers=2, num_bases=2, dropout=0.0)
    dense = KGEConfig(rgcn=RGCNConfig(**rgcn))
    sharded = KGEConfig(rgcn=RGCNConfig(**rgcn, num_table_shards=s))
    return dense, sharded


def _sharded_params(params, kg, s):
    out = dict(params)
    out["entity_embedding"] = shard_table(
        params["entity_embedding"], ShardedTableLayout(kg.num_entities, s))
    return out


class TestModelEquivalence:
    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_minibatch_loss_and_grads_bitwise(self, small_kg, s):
        parts = expand_all(
            small_kg, partition_graph(small_kg, 2, "vertex_cut", seed=0), 2)
        budget = plan_budgets(parts, 32, 1, 2, seed=0)
        pipe = SerialMinibatchPipeline(
            parts, batch_size=32, num_negatives=1, num_hops=2,
            budget=budget, seed=5,
            table_layout=ShardedTableLayout(small_kg.num_entities, s))
        batch = next(pipe.device_batches(1))
        b0 = jax.tree_util.tree_map(lambda x: x[0], batch)
        assert b0["shard_local_ids"].shape[0] == s

        cfg_d, cfg_s = _configs(small_kg, s)
        p_dense = init_kge_params(jax.random.PRNGKey(0), cfg_d)
        p_shard = _sharded_params(p_dense, small_kg, s)
        if s > 1:   # same key => init produces the sharded layout directly
            _tree_equal(p_shard, init_kge_params(jax.random.PRNGKey(0),
                                                 cfg_s))

        def ld(p):
            return minibatch_loss(p, cfg_d, b0)[0]

        def ls(p):
            return minibatch_loss(p, cfg_s, b0)[0]

        (l_d, g_d) = jax.value_and_grad(ld)(p_dense)
        (l_s, g_s) = jax.value_and_grad(ls)(p_shard)
        assert float(l_d) == float(l_s)
        g_s = dict(g_s)
        g_s["entity_embedding"] = unshard_table(
            g_s["entity_embedding"], small_kg.num_entities)
        _tree_equal(g_d, g_s)

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_fullgraph_loss_bitwise_with_on_the_fly_plan(self, small_kg, s):
        """Paths that build gather ids on device (full-graph training,
        evaluation) use the in-jit plan — same result, no host plan."""
        parts = expand_all(
            small_kg, partition_graph(small_kg, 2, "vertex_cut", seed=0), 2)
        pb = pad_partitions(parts)
        part0 = {f.name: jnp.asarray(getattr(pb, f.name)[0])
                 for f in dataclasses.fields(pb)}
        cfg_d, cfg_s = _configs(small_kg, s)
        p_dense = init_kge_params(jax.random.PRNGKey(0), cfg_d)
        p_shard = _sharded_params(p_dense, small_kg, s)
        key = jax.random.PRNGKey(3)
        l_d, _ = fullgraph_loss(p_dense, cfg_d, part0, key, train=False)
        l_s, _ = fullgraph_loss(p_shard, cfg_s, part0, key, train=False)
        assert float(l_d) == float(l_s)

        g_d = jax.grad(lambda p: fullgraph_loss(
            p, cfg_d, part0, key, train=False)[0])(p_dense)
        g_s = dict(jax.grad(lambda p: fullgraph_loss(
            p, cfg_s, part0, key, train=False)[0])(p_shard))
        g_s["entity_embedding"] = unshard_table(
            g_s["entity_embedding"], small_kg.num_entities)
        _tree_equal(g_d, g_s)

    def test_encode_all_entities_matches(self, small_kg):
        from repro.training.evaluation import encode_all_entities
        cfg_d, cfg_s = _configs(small_kg, 2)
        p_dense = init_kge_params(jax.random.PRNGKey(0), cfg_d)
        p_shard = _sharded_params(p_dense, small_kg, 2)
        e_d = encode_all_entities(p_dense, cfg_d, small_kg, 2)
        e_s = encode_all_entities(p_shard, cfg_s, small_kg, 2)
        np.testing.assert_array_equal(e_d, e_s)


# ====================================================================== #
# Trainer-level: full training runs are bitwise identical
# ====================================================================== #
class TestTrainerEquivalence:
    def test_two_shard_minibatch_training_matches(self):
        from repro.data import synthetic_fb15k
        from repro.training import KGETrainer, TrainConfig
        splits = synthetic_fb15k(scale=0.01, seed=3)
        losses = {}
        for s in (1, 2):
            tr = KGETrainer(splits, TrainConfig(
                num_trainers=2, epochs=2, hidden_dim=16, batch_size=64,
                num_negatives=1, learning_rate=0.01, seed=0,
                num_table_shards=s))
            losses[s] = [h["loss"] for h in tr.fit()]
            tr.close()
        assert losses[1] == losses[2]

    @pytest.mark.slow
    def test_multi_shard_sweep_minibatch_and_fullgraph(self):
        """The full equivalence sweep (1, 2, 4 shards × both training
        modes × eval) — the tentpole acceptance run."""
        from repro.data import synthetic_fb15k
        from repro.training import KGETrainer, TrainConfig
        splits = synthetic_fb15k(scale=0.015, seed=3)
        for batch_size in (64, None):          # mini-batch and full-graph
            losses, mrrs = {}, {}
            for s in SHARD_COUNTS:
                tr = KGETrainer(splits, TrainConfig(
                    num_trainers=2, epochs=3, hidden_dim=16,
                    batch_size=batch_size, num_negatives=1,
                    learning_rate=0.01, seed=0, num_table_shards=s))
                losses[s] = [h["loss"] for h in tr.fit()]
                mrrs[s] = tr.evaluate("valid")["valid_mrr"]
                tr.close()
            assert losses[1] == losses[2] == losses[4], (batch_size, losses)
            assert mrrs[1] == mrrs[2] == mrrs[4], (batch_size, mrrs)

    def test_dedup_training_matches(self):
        """gather_dedup rearranges the exchange payload, never the math:
        the full loss trajectory is identical to the non-deduped run."""
        from repro.data import synthetic_fb15k
        from repro.training import KGETrainer, TrainConfig
        splits = synthetic_fb15k(scale=0.01, seed=3)
        losses = {}
        for dedup in (False, True):
            tr = KGETrainer(splits, TrainConfig(
                num_trainers=2, epochs=2, hidden_dim=16, batch_size=64,
                num_negatives=1, learning_rate=0.01, seed=0,
                num_table_shards=2, gather_dedup=dedup))
            if dedup:   # the deduped batch really carries the inverse map
                batch = next(tr.pipeline.device_batches(1))
                assert "shard_inverse" in batch
                assert batch["shard_local_ids"].shape[-1] <= \
                    batch["shard_inverse"].shape[-1] + 64
            losses[dedup] = [h["loss"] for h in tr.fit()]
            tr.close()
        assert losses[False] == losses[True]

    def test_masked_sum_exchange_training_matches_fused(self):
        """The legacy chain exchange and the fused default train
        identically (the fused path's bitwise contract, trainer-level)."""
        from repro.data import synthetic_fb15k
        from repro.training import KGETrainer, TrainConfig
        splits = synthetic_fb15k(scale=0.01, seed=3)
        losses = {}
        for exchange in (None, "masked_sum"):
            tr = KGETrainer(splits, TrainConfig(
                num_trainers=2, epochs=2, hidden_dim=16, batch_size=64,
                num_negatives=1, learning_rate=0.01, seed=0,
                num_table_shards=2, gather_exchange=exchange))
            losses[exchange] = [h["loss"] for h in tr.fit()]
            tr.close()
        assert losses[None] == losses["masked_sum"]

    def test_feature_mode_rejects_sharding(self):
        from repro.data import synthetic_citation2
        from repro.training import KGETrainer, TrainConfig
        splits = synthetic_citation2(scale=0.0003, seed=0)
        with pytest.raises(ValueError, match="learned entity embeddings"):
            KGETrainer(splits, TrainConfig(
                num_trainers=2, epochs=1, batch_size=64,
                num_table_shards=2))


# ====================================================================== #
# shard_map step: sharded params survive the real-mesh code path
# ====================================================================== #
class TestSpmdStep:
    def test_spmd_step_with_sharded_table_matches_simulation(self, small_kg):
        """1×1 host mesh smoke: the shard_map step with a sharded-layout
        table + kge_param_specs + psum exchange runs and matches the vmap
        simulation (multi-device meshes change only the axis size)."""
        from repro.launch.mesh import make_host_mesh
        from repro.sharding import kge_param_specs
        from repro.training import adam
        from repro.training.distributed import (
            make_simulated_train_step, make_spmd_train_step,
        )
        mesh = make_host_mesh(1, 1)
        parts = expand_all(
            small_kg, partition_graph(small_kg, 1, "vertex_cut", seed=0), 2)
        pb = pad_partitions(parts)
        batch = {f.name: jnp.asarray(getattr(pb, f.name))
                 for f in dataclasses.fields(pb)}
        _, cfg = _configs(small_kg, 1)
        params = init_kge_params(jax.random.PRNGKey(0), cfg)
        assert params["entity_embedding"].ndim == 2  # s=1 stays dense
        cfg_s = KGEConfig(rgcn=dataclasses.replace(
            cfg.rgcn, num_table_shards=1))
        p_shard = _sharded_params(params, small_kg, 1)
        specs = kge_param_specs(p_shard, mesh)
        opt = adam(0.01)
        keys = jax.random.split(jax.random.PRNGKey(2), 1)

        def loss_spmd(p, b, k):
            return fullgraph_loss(p, cfg_s, b, k, train=False,
                                  model_axis="model")

        def loss_sim(p, b, k):
            return fullgraph_loss(p, cfg_s, b, k, train=False)

        step_spmd = make_spmd_train_step(loss_spmd, opt, mesh,
                                         param_specs=specs)
        step_sim = make_simulated_train_step(loss_sim, opt)
        p1, _, m1 = step_spmd(p_shard, opt.init(p_shard), batch, keys)
        p2, _, m2 = step_sim(p_shard, opt.init(p_shard), batch, keys)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                  rel=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-6)


# ====================================================================== #
# Checkpoint layout conversion primitive
# ====================================================================== #
class TestLayoutConversion:
    def test_dense_sharded_roundtrips(self):
        rng = np.random.default_rng(2)
        dense = rng.normal(size=(101, 8)).astype(np.float32)
        for s in (2, 4):
            lay = ShardedTableLayout(101, s)
            sh = convert_table_layout(dense, (s, lay.rows_per_shard, 8))
            np.testing.assert_array_equal(sh, np.asarray(
                shard_table(dense, lay)))
            back = convert_table_layout(sh, (101, 8))
            np.testing.assert_array_equal(back, dense)
        # resharding 2 -> 4 via contiguous row blocks
        sh2 = convert_table_layout(dense, (2, 51, 8))
        sh4 = convert_table_layout(sh2, (4, 26, 8))
        np.testing.assert_array_equal(
            convert_table_layout(sh4, (101, 8)), dense)

    def test_incompatible_shapes_rejected(self):
        with pytest.raises(ValueError, match="cannot convert"):
            convert_table_layout(np.zeros((10, 8)), (10, 4))

    def test_vocab_mismatch_rejected(self):
        """Layout conversion must not silently truncate or zero-pad a
        checkpoint whose logical row count differs (wrong dataset/config)."""
        with pytest.raises(ValueError, match="disjoint logical row"):
            convert_table_layout(np.zeros((100, 8)), (50, 8))
        with pytest.raises(ValueError, match="disjoint logical row"):
            convert_table_layout(np.zeros((100, 8)), (200, 8))
        with pytest.raises(ValueError, match="disjoint logical row"):
            # (4, 26) can only hold 101..104 logical rows, not 100
            convert_table_layout(np.zeros((100, 8)), (4, 26, 8))
        with pytest.raises(ValueError, match="disjoint logical row"):
            convert_table_layout(np.zeros((2, 51, 8)), (90, 8))

    def test_num_rows_closes_the_padding_ambiguity(self):
        """A sharded shape hides the exact row count in its tail padding;
        the caller's true entity count makes the check exact."""
        # (2, 51) fits any V in 101..102 — undetectable from shapes alone,
        # but num_rows=101 proves the 102-row checkpoint is a wrong vocab
        with pytest.raises(ValueError, match="cannot hold exactly 101"):
            convert_table_layout(np.zeros((102, 8)), (2, 51, 8),
                                 num_rows=101)
        out = convert_table_layout(np.zeros((101, 8)), (2, 51, 8),
                                   num_rows=101)
        assert out.shape == (2, 51, 8)
        # and through the checkpoint seam
        import jax
        from repro.models import KGEConfig, RGCNConfig, init_kge_params
        from repro.training import restore_checkpoint, save_checkpoint
        import tempfile
        p_shard = init_kge_params(jax.random.PRNGKey(0), KGEConfig(
            rgcn=RGCNConfig(num_entities=101, num_relations=6,
                            hidden_dim=16, num_layers=2, num_bases=2,
                            num_table_shards=2)))
        p_dense_102 = init_kge_params(jax.random.PRNGKey(0), KGEConfig(
            rgcn=RGCNConfig(num_entities=102, num_relations=6,
                            hidden_dim=16, num_layers=2, num_bases=2)))
        with tempfile.TemporaryDirectory() as tmp:
            path = save_checkpoint(tmp, 1, p_dense_102)
            with pytest.raises(ValueError, match="cannot hold exactly"):
                restore_checkpoint(path, p_shard, entity_rows=101)


# ====================================================================== #
# Quantized (int8) table: the straight-through gather contract at every
# shard count and exchange layout
# ====================================================================== #
class TestQuantizedGatherSweep:
    """``table_dtype="int8"`` sweep: forward within the per-row ``scale/2``
    bound of dense fp32 at 1/2/4 shards, master-weight gradients BITWISE
    equal to the fp32 path on the identical dequantized inputs (the
    straight-through backward is the same scatter-add), and every
    shard_map exchange layout bitwise equal to the single-device int8
    simulation."""

    V, D = 301, 16

    def _setup(self, s):
        table = jax.random.normal(jax.random.PRNGKey(4), (self.V, self.D))
        # duplicates, out-of-order, boundary rows; 13 ids so V_b % s != 0
        # for s in (2, 4) — the pad-around-collective path
        ids = np.array([5, 3, 5, 0, self.V - 1, 3, 299, 150, 150, 7, 0,
                        self.V - 1, 42], np.int32)
        lay = ShardedTableLayout(self.V, s)
        shards = shard_table(table, lay)
        li, ow = plan_local_gather(lay, ids)
        return table, ids, shards, jnp.asarray(li), jnp.asarray(ow)

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_forward_within_half_scale_of_dense(self, s):
        table, ids, shards, li, ow = self._setup(s)
        codes, scales = quantize_rows(np.asarray(shards))
        out = np.asarray(sharded_gather(shards, li, ow, table_dtype="int8"))
        dense = np.asarray(table)[ids]
        # contiguous row blocks put global row g at flat row g
        row_scale = scales.reshape(-1)[ids]
        assert (np.abs(out - dense) <= row_scale[:, None] / 2.0).all()
        # and bitwise equal to the dense gather of the dequantized master
        dq = np.asarray(dequantize_rows(codes, scales))
        np.testing.assert_array_equal(out, dq.reshape(-1, self.D)[ids])

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_loss_and_grads_within_tolerance_of_fp32(self, s):
        table, ids, shards, li, ow = self._setup(s)
        w = jnp.arange(1.0, self.D + 1)

        def loss(t, dtype):
            return jnp.sum(jnp.tanh(
                sharded_gather(t, li, ow, table_dtype=dtype)) * w)

        l8, g8 = jax.value_and_grad(loss)(shards, "int8")
        lf, gf = jax.value_and_grad(loss)(shards, "fp32")
        # |tanh(a) - tanh(b)| <= |a - b| <= scale/2 per gathered element,
        # so the loss bound is sum(w) * scale_max / 2 per batch slot and
        # the per-table-element grad bound follows from |tanh'| shifts
        # (<= 2|a-b|) times the duplicate count (<= 3 here)
        _, scales = quantize_rows(np.asarray(shards))
        s_max = float(scales.max())
        assert abs(float(l8) - float(lf)) <= \
            len(ids) * float(jnp.sum(w)) * s_max / 2.0
        np.testing.assert_allclose(np.asarray(g8), np.asarray(gf),
                                   atol=3 * self.D * s_max, rtol=0)

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_master_grads_bitwise_fp32_path_on_dequant(self, s):
        _, ids, shards, li, ow = self._setup(s)
        dq = jnp.asarray(dequantize_rows(*quantize_rows(np.asarray(shards))))
        w = jnp.arange(1.0, self.D + 1)

        def loss(t, dtype):
            return jnp.sum(jnp.tanh(
                sharded_gather(t, li, ow, table_dtype=dtype)) * w)

        lq, gq = jax.value_and_grad(loss)(shards, "int8")
        lf, gf = jax.value_and_grad(loss)(dq, "fp32")
        assert float(lq) == float(lf)
        np.testing.assert_array_equal(np.asarray(gq), np.asarray(gf))

    @pytest.mark.parametrize("exchange",
                             ("psum", "psum_scatter", "alltoall"))
    @pytest.mark.parametrize("s", (2, 4))
    def test_spmd_exchange_matches_sim_and_fp32_grads(self, s, exchange):
        _, ids, shards, li, ow = self._setup(s)
        sim = np.asarray(sharded_gather(shards, li, ow, table_dtype="int8"))
        w = jnp.arange(1.0, self.D + 1)

        def spmd_loss_and_out(stack, dtype):
            out = jax.vmap(lambda t: sharded_gather(
                t[None], li, ow, axis_name="model", exchange=exchange,
                table_dtype=dtype), axis_name="model")(stack)
            return out

        out = spmd_loss_and_out(shards, "int8")
        for shard in range(s):          # replicated output == simulation
            np.testing.assert_array_equal(np.asarray(out[shard]), sim)

        # int8 spmd master grads == fp32 spmd grads at the dequantized
        # master (same vmap-inlined collective-transpose backward path as
        # the fp32 exchange grad test above: loss consumes shard 0's copy)
        dq = jnp.asarray(dequantize_rows(*quantize_rows(np.asarray(shards))))

        def loss(stack, dtype):
            return jnp.sum(jnp.tanh(spmd_loss_and_out(stack, dtype)[0]) * w)

        gq = jax.grad(loss)(shards, "int8")
        gf = jax.grad(loss)(dq, "fp32")
        np.testing.assert_array_equal(np.asarray(gq), np.asarray(gf))

    @pytest.mark.parametrize("s", SHARD_COUNTS)
    def test_fullgraph_loss_matches_fp32_on_dequantized_master(
            self, small_kg, s):
        """Model-level: the int8 full-graph loss and ALL parameter
        gradients are bitwise what the fp32 model produces when handed the
        dequantized master table — the quantizer is exactly a forward-only
        table substitution."""
        parts = expand_all(
            small_kg, partition_graph(small_kg, 2, "vertex_cut", seed=0), 2)
        pb = pad_partitions(parts)
        part0 = {f.name: jnp.asarray(getattr(pb, f.name)[0])
                 for f in dataclasses.fields(pb)}
        rgcn = dict(num_entities=small_kg.num_entities,
                    num_relations=small_kg.num_relations, hidden_dim=16,
                    num_layers=2, num_bases=2, dropout=0.0,
                    num_table_shards=s)
        cfg8 = KGEConfig(rgcn=RGCNConfig(**rgcn, table_dtype="int8"))
        cfgf = KGEConfig(rgcn=RGCNConfig(**rgcn))
        p = init_kge_params(jax.random.PRNGKey(0), cfgf)
        emb = np.asarray(p["entity_embedding"])
        dq = dequantize_rows(*quantize_rows(
            emb if emb.ndim == 3 else emb[None]))
        p_dq = dict(p)
        p_dq["entity_embedding"] = jnp.asarray(
            dq if emb.ndim == 3 else dq[0])
        key = jax.random.PRNGKey(3)
        l8, g8 = jax.value_and_grad(lambda q: fullgraph_loss(
            q, cfg8, part0, key, train=False)[0])(p)
        lf, gf = jax.value_and_grad(lambda q: fullgraph_loss(
            q, cfgf, part0, key, train=False)[0])(p_dq)
        assert float(l8) == float(lf)
        _tree_equal(g8, gf)
        # and the quantization error stays small at model level
        l_fp32 = fullgraph_loss(p, cfgf, part0, key, train=False)[0]
        np.testing.assert_allclose(float(l8), float(l_fp32), rtol=0.05)


# ====================================================================== #
# Real multi-device mesh: the psum exchange itself (subprocess: forcing
# host device count must happen before jax import)
# ====================================================================== #
_TWO_DEVICE_SCRIPT = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
assert jax.device_count() == 2, jax.devices()
from repro.core import expand_all, make_synthetic_kg, pad_partitions, \\
    partition_graph
from repro.launch.mesh import make_host_mesh
from repro.models import KGEConfig, RGCNConfig, fullgraph_loss, \\
    init_kge_params
from repro.sharding import kge_param_specs
from repro.training import adam
from repro.training.distributed import (
    make_simulated_train_step, make_spmd_train_step,
)

kg = make_synthetic_kg(150, 6, 1200, seed=1).with_inverse_relations()
parts = expand_all(kg, partition_graph(kg, 1, "vertex_cut", seed=0), 2)
pb = pad_partitions(parts)
batch = {f.name: jnp.asarray(getattr(pb, f.name))
         for f in dataclasses.fields(pb)}
cfg = KGEConfig(rgcn=RGCNConfig(
    num_entities=kg.num_entities, num_relations=kg.num_relations,
    hidden_dim=16, num_layers=2, num_bases=2, dropout=0.0,
    num_table_shards=2))
params = init_kge_params(jax.random.PRNGKey(0), cfg)
assert params["entity_embedding"].shape[0] == 2
mesh = make_host_mesh(1, 2)                      # data=1 x model=2
opt = adam(0.01)
keys = jax.random.split(jax.random.PRNGKey(2), 1)

step_spmd = make_spmd_train_step(
    lambda p, b, k: fullgraph_loss(p, cfg, b, k, train=False,
                                   model_axis="model"),
    opt, mesh, param_specs=kge_param_specs(params, mesh))
step_sim = make_simulated_train_step(
    lambda p, b, k: fullgraph_loss(p, cfg, b, k, train=False), opt)
# The exchange's REPLICATED-LOSS backward (identity, not the collective
# transpose — sharding.embedding._replicated_exchange) makes the real
# shard_map step BITWISE equal to the vmap simulation: the historical S-x
# entity-gradient inflation (psum transposing to psum under
# check_rep=False, masked by adam's scale-invariant first step and the
# old atol=5e-3) would fail this exactly.
p1, o1, m1 = step_spmd(params, opt.init(params), batch, keys)
p2, o2, m2 = step_sim(params, opt.init(params), batch, keys)
assert float(m1["loss"]) == float(m2["loss"])
for a, b in zip(jax.tree_util.tree_leaves(p1),
                jax.tree_util.tree_leaves(p2)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# second step (optimizer state now differs from init — a wrong exchange
# backward would compound here) stays bitwise on the same trajectory
keys2 = jax.random.split(jax.random.PRNGKey(5), 1)
_, _, m1b = step_spmd(p1, o1, batch, keys2)
_, _, m2b = step_sim(p2, o2, batch, keys2)
assert float(m1b["loss"]) == float(m2b["loss"])
assert float(m1b["loss"]) < float(m1["loss"])    # it is actually learning

# every exchange layout over the REAL 2-device axis is bitwise equal to
# the dense replicated psum: same loss, same updated params, bit for bit
ref_p = ref_m = None
for exchange in ("psum", "psum_scatter", "alltoall"):
    cfg_x = KGEConfig(rgcn=dataclasses.replace(
        cfg.rgcn, gather_exchange=exchange))
    step_x = make_spmd_train_step(
        lambda p, b, k: fullgraph_loss(p, cfg_x, b, k, train=False,
                                       model_axis="model"),
        opt, mesh, param_specs=kge_param_specs(params, mesh))
    p_x, _, m_x = step_x(params, opt.init(params), batch, keys)
    if ref_p is None:
        ref_p, ref_m = p_x, m_x
    else:
        assert float(m_x["loss"]) == float(ref_m["loss"]), exchange
        for a, b in zip(jax.tree_util.tree_leaves(p_x),
                        jax.tree_util.tree_leaves(ref_p)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("TWO_DEVICE_OK")
"""


@pytest.mark.slow
def test_spmd_two_device_model_axis_psum_exchange():
    """Drive the REAL exchange: 2 forced host devices, mesh 1x2
    (data x model), entity table sharded P('model') so each device holds
    one row block and sharded_gather takes the axis_index + psum branch;
    loss and training trajectory must be BITWISE equal to the
    single-device vmap simulation (the replicated-loss identity backward
    makes the exchange transpose exact)."""
    import os
    import subprocess
    import sys
    repo = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    proc = subprocess.run(
        [sys.executable, "-c", _TWO_DEVICE_SCRIPT], cwd=repo, env=env,
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "TWO_DEVICE_OK" in proc.stdout


_TWO_DEVICE_INT8_SCRIPT = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
assert jax.device_count() == 2, jax.devices()
from repro.core import expand_all, make_synthetic_kg, pad_partitions, \\
    partition_graph
from repro.launch.mesh import make_host_mesh
from repro.models import KGEConfig, RGCNConfig, fullgraph_loss, \\
    init_kge_params
from repro.sharding import kge_param_specs
from repro.training import adam
from repro.training.distributed import (
    make_simulated_train_step, make_spmd_train_step,
)

kg = make_synthetic_kg(150, 6, 1200, seed=1).with_inverse_relations()
parts = expand_all(kg, partition_graph(kg, 1, "vertex_cut", seed=0), 2)
pb = pad_partitions(parts)
batch = {f.name: jnp.asarray(getattr(pb, f.name))
         for f in dataclasses.fields(pb)}
cfg = KGEConfig(rgcn=RGCNConfig(
    num_entities=kg.num_entities, num_relations=kg.num_relations,
    hidden_dim=16, num_layers=2, num_bases=2, dropout=0.0,
    num_table_shards=2, table_dtype="int8"))
params = init_kge_params(jax.random.PRNGKey(0), cfg)
assert params["entity_embedding"].shape[0] == 2
assert params["entity_embedding"].dtype == jnp.float32   # fp32 master
mesh = make_host_mesh(1, 2)                      # data=1 x model=2
opt = adam(0.01)
keys = jax.random.split(jax.random.PRNGKey(2), 1)

# the REAL quantized exchange (int8 codes + f32 scale sidecar over the
# 2-device model axis) must be bitwise equal to the single-device int8
# simulation: same loss, same updated fp32 master, two steps deep
step_spmd = make_spmd_train_step(
    lambda p, b, k: fullgraph_loss(p, cfg, b, k, train=False,
                                   model_axis="model"),
    opt, mesh, param_specs=kge_param_specs(params, mesh))
step_sim = make_simulated_train_step(
    lambda p, b, k: fullgraph_loss(p, cfg, b, k, train=False), opt)
p1, o1, m1 = step_spmd(params, opt.init(params), batch, keys)
p2, o2, m2 = step_sim(params, opt.init(params), batch, keys)
assert float(m1["loss"]) == float(m2["loss"])
for a, b in zip(jax.tree_util.tree_leaves(p1),
                jax.tree_util.tree_leaves(p2)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
keys2 = jax.random.split(jax.random.PRNGKey(5), 1)
_, _, m1b = step_spmd(p1, o1, batch, keys2)
_, _, m2b = step_sim(p2, o2, batch, keys2)
assert float(m1b["loss"]) == float(m2b["loss"])
assert float(m1b["loss"]) < float(m1["loss"])    # it is actually learning

# every exchange layout carries the int8 codes + scales bitwise equal
ref_p = ref_m = None
for exchange in ("psum", "psum_scatter", "alltoall"):
    cfg_x = KGEConfig(rgcn=dataclasses.replace(
        cfg.rgcn, gather_exchange=exchange))
    step_x = make_spmd_train_step(
        lambda p, b, k: fullgraph_loss(p, cfg_x, b, k, train=False,
                                       model_axis="model"),
        opt, mesh, param_specs=kge_param_specs(params, mesh))
    p_x, _, m_x = step_x(params, opt.init(params), batch, keys)
    if ref_p is None:
        ref_p, ref_m = p_x, m_x
    else:
        assert float(m_x["loss"]) == float(ref_m["loss"]), exchange
        for a, b in zip(jax.tree_util.tree_leaves(p_x),
                        jax.tree_util.tree_leaves(ref_p)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("TWO_DEVICE_INT8_OK")
"""


@pytest.mark.slow
def test_spmd_two_device_int8_table_matches_simulation():
    """The int8 table over a REAL 2-device model axis: each device
    quantizes its fp32 master block in-jit and exchanges int8 codes with
    the f32 scale sidecar; the training trajectory (loss, updated master,
    two steps) must be BITWISE equal to the single-device int8 simulation,
    for every exchange layout."""
    import os
    import subprocess
    import sys
    repo = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    proc = subprocess.run(
        [sys.executable, "-c", _TWO_DEVICE_INT8_SCRIPT], cwd=repo, env=env,
        capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "TWO_DEVICE_INT8_OK" in proc.stdout
