"""End-to-end driver (deliverable b): distributed edge-mini-batch training
on an ogbl-citation2-shaped graph — the paper's large-dataset configuration
(Algorithm 1) — for a few hundred model updates, with the Fig. 6 component
timing breakdown and a partitioning-strategy comparison (Table 5).

Run: PYTHONPATH=src python examples/distributed_kg_train.py [--updates 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.data import synthetic_citation2
from repro.training import KGETrainer, TrainConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=200,
                    help="total model updates (a few hundred)")
    ap.add_argument("--trainers", type=int, default=4)
    ap.add_argument("--pipeline", default="async",
                    choices=("async", "serial"),
                    help="host input pipeline (async overlaps "
                         "getComputeGraph with the device step)")
    args = ap.parse_args()

    splits = synthetic_citation2(scale=0.001, seed=0)
    kg = splits["train"]
    print(f"KG: {kg.num_entities} entities, {kg.num_edges} edges, "
          f"{kg.features.shape[1]}-d features")

    # --- Table 5 comparison: partition quality per strategy ----------
    print("\npartitioning strategies (Table 5):")
    for strategy in ("vertex_cut", "edge_cut", "random"):
        tr = KGETrainer(splits, TrainConfig(
            num_trainers=args.trainers, strategy=strategy, epochs=1,
            hidden_dim=16, batch_size=512, learning_rate=0.01))
        total = np.mean([p.num_local_edges for p in tr.partitions])
        print(f"  {strategy:11s} RF={tr.replication_factor:4.2f} "
              f"avg total edges/partition={total:,.0f}")

    # --- Algorithm 1 training ---------------------------------------
    cfg = TrainConfig(
        num_trainers=args.trainers, strategy="vertex_cut", num_hops=2,
        hidden_dim=32, num_negatives=1, batch_size=512,
        learning_rate=0.01, epochs=10_000,   # bounded by --updates below
        pipeline=args.pipeline,
    )
    trainer = KGETrainer(splits, cfg)
    print(f"\ntraining: {args.trainers} trainers ({cfg.pipeline} pipeline), "
          f"budget={trainer.budget}")
    updates = 0
    epoch = 0
    while updates < args.updates:
        rec = trainer.train_epoch()
        updates += rec["num_batches"]
        epoch += 1
        print(f"  epoch {epoch:2d}: loss={rec['loss']:.4f} "
              f"updates={updates:4d} "
              f"getComputeGraph={rec['t_get_compute_graph']:.2f}s "
              f"(built {rec['t_host_build']:.2f}s, "
              f"overlap {rec['overlap_fraction']:.0%}) "
              f"deviceStep={rec['t_device_step']:.2f}s")

    metrics = trainer.evaluate("valid")
    print("\nvalidation:", {k: round(v, 4) for k, v in metrics.items()})
    assert np.isfinite(metrics["valid_mrr"])
    print("OK")


if __name__ == "__main__":
    main()
