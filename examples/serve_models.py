"""Serving example: (a) batched greedy decoding of a reduced assigned-arch
LM through the ServeEngine (the same serve_step the dry-run lowers at
32k/500k cache scale), and (b) KGE link-prediction queries answered with the
Pallas ranking kernel.

Run: PYTHONPATH=src python examples/serve_models.py [--arch rwkv6-3b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import synthetic_fb15k
from repro.nn import init_params
from repro.serving import (
    KGEServeEngine, KGEServer, Request, ServeEngine, ShardedKGEServer,
)
from repro.training import KGETrainer, TrainConfig


def serve_lm(arch: str) -> None:
    cfg = get_arch(arch).reduced()
    print(f"[lm] serving {cfg.name} ({cfg.arch_type})")
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    engine = ServeEngine(cfg, params, slots=4, max_seq=64)
    rng = np.random.default_rng(0)
    requests = [
        Request(i, rng.integers(1, cfg.vocab_size, size=1 + i % 5)
                .astype(np.int32), max_new_tokens=8)
        for i in range(6)
    ]
    done = engine.run(requests)
    for r in done:
        print(f"  req {r.request_id}: prompt={r.prompt.tolist()} "
              f"-> {r.output}")
    assert all(len(r.output) == 8 for r in done)


def serve_kge(decoder: str = "distmult") -> None:
    print(f"[kge] training a small {decoder} model, then serving "
          f"(h, r, ?) queries")
    splits = synthetic_fb15k(scale=0.015, seed=0)
    tr = KGETrainer(splits, TrainConfig(
        num_trainers=2, epochs=10, hidden_dim=24, learning_rate=0.05,
        decoder=decoder))
    tr.fit()
    emb = tr.encode_all_entities()
    # the server takes the decoder's whole parameter tree — any registered
    # decoder serves through the same Pallas ranking kernel
    server = KGEServer(emb, tr.params["decoder"], decoder=decoder)
    heads = np.array([0, 1, 2])
    rels = np.array([0, 1, 2])
    top = server.topk_tails(heads, rels, k=5)
    for h, r, t in zip(heads, rels, top):
        print(f"  ({h}, r{r}, ?) -> top tails {t.tolist()}")

    # the sharded engine: same trained model, table row-sharded over 2
    # shards, per-shard top-k + merge (the (B, N) score matrix never
    # materializes), dynamic request batching with a hot-entity cache —
    # answers EXACTLY equal to the dense server (docs/serving.md)
    sharded = ShardedKGEServer(emb, tr.params["decoder"], decoder,
                               num_shards=2, cache_size=32)
    engine = KGEServeEngine(sharded, slots=4, max_k=5)
    reqs = [engine.submit(int(h), int(r), k=5)
            for h, r in zip(heads, rels)]
    engine.run()
    for r, dense_row in zip(reqs, top):
        print(f"  [2-shard] req {r.request_id}: "
              f"({r.head}, r{r.relation}, ?) -> {r.tails.tolist()}")
        assert (r.tails == dense_row).all(), "sharded != dense top-k"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    args = ap.parse_args()
    serve_lm(args.arch)
    serve_kge()
    print("OK")


if __name__ == "__main__":
    main()
