"""Quickstart: the paper's full pipeline in ~60 seconds on CPU.

Partitions a synthetic FB15k-237-shaped knowledge graph with vertex-cut,
expands partitions to self-sufficiency, trains a 2-layer RGCN + DistMult
with constraint-based negative sampling on 4 (simulated) trainers with
AllReduce-averaged gradients, and reports filtered MRR / Hits@k.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import synthetic_fb15k
from repro.training import KGETrainer, TrainConfig


def main() -> None:
    splits = synthetic_fb15k(scale=0.02, seed=0)
    kg = splits["train"]
    print(f"KG: {kg.num_entities} entities, {kg.num_relations} relations, "
          f"{kg.num_edges} train edges")

    cfg = TrainConfig(
        num_trainers=4,           # paper runs 1..8
        strategy="vertex_cut",    # + neighborhood expansion (§3.2)
        num_hops=2,               # == RGCN layers
        hidden_dim=32,
        num_negatives=1,          # constraint-based, partition-local
        batch_size=None,          # full edge batch (paper's FB15k setting)
        learning_rate=0.05,
        epochs=15,
    )
    trainer = KGETrainer(splits, cfg)
    print(f"partitioned into {cfg.num_trainers} self-sufficient partitions, "
          f"replication factor {trainer.replication_factor:.2f}")

    trainer.fit(log_fn=lambda r: print(
        f"  epoch {r['epoch']:3d}  loss {r['loss']:.4f}  "
        f"({r['t_epoch']:.2f}s)"))

    metrics = trainer.evaluate("test")
    print("\nfiltered test metrics (Eq. 5/6):")
    for k, v in metrics.items():
        print(f"  {k:14s} {v:.4f}")
    assert metrics["test_mrr"] > 0.03, "training failed to learn"

    decoder_sweep()
    print("\nOK — see examples/distributed_kg_train.py for the "
          "mini-batch/ogbl-citation2 configuration.")


def decoder_sweep() -> None:
    """The scaling stack is decoder-agnostic (paper §6): every registered
    decoder — DistMult, TransE, ComplEx, RotatE — trains and evaluates
    through the same trainer, Pallas ranking kernel and (with
    ``num_table_shards > 1``) candidate-axis-sharded ranking.  Swap the
    scoring function by name; nothing else changes."""
    from repro.models import registered_decoders

    splits = synthetic_fb15k(scale=0.01, seed=1)
    print("\ndecoder sweep (3 epochs each, 2-shard sharded ranking):")
    for name in registered_decoders():
        trainer = KGETrainer(splits, TrainConfig(
            num_trainers=2, num_hops=2, hidden_dim=32, batch_size=None,
            learning_rate=0.05, epochs=3, decoder=name,
            num_table_shards=2))
        trainer.fit()
        m = trainer.evaluate("valid")
        trainer.close()
        print(f"  {name:10s} valid MRR {m['valid_mrr']:.4f}  "
              f"Hits@10 {m['valid_hits@10']:.4f}")


if __name__ == "__main__":
    main()
