"""Train any assigned architecture (reduced config) on the synthetic token
stream — the same ``train_step`` the multi-pod dry-run lowers at production
scale, here exercised with real numerics on CPU.

Run: PYTHONPATH=src python examples/train_assigned_arch.py \
         [--arch deepseek-v2-lite-16b] [--steps 30]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_arch
from repro.data import TokenStream
from repro.launch.steps import make_train_step
from repro.nn import count_params, init_params
from repro.training.optimizer import adam, warmup_cosine_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b",
                    choices=ASSIGNED)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    print(f"{cfg.name}: {count_params(params):,} params "
          f"({cfg.arch_type})")

    optimizer = adam(warmup_cosine_schedule(3e-3, 5, args.steps))
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(cfg, optimizer), donate_argnums=(0, 1))

    stream = iter(TokenStream(cfg.vocab_size, args.batch, args.seq, seed=0))
    first = last = None
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        if cfg.arch_type == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, args.seq, cfg.vision_dim), jnp.float32)
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, :, None],
                (args.batch, args.seq, 3)).astype(jnp.int32)
        if cfg.arch_type == "encdec":
            batch["audio_frames"] = jnp.zeros(
                (args.batch, cfg.encoder_frames, cfg.d_model), jnp.float32)
        params, opt_state, metrics = step(params, opt_state, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 5 == 0 or i == args.steps - 1:
            print(f"  step {i:3d}  loss {loss:.4f}")
    assert np.isfinite(last)
    assert last < first, "loss did not decrease"
    print(f"OK: loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
